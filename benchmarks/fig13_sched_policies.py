"""Fig 13: scheduling policies on production-like traces (loaded regime).

Paper: PRE_EV/PRE_MG cut high-priority execution time by 5.3 %/4.5 % vs
NO_PRE; PRE_MG also helps low-priority tasks via migration.  The cluster is
sized so demand exceeds capacity (the paper's 32-vFPGA setting relative to
its trace volume) — preemption only matters under contention."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.scheduler import Policy
from repro.core.simulator import SimParams, Simulator
from repro.core.traces import generate_trace

JOBS = generate_trace(n_jobs=800, horizon_s=2 * 3600, seed=13)


def main():
    for pol in (Policy.FCFS, Policy.NO_PRE, Policy.PRE_EV, Policy.PRE_MG):
        r = Simulator(JOBS, num_nodes=8, policy=pol,
                      params=SimParams(acceleration_rate=1.0)).run()
        by = r["latency_by_priority"]
        hp = max(by)
        lp = min(by)
        emit(f"fig13/{pol.value}", r["mean_latency_s"] * 1e6,
             f"hp={by[hp]:.0f}s lp={by[lp]:.0f}s "
             f"evict={r['evictions']} migr={r['migrations']}")


if __name__ == "__main__":
    main()
