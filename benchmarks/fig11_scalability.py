"""Fig 11: throughput vs cluster size x acceleration rate (trace-driven).

Paper: 1-128 vFPGAs, rates 0/25/50/75/100 %; even 25 % acceleration gives
1.1x throughput over 0 %."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.scheduler import Policy
from repro.core.simulator import SimParams, Simulator
from repro.core.traces import generate_trace

JOBS = generate_trace(n_jobs=600, horizon_s=6 * 3600, seed=11)


def main():
    base = {}
    for n in (1, 4, 16, 64, 128):
        for rate in (0.0, 0.25, 0.5, 0.75, 1.0):
            r = Simulator(JOBS, num_nodes=n, policy=Policy.NO_PRE,
                          params=SimParams(acceleration_rate=rate)).run()
            if rate == 0.0:
                base[n] = r["throughput_per_min"]
            gain = r["throughput_per_min"] / base[n]
            emit(f"fig11/vslices{n}_rate{int(rate * 100)}",
                 r["mean_latency_s"] * 1e6,
                 f"thr={r['throughput_per_min']:.2f}/min x{gain:.2f} vs 0%")


if __name__ == "__main__":
    main()
