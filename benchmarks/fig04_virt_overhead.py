"""Fig 4: end-to-end execution time — native JAX vs through the Funky stack.

The same jitted step functions run (a) dispatched directly ("native"), and
(b) as a guest task whose requests cross the monitor's queues ("funky").
The paper reports 7.4 % mean overhead vs native on Alveo U50; here the
accelerator is the host CPU so absolute times differ, but the measured
quantity is identical: virtualization overhead of the request path.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.core import TaskImage, TaskStatus, make_cluster
from repro.train import (DataConfig, OptConfig, make_batch, make_train_state,
                         make_train_step)

STEPS = 20


def _native_seconds(image: TaskImage) -> float:
    from repro.configs import get_arch
    from repro.configs.base import ShapeConfig
    from repro.models import build_model

    cfg = get_arch(image.arch)
    shape = ShapeConfig("b", "train", image.seq_len, image.global_batch)
    bundle = build_model(cfg)
    params, opt = make_train_state(bundle, image.opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(bundle, image.opt,
                                   num_microbatches=image.chunks))
    # warm compile outside the timed region (Funky compiles in setup too —
    # setup costs are Fig 6's subject, steady-state overhead is Fig 4's)
    b0 = make_batch(cfg, shape, 0)
    params, opt, _ = step(params, opt, b0)
    t0 = time.perf_counter()
    for i in range(STEPS):
        batch = make_batch(cfg, shape, i + 1)
        params, opt, m = step(params, opt, batch)
    jax.block_until_ready(m["loss"])
    return time.perf_counter() - t0


def _funky_seconds(image: TaskImage) -> float:
    cl = make_cluster(num_nodes=1, slices_per_node=1, images={"i": image})
    rt = cl.nodes["node0"].runtime
    rt.create("t", image)
    rt.start("t")
    rec = rt.tasks["t"]
    # skip setup + first (warm-up) step, then time the remaining steps
    while rec.guest_state.step < 1 and rec.status.value not in ("done", "failed"):
        time.sleep(0.002)
    t0 = time.perf_counter()
    assert rt.wait("t", timeout=3600) == TaskStatus.DONE, rec.error
    return time.perf_counter() - t0


def main():
    image = TaskImage(name="i", kind="train", arch="yi-9b-smoke", seq_len=32,
                      global_batch=8, total_steps=STEPS + 1, chunks=2,
                      opt=OptConfig(warmup_steps=2, decay_steps=100))
    native = _native_seconds(image)
    funky = _funky_seconds(image)
    ovh = (funky - native) / native * 100.0
    emit("fig04/native_train_20steps", native * 1e6 / STEPS,
         f"{native:.2f}s total")
    emit("fig04/funky_train_20steps", funky * 1e6 / STEPS,
         f"{funky:.2f}s total; overhead={ovh:.1f}% (paper: 7.4%)")


if __name__ == "__main__":
    main()
