"""Fig 7: FPGA-state evict/resume latency vs (dirty) data size.

Paper: eviction 0.4 ms (1 MB) - 177 ms (1000 MB); resumption higher due to
worker respawn + both buffers.  We sweep a dirty device buffer 1 MiB - 512
MiB and also show the dirty-only optimization (clean buffers cost ~0).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import FunkyCL, Monitor, Program, SliceAllocator


def _measure(mb: int, dirty: bool):
    alloc = SliceAllocator("n0", 1, mem_cap_bytes=16 << 30)
    m = Monitor(f"ev{mb}", alloc)
    n = mb * (1 << 20) // 4
    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    m.vfpga_init(Program("id", lambda x: x + 0.0), (spec,))
    cl = FunkyCL(m)
    cl.clCreateBuffer("x", spec)
    cl.write_buffer("x", np.ones(n, np.float32))
    if dirty:
        cl.clEnqueueKernel("id", ("x",), ("x",))    # device-newer => DIRTY
    cl.clFinish()
    ev = m.evict()
    rs = m.resume()
    m.vfpga_exit()
    return ev, rs


def main():
    for mb in (1, 16, 64, 256, 512):
        ev, rs = _measure(mb, dirty=True)
        emit(f"fig07/evict_dirty_{mb}MiB", ev["evict_seconds"] * 1e6,
             f"{ev['saved_bytes'] / 2**20:.0f} MiB saved")
        emit(f"fig07/resume_{mb}MiB", rs["resume_seconds"] * 1e6,
             f"{rs['restored_bytes'] / 2**20:.0f} MiB restored")
    ev, rs = _measure(256, dirty=False)
    emit("fig07/evict_clean_256MiB", ev["evict_seconds"] * 1e6,
         f"dirty-only optimization: {ev['saved_bytes']} bytes saved "
         f"({ev['skipped_bytes'] / 2**20:.0f} MiB skipped)")


if __name__ == "__main__":
    main()
