"""Fig 6: sandbox setup/teardown overheads.

Paper: unikernel boot/teardown cuts container overheads by 82-84 %; the
FunkyCL-specific setup (bitstream copy + worker spawn) is ~245 ms.  Here:
task create (boot), vfpga_init cold (program compile = "reconfiguration")
vs warm (program-cache hit), worker-thread spawn, teardown.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import (FunkyCL, Monitor, Program, SliceAllocator, TaskImage,
                        make_cluster)


def main():
    # --- task create (unikernel "boot") ------------------------------------
    image = TaskImage(name="i", kind="train", arch="yi-9b-smoke",
                      total_steps=1)
    cl = make_cluster(num_nodes=1, slices_per_node=1, images={"i": image})
    rt = cl.nodes["node0"].runtime
    t0 = time.perf_counter()
    rec = rt.create("boot-test", image)
    t_create = time.perf_counter() - t0
    emit("fig06/task_create", t_create * 1e6, "sandbox object boot")

    # --- vfpga_init: cold vs warm reconfiguration ---------------------------
    alloc = SliceAllocator("n0", 2)
    spec = jax.ShapeDtypeStruct((1 << 16,), jnp.float32)
    prog = Program("mm", lambda x: jnp.tanh(x) * 2.0)

    m1 = Monitor("cold", alloc)
    t0 = time.perf_counter()
    m1.vfpga_init(prog, (spec,))
    t_cold = time.perf_counter() - t0
    emit("fig06/vfpga_init_cold", t_cold * 1e6,
         "slot acquire + XLA compile ('bitstream reconfiguration')")

    m2 = Monitor("warm", alloc)
    m2.programs = m1.programs          # shared node-level program cache
    t0 = time.perf_counter()
    m2.vfpga_init(prog, (spec,))
    t_warm = time.perf_counter() - t0
    emit("fig06/vfpga_init_warm", t_warm * 1e6,
         f"cache hit; {t_cold / max(t_warm, 1e-9):.0f}x faster than cold")

    spawn = m1.metrics_hist["worker_spawn"][-1]
    emit("fig06/worker_thread_spawn", spawn * 1e6,
         "paper: 97.6-158ms on Alveo")

    # --- teardown -------------------------------------------------------------
    t0 = time.perf_counter()
    m1.vfpga_exit()
    m2.vfpga_exit()
    t_down = (time.perf_counter() - t0) / 2
    emit("fig06/vfpga_exit", t_down * 1e6, "zero + release")


if __name__ == "__main__":
    main()
