"""Fig 12: fault tolerance vs checkpoint interval (trace-driven).

Every job fails once at a uniform point (mean ~50 % of its runtime, per the
paper's setup); periodic snapshots bound the lost work.  Also reports the
no-failure overhead of each interval (Success case)."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.scheduler import Policy
from repro.core.simulator import SimParams, Simulator
from repro.core.traces import generate_trace

FAIL = generate_trace(n_jobs=300, horizon_s=4 * 3600, seed=12,
                      with_failures=True)
OK = generate_trace(n_jobs=300, horizon_s=4 * 3600, seed=12,
                    with_failures=False)
INTERVALS = (None, 30.0, 120.0, 600.0, 1800.0)


def main():
    for ck in INTERVALS:
        p = SimParams(checkpoint_interval_s=ck)
        rf = Simulator(FAIL, num_nodes=32, policy=Policy.NO_PRE, params=p).run()
        rs = Simulator(OK, num_nodes=32, policy=Policy.NO_PRE, params=p).run()
        label = "none" if ck is None else f"{int(ck)}s"
        emit(f"fig12/failures_ckpt_{label}", rf["mean_exec_s"] * 1e6,
             f"success-case exec {rs['mean_exec_s']:.1f}s")


if __name__ == "__main__":
    main()
