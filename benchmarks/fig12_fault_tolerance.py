"""Fig 12: fault tolerance vs checkpoint interval.

Trace-driven arm: every job fails once at a uniform point (mean ~50 % of
its runtime, per the paper's setup); periodic snapshots bound the lost
work.  Also reports the no-failure overhead of each interval (Success
case).

Live-plane arm (``--live`` / always in ``--smoke``): a two-node
engine-serve deployment absorbs a hard node crash mid-decode — leased
requests replay through the router, the replica restores from its last
crash-consistent snapshot on the surviving node, and the arm reports
goodput faulted vs fault-free plus the recovery latency (crash to first
post-crash completion).  The faulted run must complete the identical
request set bit-exactly (zero lost, zero duplicated).

    PYTHONPATH=src python -m benchmarks.fig12_fault_tolerance [--smoke]
"""

from __future__ import annotations

import sys
import time

from benchmarks.common import emit
from repro.core.scheduler import Policy
from repro.core.simulator import SimParams, Simulator
from repro.core.traces import generate_trace

INTERVALS = (None, 30.0, 120.0, 600.0, 1800.0)


def sim_arm(smoke: bool = False):
    n_jobs = 60 if smoke else 300
    fail = generate_trace(n_jobs=n_jobs, horizon_s=4 * 3600, seed=12,
                          with_failures=True)
    ok = generate_trace(n_jobs=n_jobs, horizon_s=4 * 3600, seed=12,
                        with_failures=False)
    for ck in INTERVALS:
        p = SimParams(checkpoint_interval_s=ck)
        rf = Simulator(fail, num_nodes=32, policy=Policy.NO_PRE,
                       params=p).run()
        rs = Simulator(ok, num_nodes=32, policy=Policy.NO_PRE,
                       params=p).run()
        label = "none" if ck is None else f"{int(ck)}s"
        emit(f"fig12/failures_ckpt_{label}", rf["mean_exec_s"] * 1e6,
             f"success-case exec {rs['mean_exec_s']:.1f}s")


def _run_live(n_req, max_new, *, crash, seed=11):
    """One live engine-serve run; optionally checkpoint + crash the
    serving node mid-flight.  Returns (busy_s, tokens_by_rid,
    recovery_s, replayed)."""
    import numpy as np

    from repro.core import TaskImage, make_cluster
    from repro.scaling.metrics import MetricsRegistry
    from repro.scaling.serving import reset_router, wait_for_service
    from repro.serve.engine import ServeRequest

    rng = np.random.Generator(np.random.Philox(seed))
    reqs = [ServeRequest(rid=f"r{i}", prompt=rng.integers(0, 100, 8),
                         max_new_tokens=2 + i % max_new)
            for i in range(n_req)]
    reg = MetricsRegistry()
    img = TaskImage(name="fig12-live", kind="engine-serve",
                    arch="yi-9b-smoke", prompt_len=8, global_batch=2,
                    total_steps=10 ** 9, max_new_tokens=max_new,
                    page_size=4)
    cluster = make_cluster(num_nodes=2, slices_per_node=1,
                           images={"fig12-live": img}, metrics=reg)
    router = reset_router("fig12-live")
    orch = cluster.orchestrator
    orch.start(tick_interval=0.01)
    recovery_s = None
    try:
        cid = orch.submit("fig12-live")
        node = wait_for_service(cluster, orch, cid, timeout_s=300)
        t0 = time.perf_counter()
        for r in reqs:
            router.submit(r)
        if crash:
            deadline = time.time() + 120
            while len(router.completed) < 2 and time.time() < deadline:
                time.sleep(0.005)
            orch.checkpoint(cid)
            done_before = set(router.completed)
            t_crash = time.perf_counter()
            orch.handle_node_failure(node)
            while (not (set(router.completed) - done_before)
                   and time.time() < deadline):
                time.sleep(0.005)
            recovery_s = time.perf_counter() - t_crash
        deadline = time.time() + 300
        while router.outstanding() > 0 and time.time() < deadline:
            time.sleep(0.02)
        busy_s = time.perf_counter() - t0
        if router.outstanding() > 0:
            raise SystemExit(
                f"fig12 live arm: {router.outstanding()} requests lost "
                f"(completed {sorted(router.completed)})")
        if router.duplicates or router.replay_mismatches:
            raise SystemExit(
                f"fig12 live arm: duplicates={router.duplicates} "
                f"replay_mismatches={router.replay_mismatches}")
        toks = {rid: list(rec.tokens)
                for rid, rec in router.completed.items()}
        return busy_s, toks, recovery_s, dict(router.replayed)
    finally:
        router.close()
        cluster.stop()


def live_arm(smoke: bool = False):
    n_req, max_new = (6, 5) if smoke else (12, 8)
    busy0, toks0, _, _ = _run_live(n_req, max_new, crash=False)
    busy1, toks1, recovery_s, replayed = _run_live(n_req, max_new,
                                                   crash=True)
    if toks1 != toks0:
        raise SystemExit("fig12 live arm: faulted run not bit-exact vs "
                         "fault-free baseline")
    total = sum(len(t) for t in toks0.values())
    emit("fig12/live_faultfree", busy0 * 1e6 / total,
         f"goodput={total / busy0:.1f}tok/s requests={n_req}")
    emit("fig12/live_crash", busy1 * 1e6 / total,
         f"goodput={total / busy1:.1f}tok/s replayed={len(replayed)} "
         f"bit_exact=yes")
    emit("fig12/live_recovery", (recovery_s or 0.0) * 1e6,
         f"recovery_s={recovery_s:.3f}" if recovery_s is not None
         else "recovery_s=n/a")


def main(smoke: bool = False, live: bool = True):
    sim_arm(smoke)
    if live:
        live_arm(smoke)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:],
         live="--no-live" not in sys.argv[1:])
