"""Shared benchmark helpers: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (derived = the
figure-specific metric, e.g. overhead %, bytes/s, tasks/min).
"""

from __future__ import annotations

import time
from contextlib import contextmanager

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


@contextmanager
def timed():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["seconds"] = time.perf_counter() - t0


def time_fn(fn, *args, repeat: int = 5, warmup: int = 1, **kw) -> float:
    """Median wall seconds of fn(*args, **kw)."""
    for _ in range(warmup):
        fn(*args, **kw)
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


SMALL_TRAIN = dict(kind="train", arch="yi-9b-smoke", seq_len=32,
                   global_batch=8, chunks=2)
SMALL_SERVE = dict(kind="serve", arch="yi-9b-smoke", prompt_len=16,
                   global_batch=4, tokens_per_step=4)
