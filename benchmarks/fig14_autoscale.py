"""Fig 14 (extension): SLO attainment + replica count vs offered load for
each scaling policy, burst traffic.

Part A replays an open-loop burst trace through the ``ServingSimulator``
with the autoscaler in the loop (virtual clock, seconds-scale horizons).
Part B runs the same control loop against the *live* cluster: the
orchestrator's reconcile thread reads the canonical service signals and
scales a real serving task out/in through node agents -> CRI replicate /
remove.  Both planes emit through ``repro.scaling.metrics`` — the derived
column proves the schema parity the autoscaler depends on.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import TaskImage, make_cluster
from repro.core.simulator import ServingParams, ServingSimulator
from repro.scaling import (Autoscaler, LatencySLOPolicy, OrchestratorScaler,
                           QueueLengthPolicy, TargetUtilizationPolicy,
                           burst_rate, drive_open_loop, open_loop,
                           teardown_service, wait_for_service)

SLO_S = 1.0
MEAN_SERVICE_S = 0.25
HORIZON_S = 120.0
BASE_RATE = 3.0          # req/s outside the burst


def _autoscaler(policy):
    return Autoscaler(policy, min_replicas=1, max_replicas=12,
                      scale_down_cooldown_s=5.0)


def sim_sweep():
    results = {}
    for load_mult in (1.0, 2.0, 4.0):
        reqs = open_loop(
            burst_rate(BASE_RATE * load_mult, 6.0, 40.0, 40.0), HORIZON_S,
            seed=14, mean_service_s=MEAN_SERVICE_S)
        params = ServingParams(slo_latency_s=SLO_S)
        runs = {
            "fixed-2": ServingSimulator(reqs, initial_replicas=2,
                                        params=params),
            "target-util": ServingSimulator(
                reqs, autoscaler=_autoscaler(TargetUtilizationPolicy(0.6)),
                initial_replicas=2, params=params),
            "queue-len": ServingSimulator(
                reqs, autoscaler=_autoscaler(QueueLengthPolicy(2.0)),
                initial_replicas=2, params=params),
            "latency-slo": ServingSimulator(
                reqs, autoscaler=_autoscaler(LatencySLOPolicy(SLO_S)),
                initial_replicas=2, params=params),
        }
        for name, sim in runs.items():
            r = sim.run()
            results[(name, load_mult)] = r
            emit(f"fig14/sim/{name}@{load_mult:g}x",
                 r["mean_latency_s"] * 1e6,
                 f"slo={r['slo_attainment']:.3f} "
                 f"p95={r['p95_latency_s']:.2f}s "
                 f"mean_rep={r['mean_replicas']:.1f} "
                 f"max_rep={r['max_replicas']:.0f}")
        if (results[("latency-slo", load_mult)]["slo_attainment"]
                <= results[("fixed-2", load_mult)]["slo_attainment"]):
            raise SystemExit(
                f"latency-SLO policy did not beat the fixed baseline "
                f"at {load_mult}x")
    return results


# ---------------------------------------------------------------------------
# Live plane: real replicate/remove through the orchestrator
# ---------------------------------------------------------------------------
LIVE_IMAGE = TaskImage(name="svc", kind="serve", arch="yi-9b-smoke",
                       prompt_len=16, global_batch=2, total_steps=100000,
                       tokens_per_step=2)


def live_run(duration_s: float = 9.0, service_rate: float = 40.0):
    """Drive a compressed burst against a live cluster; the orchestrator's
    autoscaler thread scales the service through the node agents.

    The shared ``repro.scaling.serving`` driver models request termination
    (``service_rate`` req/s per RUNNING replica) while every scaling action
    is the real paper machinery: checkpoint-clone replicate onto a node
    with free vSlices, kill+delete on scale-in.
    """
    cluster = make_cluster(num_nodes=4, slices_per_node=1,
                           images={"svc": LIVE_IMAGE})
    orch = cluster.orchestrator

    cid = orch.submit("svc", priority=5)
    orch.start(tick_interval=0.02)
    wait_for_service(cluster, orch, cid)

    scaler = OrchestratorScaler(orch, cid, service="svc")
    asc = Autoscaler(LatencySLOPolicy(slo_p95_s=0.6, growth=2.0),
                     min_replicas=1, max_replicas=4,
                     scale_down_cooldown_s=2.0)
    orch.attach_autoscaler(asc, scaler, service="svc", interval_s=0.2)

    # compressed burst: 6x the sustainable single-replica rate mid-run
    reqs = open_loop(
        burst_rate(0.6 * service_rate, 6.0, duration_s / 3, duration_s / 3),
        duration_s, seed=41, mean_service_s=1.0 / service_rate)
    res = drive_open_loop(orch, scaler, reqs, duration_s=duration_s,
                          service_rate=service_rate, slo_s=SLO_S,
                          service="svc")

    teardown_service(orch, scaler)
    scaled_out = any(e[1] == "replicate" for e in orch.events)
    scaled_in = any(e[1] == "scale_in" for e in orch.events)
    emit("fig14/live/latency-slo", 0.0,
         f"slo={res.attainment:.3f} served={res.served} "
         f"max_rep={res.max_replicas} scaled_out={scaled_out} "
         f"scaled_in={scaled_in}")
    return orch.metrics.snapshot(), scaled_out


def main():
    results = sim_sweep()
    live_snap, scaled_out = live_run()

    # schema parity: the signals the autoscaler reads exist, with identical
    # names, in both planes' snapshots
    sim = ServingSimulator(
        open_loop(burst_rate(3.0, 4.0, 5.0, 5.0), 15.0, seed=2,
                  mean_service_s=0.2),
        autoscaler=_autoscaler(LatencySLOPolicy(SLO_S)), initial_replicas=1)
    sim.run()
    sim_snap = sim.metrics.snapshot()
    want = {"requests_total{service=svc}",
            "completions_total{service=svc}"}
    shared_counters = (set(sim_snap["counters"])
                       & set(live_snap["counters"]))
    shared_hists = (set(sim_snap["histograms"])
                    & set(live_snap["histograms"]))
    assert want <= shared_counters, shared_counters
    assert "request_latency_seconds{service=svc}" in shared_hists
    emit("fig14/schema-parity", 0.0,
         f"shared_counters={len(shared_counters)} "
         f"shared_hists={len(shared_hists)} live_scaled_out={scaled_out}")


if __name__ == "__main__":
    main()
