"""Fig 14 (extension): SLO attainment + replica count vs offered load for
each scaling policy, burst traffic.

Part A replays an open-loop burst trace through the ``ServingSimulator``
with the autoscaler in the loop (virtual clock, seconds-scale horizons);
request service times come from an **engine calibration** — a short live
run of the continuous-batching engine whose measured TTFT/TBT medians
parameterize ``engine_service_model`` (shape from the device, operating
point pinned to MEAN_SERVICE_S for comparability across machines).
Part B runs the same control loop against the *live* cluster on the
per-request path: engine replicas pull from the service router and
terminate requests on-device, while the orchestrator's reconcile thread
reads the canonical service signals and scales the service out/in through
node agents -> CRI replicate / remove.  Both planes emit through
``repro.scaling.metrics`` — the derived column proves the schema parity
the autoscaler depends on.
"""

from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.core import FunkyCL, Monitor, SliceAllocator, TaskImage, \
    make_cluster
from repro.obs import Tracer, export_chrome_trace
from repro.core.simulator import (ServingParams, ServingSimulator,
                                  engine_service_model)
from repro.scaling import (Autoscaler, ClosedLoopGen, LatencySLOPolicy,
                           OrchestratorScaler, QueueLengthPolicy,
                           TargetUtilizationPolicy, burst_rate,
                           drive_engine_open_loop, open_loop, reset_router,
                           teardown_service, wait_for_service)
from repro.scaling.metrics import MetricsRegistry
from repro.serve.engine import (M_TBT, M_TTFT, ContinuousBatchingEngine,
                                ServeRequest)

SLO_S = 1.0
MEAN_SERVICE_S = 0.25
HORIZON_S = 120.0
BASE_RATE = 3.0          # req/s outside the burst
TOKENS_RANGE = (4, 13)   # ragged generation lengths
ARCH = "yi-9b-smoke"


def _autoscaler(policy):
    return Autoscaler(policy, min_replicas=1, max_replicas=12,
                      scale_down_cooldown_s=5.0)


def engine_calibration(n_requests: int = 6):
    """Short live engine run; returns (median ttft_s, median tbt_s).

    Requests run one at a time so TTFT measures the un-queued admission
    cost (prefill + scatter) rather than batch-arrival queueing — the
    service-*demand* decomposition the simulator's model needs."""
    reg = MetricsRegistry()
    mon = Monitor("fig14-calib", SliceAllocator("calib0", 1), telemetry=reg)
    eng = ContinuousBatchingEngine(ARCH, FunkyCL(mon), slots=4,
                                   prompt_len=8,
                                   max_new_tokens=TOKENS_RANGE[1],
                                   registry=reg)
    eng.setup()
    rng = np.random.Generator(np.random.Philox(3))
    for i in range(n_requests):
        eng.submit(ServeRequest(rid=f"c{i}", prompt=rng.integers(0, 256, 8),
                                max_new_tokens=int(
                                    rng.integers(*TOKENS_RANGE))))
        eng.run_until_drained()
    mon.vfpga_exit()
    ttft = reg.histogram(M_TTFT, service="svc").quantile(0.5)
    tbt = reg.histogram(M_TBT, service="svc").quantile(0.5)
    emit("fig14/calibration", ttft * 1e6,
         f"ttft={ttft * 1e3:.1f}ms tbt={tbt * 1e3:.2f}ms")
    return ttft, tbt


def sim_sweep(ttft_s: float, tbt_s: float):
    # engine-measured latency *shape*, normalized so the mean service time
    # sits at the figure's canonical operating point regardless of how
    # fast the calibration host happens to be
    mean_n = (TOKENS_RANGE[0] + TOKENS_RANGE[1] - 1) / 2.0
    raw_mean = ttft_s + (mean_n - 1) * tbt_s
    scale = MEAN_SERVICE_S / raw_mean
    service_time_fn = engine_service_model(
        ttft_s * scale, tbt_s * scale,
        default_tokens=int(mean_n))
    results = {}
    for load_mult in (1.0, 2.0, 4.0):
        reqs = open_loop(
            burst_rate(BASE_RATE * load_mult, 6.0, 40.0, 40.0), HORIZON_S,
            seed=14, mean_service_s=MEAN_SERVICE_S,
            tokens_range=TOKENS_RANGE)
        params = ServingParams(slo_latency_s=SLO_S)

        def sim(**kw):
            return ServingSimulator(reqs, params=params,
                                    service_time_fn=service_time_fn, **kw)

        runs = {
            "fixed-2": sim(initial_replicas=2),
            "target-util": sim(
                autoscaler=_autoscaler(TargetUtilizationPolicy(0.6)),
                initial_replicas=2),
            "queue-len": sim(
                autoscaler=_autoscaler(QueueLengthPolicy(2.0)),
                initial_replicas=2),
            "latency-slo": sim(
                autoscaler=_autoscaler(LatencySLOPolicy(SLO_S)),
                initial_replicas=2),
        }
        for name, sim in runs.items():
            r = sim.run()
            results[(name, load_mult)] = r
            emit(f"fig14/sim/{name}@{load_mult:g}x",
                 r["mean_latency_s"] * 1e6,
                 f"slo={r['slo_attainment']:.3f} "
                 f"p95={r['p95_latency_s']:.2f}s "
                 f"mean_rep={r['mean_replicas']:.1f} "
                 f"max_rep={r['max_replicas']:.0f}")
        if (results[("latency-slo", load_mult)]["slo_attainment"]
                <= results[("fixed-2", load_mult)]["slo_attainment"]):
            raise SystemExit(
                f"latency-SLO policy did not beat the fixed baseline "
                f"at {load_mult}x")
    return results


def closed_loop_sweep(ttft_s: float, tbt_s: float):
    """Closed-loop think-time arm: N clients each wait ``think_time_s``
    after a completion before issuing again, so offered load *adapts* to
    the system (overload shows up as client backpressure, not an unbounded
    queue).  SLO attainment alone is therefore misleading here — a slow
    fixed deployment quietly throttles its own clients — so the honest
    closed-loop comparison is throughput *and* latency: the autoscaled run
    must complete at least as many requests with a lower mean latency."""
    mean_n = (TOKENS_RANGE[0] + TOKENS_RANGE[1] - 1) / 2.0
    raw_mean = ttft_s + (mean_n - 1) * tbt_s
    scale = MEAN_SERVICE_S / raw_mean
    service_time_fn = engine_service_model(ttft_s * scale, tbt_s * scale,
                                           default_tokens=int(mean_n))
    results = {}
    for n_clients in (8, 24):
        def run(autoscaler=None):
            gen = ClosedLoopGen(n_clients=n_clients, think_time_s=0.5,
                                mean_service_s=MEAN_SERVICE_S,
                                horizon_s=60.0, seed=17,
                                tokens_range=TOKENS_RANGE)
            sim = ServingSimulator(
                gen.initial(), closed_gen=gen, autoscaler=autoscaler,
                initial_replicas=2,
                params=ServingParams(slo_latency_s=SLO_S),
                service_time_fn=service_time_fn)
            rep = sim.run()
            assert rep["completed"] == gen.issued, \
                (rep["completed"], gen.issued)   # closed loop conserves
            return rep

        fixed = run()
        elastic = run(_autoscaler(QueueLengthPolicy(1.0)))
        results[n_clients] = (fixed, elastic)
        for name, r in (("fixed-2", fixed), ("queue-len", elastic)):
            emit(f"fig14/closed/{name}@{n_clients}c",
                 r["mean_latency_s"] * 1e6,
                 f"slo={r['slo_attainment']:.3f} "
                 f"p95={r['p95_latency_s']:.2f}s "
                 f"served={r['completed']} "
                 f"mean_rep={r['mean_replicas']:.1f}")
        if (elastic["completed"] < fixed["completed"]
                or elastic["mean_latency_s"] >= fixed["mean_latency_s"]):
            raise SystemExit(
                f"closed-loop queue-len policy did not beat the fixed "
                f"baseline at {n_clients} clients (served "
                f"{elastic['completed']} vs {fixed['completed']}, mean "
                f"{elastic['mean_latency_s']:.3f}s vs "
                f"{fixed['mean_latency_s']:.3f}s)")
    return results


# ---------------------------------------------------------------------------
# Live plane: per-request engine serving, real replicate/remove
# ---------------------------------------------------------------------------
LIVE_SLOTS = 4
LIVE_IMAGE = TaskImage(name="svc", kind="engine-serve", arch=ARCH,
                       prompt_len=8, global_batch=LIVE_SLOTS,
                       total_steps=10 ** 9, max_new_tokens=TOKENS_RANGE[1])


def live_run(ttft_s: float, tbt_s: float, duration_s: float = 9.0,
             trace_out: str = None):
    """Drive a compressed burst against a live cluster on the per-request
    path: engine replicas pull from the service router and terminate
    requests on-device, and the orchestrator's autoscaler thread scales
    the service through the node agents (checkpoint-clone replicate onto a
    node with free vSlices, kill+delete on scale-in).  SLO attainment is
    computed from engine-reported end-to-end latencies.

    A tracer rides along: orchestration actions (place / replicate /
    scale-in drain / failure restore) land in one ``cluster`` trace, so
    ``--trace-out`` yields a Perfetto-loadable timeline of the control
    loop next to the per-request spans."""
    tracer = Tracer(clock=time.perf_counter, capacity=2048,
                    sample_rate=1.0, keep_slowest=16)
    cluster = make_cluster(num_nodes=4, slices_per_node=1,
                           images={"svc": LIVE_IMAGE}, tracer=tracer)
    orch = cluster.orchestrator
    router = reset_router("svc")
    router.registry = orch.metrics
    router.tracer = tracer

    cid = orch.submit("svc", priority=5)
    orch.start(tick_interval=0.02)
    wait_for_service(cluster, orch, cid)

    scaler = OrchestratorScaler(orch, cid, service="svc")
    asc = Autoscaler(LatencySLOPolicy(slo_p95_s=0.6, growth=2.0),
                     min_replicas=1, max_replicas=4,
                     scale_down_cooldown_s=2.0)
    orch.attach_autoscaler(asc, scaler, service="svc", interval_s=0.2)

    # offered load from the calibration: ~30% of one replica's measured
    # token throughput outside the burst, 4x that mid-run (the replicas
    # share one physical device here, so sustained heavy overload would
    # only measure the backlog, not the control loop)
    mean_n = (TOKENS_RANGE[0] + TOKENS_RANGE[1] - 1) / 2.0
    replica_rate = LIVE_SLOTS / (ttft_s + (mean_n - 1) * tbt_s)
    reqs = open_loop(
        burst_rate(0.3 * replica_rate, 4.0, duration_s / 3, duration_s / 3),
        duration_s, seed=41, mean_service_s=1.0 / replica_rate,
        tokens_range=TOKENS_RANGE)
    res = drive_engine_open_loop(
        orch, scaler, reqs, duration_s=duration_s, slo_s=SLO_S,
        service="svc", prompt_len=LIVE_IMAGE.prompt_len,
        slots_per_replica=LIVE_SLOTS)

    teardown_service(orch, scaler)
    scaled_out = any(e[1] == "replicate" for e in orch.events)
    scaled_in = any(e[1] == "scale_in" for e in orch.events)
    emit("fig14/live/latency-slo", 0.0,
         f"slo={res.attainment:.3f} served={res.served} "
         f"max_rep={res.max_replicas} scaled_out={scaled_out} "
         f"scaled_in={scaled_in}")
    cluster_tr = tracer.find("cluster")
    assert cluster_tr is not None and len(cluster_tr.spans()) > 1, \
        "orchestrator emitted no action spans"
    if trace_out:
        export_chrome_trace(tracer, trace_out)
        emit("fig14/trace", 0.0,
             f"path={trace_out} cluster_spans={len(cluster_tr.spans())}")
    return orch.metrics.snapshot(), scaled_out


def main(trace_out: str = None):
    ttft_s, tbt_s = engine_calibration()
    results = sim_sweep(ttft_s, tbt_s)
    closed_loop_sweep(ttft_s, tbt_s)
    live_snap, scaled_out = live_run(ttft_s, tbt_s, trace_out=trace_out)

    # schema parity: the signals the autoscaler reads exist, with identical
    # names, in both planes' snapshots
    sim = ServingSimulator(
        open_loop(burst_rate(3.0, 4.0, 5.0, 5.0), 15.0, seed=2,
                  mean_service_s=0.2),
        autoscaler=_autoscaler(LatencySLOPolicy(SLO_S)), initial_replicas=1)
    sim.run()
    sim_snap = sim.metrics.snapshot()
    want = {"requests_total{service=svc}",
            "completions_total{service=svc}"}
    shared_counters = (set(sim_snap["counters"])
                       & set(live_snap["counters"]))
    shared_hists = (set(sim_snap["histograms"])
                    & set(live_snap["histograms"]))
    assert want <= shared_counters, shared_counters
    assert "request_latency_seconds{service=svc}" in shared_hists
    emit("fig14/schema-parity", 0.0,
         f"shared_counters={len(shared_counters)} "
         f"shared_hists={len(shared_hists)} live_scaled_out={scaled_out}")


if __name__ == "__main__":
    argv = sys.argv[1:]
    main(trace_out=(argv[argv.index("--trace-out") + 1]
                    if "--trace-out" in argv else None))
