"""Fig 9: FPGA-synchronization wait vs request splitting (the paper's key
state-management optimization, §3.4).

One logical optimizer step over a fixed global batch is executed as k
chunked EXECUTE requests (gradient accumulation).  A preemption request
arriving right after dispatch must wait for the in-flight work: we measure
that sync wait and the total step time for k = 1..16.  Paper: 32 chunks cut
96.9 % of the wait at <0.1 % throughput cost.
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import TaskImage, TaskStatus, make_cluster
from repro.train import OptConfig

BATCH = 64
STEPS = 6


def _measure(chunks: int):
    image = TaskImage(
        name="i", kind="train", arch="yi-9b-smoke", seq_len=128,
        global_batch=BATCH, total_steps=STEPS, chunks=chunks,
        opt=OptConfig(warmup_steps=1, decay_steps=50))
    cl = make_cluster(num_nodes=1, slices_per_node=1, images={"i": image})
    rt = cl.nodes["node0"].runtime
    rt.create("t", image)
    t0 = time.perf_counter()
    rt.start("t")
    rec = rt.tasks["t"]
    # wait until steady state, then preempt mid-step
    while rec.guest_state.step < 1 and rec.status != TaskStatus.FAILED:
        time.sleep(0.001)
    time.sleep(0.05)        # land inside a dispatched logical step
    t_ev = time.perf_counter()
    ev = rt.evict("t")
    # preemption latency = park at the chunk boundary + queue drain
    wait = (time.perf_counter() - t_ev
            - ev["evict_seconds"] + ev["sync_wait_seconds"])
    rt.resume("t")
    assert rt.wait("t", timeout=3600) == TaskStatus.DONE, rec.error
    total = time.perf_counter() - t0
    return max(wait, 1e-6), total


def main():
    base_wait = None
    base_total = None
    for chunks in (1, 2, 4, 8, 16):
        wait, total = _measure(chunks)
        if chunks == 1:
            base_wait, base_total = wait, total
        cut = (1 - wait / base_wait) * 100 if base_wait else 0.0
        ovh = (total / base_total - 1) * 100 if base_total else 0.0
        emit(f"fig09/sync_wait_chunks{chunks}", wait * 1e6,
             f"wait cut {cut:.1f}% vs 1 chunk; total overhead {ovh:+.1f}% "
             f"(paper: -96.9% wait, <0.1% cost @32)")


if __name__ == "__main__":
    main()
