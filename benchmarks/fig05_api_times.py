"""Fig 5: per-OpenCL-API overheads — FunkyCL vs the native JAX equivalent.

Paper claim: Funky adds no per-API overhead for FPGA operations; the gap is
setup-time only.  We measure clCreateBuffer / clEnqueueMigrateMemObjects /
clEnqueueKernel / clFinish against device_put / jitted-call / block_until_ready.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import FunkyCL, Monitor, Program, SliceAllocator

N = 1 << 20   # 4 MiB f32 buffer


def main():
    alloc = SliceAllocator("n0", 1)
    m = Monitor("bench", alloc)
    spec = jax.ShapeDtypeStruct((N,), jnp.float32)
    prog = Program("axpy", lambda x: x * 1.0001 + 0.5)
    m.vfpga_init(prog, (spec,))
    cl = FunkyCL(m)
    host = np.ones(N, np.float32)

    # --- native equivalents -----------------------------------------------
    jf = jax.jit(prog.fn)
    dev = jax.device_put(host)
    jf(dev)  # warm
    t_put = time_fn(lambda: jax.device_put(host).block_until_ready())
    t_call = time_fn(lambda: jf(dev).block_until_ready())

    # --- FunkyCL ------------------------------------------------------------
    cl.clCreateBuffer("x", spec)
    t_write = time_fn(lambda: (cl.write_buffer("x", host), cl.clFinish()))
    t_kernel = time_fn(lambda: (cl.clEnqueueKernel("axpy", ("x",), ("x",)),
                                cl.clFinish()))
    t_finish = time_fn(cl.clFinish)

    def mkbuf(i=[0]):
        i[0] += 1
        cl.clCreateBuffer(f"b{i[0]}", jax.ShapeDtypeStruct((16,), jnp.float32))
        cl.clFinish()

    t_create = time_fn(mkbuf)

    emit("fig05/clCreateBuffer", t_create * 1e6, "registration only")
    emit("fig05/clEnqueueMigrate_h2d_4MiB", t_write * 1e6,
         f"native device_put {t_put * 1e6:.0f}us; "
         f"gap {(t_write - t_put) * 1e6:+.0f}us")
    emit("fig05/clEnqueueKernel_4MiB", t_kernel * 1e6,
         f"native jit call {t_call * 1e6:.0f}us; "
         f"gap {(t_kernel - t_call) * 1e6:+.0f}us")
    emit("fig05/clFinish_noop", t_finish * 1e6, "sync round-trip")
    m.vfpga_exit()


if __name__ == "__main__":
    main()
