"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig09 fig13  # subset
"""

from __future__ import annotations

import importlib
import os
import sys
import time
import traceback

MODULES = [
    "fig04_virt_overhead",
    "fig05_api_times",
    "fig06_setup",
    "table4_portability",
    "fig07_evict_resume",
    "fig08_migrate_ckpt",
    "fig09_sync_split",
    "fig10_preemption",
    "fig11_scalability",
    "fig12_fault_tolerance",
    "fig13_sched_policies",
    "fig14_autoscale",
    "fig15_serving",
]


def main() -> None:
    wanted = sys.argv[1:]
    mods = [m for m in MODULES
            if not wanted or any(w in m for w in wanted)]
    print("name,us_per_call,derived")
    failures = []
    for name in mods:
        t0 = time.perf_counter()
        print(f"# --- {name} ---", flush=True)
        try:
            importlib.import_module(f"benchmarks.{name}").main()
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            traceback.print_exc()
        print(f"# {name} took {time.perf_counter() - t0:.1f}s", flush=True)
    if failures:
        for name, e in failures:
            print(f"# FAILED {name}: {e}")
        raise SystemExit(1)
    sys.stdout.flush()
    # live-cluster benchmarks (fig10/12/14) leave XLA worker threads from
    # killed guest tasks behind; they can abort CPython teardown, so
    # hard-exit once every row is emitted.
    os._exit(0)


if __name__ == '__main__':
    main()
