"""Table 4: portability — deployable-image sizes and API-surface effort.

Paper: Funky unikernel OCI images average 39.6 MiB vs 1138 MiB for the
vendor container (28.7x).  Analogue here: a Funky task bundle = compiled
program artifact + task config + the repro runtime package, vs the "vendor
container" = the full JAX/XLA site-packages footprint the task would
otherwise ship.  Also reports the guest-code porting surface: lines of the
guest tasks that touch FunkyCL (the paper's 3.4 % code-diff claim analogue).
"""

from __future__ import annotations

import os
import pickle

import jax
import jax.numpy as jnp

from benchmarks.common import emit


def _tree_size(root: str, exts=None) -> int:
    total = 0
    for dirpath, _, files in os.walk(root):
        for f in files:
            if exts and not any(f.endswith(e) for e in exts):
                continue
            try:
                total += os.path.getsize(os.path.join(dirpath, f))
            except OSError:
                pass
    return total


def main():
    # --- Funky bundle: program artifact + config + runtime lib --------------
    from repro.configs import get_arch
    from repro.core import TaskImage
    from repro.models import build_model

    cfg = get_arch("yi-9b-smoke")
    bundle = build_model(cfg)
    batch = {"tokens": jnp.zeros((4, 32), jnp.int32),
             "targets": jnp.zeros((4, 32), jnp.int32)}
    lowered = jax.jit(lambda p, b: bundle.loss_fn(p, b)[0]).lower(
        jax.eval_shape(bundle.init, jax.random.PRNGKey(0)), batch)
    hlo_bytes = len(lowered.as_text().encode())
    image_bytes = len(pickle.dumps(TaskImage(name="x", kind="train")))
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    runtime_bytes = _tree_size(os.path.join(here, "src", "repro"),
                               exts=(".py",))
    funky_total = hlo_bytes + image_bytes + runtime_bytes

    # --- "vendor container": full framework footprint -------------------------
    site = os.path.dirname(os.path.dirname(jax.__file__))
    vendor = 0
    for pkg in ("jax", "jaxlib", "numpy", "scipy", "ml_dtypes"):
        p = os.path.join(site, pkg)
        if os.path.isdir(p):
            vendor += _tree_size(p)
    ratio = vendor / funky_total

    emit("table4/funky_bundle_bytes", 0,
         f"{funky_total / 2**20:.1f} MiB (program {hlo_bytes / 2**20:.2f} + "
         f"runtime {runtime_bytes / 2**20:.2f})")
    emit("table4/vendor_stack_bytes", 0, f"{vendor / 2**20:.1f} MiB")
    emit("table4/image_size_ratio", 0,
         f"{ratio:.1f}x smaller (paper: 28.7x)")

    # --- porting surface ----------------------------------------------------
    tasks_py = os.path.join(here, "src", "repro", "core", "tasks.py")
    lines = open(tasks_py).read().splitlines()
    code = [l for l in lines if l.strip() and not l.strip().startswith("#")]
    api = [l for l in code if "cl." in l]
    emit("table4/guest_api_loc", 0,
         f"{len(api)}/{len(code)} lines touch FunkyCL "
         f"({len(api) / len(code) * 100:.1f}%; paper diff: 3.4%)")


if __name__ == "__main__":
    main()
