"""Fig 15 (extension): continuous-batching engine vs the naive sequential
``generate`` loop, and paged vs worst-case-reserved KV memory.

Part 1 — serving discipline.  Both servers face the *same* arrival schedule
(a quick burst of requests with ragged generation lengths) on the same
smoke model:

* **naive** — the ``repro.serve.generate`` loop, FIFO, one request at a
  time, batch 1, jitted directly (no monitor in the way — this *favors*
  the baseline).  It is non-streaming: a request's tokens are delivered
  only when its loop finishes, so the client-observed time between tokens
  is ``(finish - arrival) / n_tokens`` — head-of-line queueing included.
* **engine** — ``repro.serve.engine.ContinuousBatchingEngine`` dispatching
  every iteration through a Funky monitor (EXECUTE per step, preemptible
  at token boundaries).  Tokens stream at iteration granularity; TBT is
  the measured inter-token gap from the shared metrics registry.

Part 2 — memory discipline.  Two engines get the *same KV pool byte
budget* (the paged pool is rounded down, never up):

* **reserved** — every lane owns a worst-case ``prompt_len +
  max_new_tokens`` stripe, so the budget caps the lane count;
* **paged** — twice the lanes over a block-table pool of equal bytes;
  lanes hold pages at token granularity and free them at retirement.

Part 3 — speculative decode.  A third engine runs the same workload with a
self-draft ``SpecConfig`` (draft == target, the forced-accept ceiling):
each iteration drafts k tokens and verifies k+1 in a single vmapped
EXECUTE.  The run asserts >1 accepted tokens per lane-iteration AND a
token stream bit-exact vs the plain engine arm (the equivalence-harness
contract: speculation is a throughput mechanism, never a token change).

The run asserts the engine beats the baseline on throughput and p99 TBT,
and that the paged engine sustains strictly more concurrent in-flight
requests than the reservation baseline at the same pool size (the §3.4
virtualization payoff the ROADMAP names) while completing the identical
workload.

Part 4 — disaggregation.  A prefill + decode replica pair joined by a
live KV handoff (``repro.serve.disagg.TransferQueue``) faces two mixed
replicas at equal total slices over a near-saturated burst: the run
asserts bit-exact token streams, strictly lower p99 TBT (gateable with
``--tbt-budget-us``), and that a squeezed decode pool degrades to
aggregated fallback (``handoff_fallback_total > 0``) instead of
queueing transfers past the TTFT target.

The plain engine arm runs with a ``repro.obs.Tracer`` attached: the run
also reports the host-vs-device µs/token split (``fig15/host_split``)
and, with ``--trace-out PATH``, exports a Perfetto-loadable Chrome-trace
JSON of every request's router -> engine -> monitor span tree
(``tools/trace_dump.py`` summarizes / validates it).

    PYTHONPATH=src python -m benchmarks.fig15_serving [--smoke] \
        [--trace-out trace.json]
"""

from __future__ import annotations

import gc
import sys
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_arch
from repro.scaling.autoscaler import M_PREFIX_HIT_RATE
from repro.core import FunkyCL, Monitor, SliceAllocator
from repro.models import build_model
from repro.obs import Tracer, export_chrome_trace
from repro.scaling.metrics import MetricsRegistry
from repro.scaling.serving import RequestRouter
from repro.serve import generate
from repro.serve.disagg import (M_HANDOFF, M_HANDOFF_FALLBACK,
                                TransferQueue)
from repro.serve.engine import (M_TBT, M_TTFT, ContinuousBatchingEngine,
                                ServeRequest, SpecConfig)
from repro.serve.equivalence import assert_transcripts_equal

ARCH = "yi-9b-smoke"
PAGE_SIZE = 4


def make_workload(n_requests: int, prompt_len: int, tokens_range: tuple,
                  arrival_gap_s: float, seed: int = 7):
    """Ragged burst: ~Poisson arrivals, uniform-ragged generation lengths."""
    rng = np.random.Generator(np.random.Philox(seed))
    t = 0.0
    out = []
    for i in range(n_requests):
        t += float(rng.exponential(arrival_gap_s))
        out.append({
            "rid": f"req-{i:03d}", "arrival_t": t,
            "prompt": rng.integers(0, 256, prompt_len).astype(np.int32),
            "n_tokens": int(rng.integers(*tokens_range)),
        })
    return out


def run_naive(bundle, params, workload, prompt_len):
    """Sequential FIFO server; returns per-request (ttft, eff_tbt, n) and
    the busy-window wall seconds."""
    # warm the jit cache outside the timed window (steady-state serving)
    warm = {"tokens": np.zeros((1, prompt_len), np.int32)}
    jax.block_until_ready(generate(bundle, params, warm, 2))
    gc.collect()
    gc.disable()        # no collector pauses inside the latency window
    try:
        t0 = time.perf_counter()
        results = []
        for w in workload:
            now = time.perf_counter() - t0
            if now < w["arrival_t"]:
                time.sleep(w["arrival_t"] - now)
            toks = generate(bundle, params,
                            {"tokens": w["prompt"].reshape(1, -1)},
                            w["n_tokens"])
            jax.block_until_ready(toks)
            finish = time.perf_counter() - t0
            latency = finish - w["arrival_t"]
            results.append({"rid": w["rid"], "ttft": latency,  # 1st token
                            "eff_tbt": latency / w["n_tokens"],
                            "n": w["n_tokens"], "finish": finish})
    finally:
        gc.enable()
    busy_s = max(r["finish"] for r in results) - workload[0]["arrival_t"]
    return results, busy_s


def run_engine(workload, prompt_len, slots, max_new_cap, *, paged=True,
               pool_pages=None, spec=None, prefix_cache=False,
               fuse_steps=1, async_depth=0, legacy=False,
               tag="fig15-engine", tracer=None):
    """Continuous-batching server through a real monitor; returns the
    engine (peak_active/preemptions/completed), the registry, and the
    busy-window seconds.  Requests flow router -> engine.pump so a tracer
    (if given) sees the full router.queue -> engine -> monitor chain.

    ``legacy=True`` recreates the pre-fused host discipline — staged
    4-op admission and a full host-mirror h2d write on every dirty
    block-table flush — so the host-overhead comparison has a measured
    same-machine baseline instead of a stale constant."""
    # perf_counter clock so request arrival_t and engine timestamps share
    # one monotonic timebase
    reg = MetricsRegistry(clock=time.perf_counter)
    alloc = SliceAllocator("bench0", 1)
    mon = Monitor(tag, alloc, telemetry=reg, tracer=tracer)
    eng = ContinuousBatchingEngine(ARCH, FunkyCL(mon), slots=slots,
                                   prompt_len=prompt_len,
                                   max_new_tokens=max_new_cap, registry=reg,
                                   paged=paged, page_size=PAGE_SIZE,
                                   pool_pages=pool_pages, spec=spec,
                                   prefix_cache=prefix_cache,
                                   fuse_steps=fuse_steps,
                                   async_depth=async_depth)
    if legacy:
        eng._legacy_admit = True    # staged 4-op admission (pre-fusion)
    eng.setup()        # compiles outside the timed window, like the baseline
    if legacy:
        eng._bt_delta_width = 0     # every dirty flush -> full h2d write
    # one throwaway request warms the full admit/append/decode path (the
    # naive baseline gets the same steady-state treatment above)
    eng.submit(ServeRequest(rid="__warm__", prompt=np.zeros(
        prompt_len, np.int32), max_new_tokens=PAGE_SIZE + 2))
    eng.run_until_drained()
    eng.completed.pop("__warm__")
    eng.drain_completions()
    eng.peak_active = 0
    # the warmup request ran the full (spec) path: restart the stats so
    # the emitted line covers only the timed window
    eng.spec_iterations = eng.spec_lane_iterations = 0
    eng.spec_committed = 0
    eng.spec_offered_drafts = eng.spec_accepted_drafts = 0
    # ... and the prefix-cache accounting (the warmup's miss would skew
    # the emitted hit rate); its tree pages stay and are evicted LRU
    # under admission pressure like any other cold entry
    eng.prefix_hits = eng.prefix_partial_hits = eng.prefix_misses = 0
    eng.prefix_prompt_tokens = eng.prefix_cached_tokens = 0
    gc.collect()
    gc.disable()        # no collector pauses inside the latency window
    # the router is the service frontend: arrivals land there and the
    # engine pulls via pump(), same as the fig14 replica drive loop
    router = RequestRouter("svc", registry=reg, kv_aware=False,
                           tracer=tracer)
    try:
        t0 = time.perf_counter()
        pending = list(workload)
        while pending or not eng.idle or router.outstanding():
            now = time.perf_counter() - t0
            while pending and pending[0]["arrival_t"] <= now:
                w = pending.pop(0)
                router.submit(ServeRequest(
                    rid=w["rid"], prompt=w["prompt"],
                    max_new_tokens=w["n_tokens"],
                    arrival_t=t0 + w["arrival_t"]))   # registry clock basis
            if not eng.pump(router):
                time.sleep(0.001)
        busy_s = (time.perf_counter() - t0) - workload[0]["arrival_t"]
    finally:
        gc.enable()
    mon.vfpga_exit()
    return eng, reg, busy_s


def run_pair(workload, prompt_len, slots, max_new_cap, *, disagg,
             decode_pool_pages=None, decode_reserve_pages=None,
             ttft_target_s=None, pf_slots=None, tag="fig15-pair"):
    """Two replicas behind one router at *equal total slices* (and equal
    total lanes): either two mixed engines (the aggregated baseline) or a
    prefill + decode pair joined by a live-KV TransferQueue.  Every
    decoding engine in both arms runs the same fused decode discipline,
    so the variables are exactly the role levers: the lane budget is
    split role-aware (the prefill replica takes few lanes — its prompt
    EXECUTEs stay small and it holds few fallback decodes — and the
    decode replica takes the rest), and the decode replica is pumped at
    token cadence (several pumps per prefill pump: its step quantum is a
    short fused span, the prefill replica's is a whole prompt EXECUTE).
    The mixed replicas are pumped symmetrically — with both roles
    colocated there is no short-quantum replica to favor.
    Returns (router, transfer_queue_or_None, registry, busy_s)."""
    reg = MetricsRegistry(clock=time.perf_counter)
    router = RequestRouter("svc", registry=reg, kv_aware=False)
    roles = ("prefill", "decode") if disagg else ("mixed", "mixed")
    if disagg:
        if pf_slots is None:
            pf_slots = max(1, slots // 4)
        slot_split = (pf_slots, 2 * slots - pf_slots)
    else:
        slot_split = (slots, slots)
    engines = []
    for i, role in enumerate(roles):
        mon = Monitor(f"{tag}-{i}", SliceAllocator(f"bench{i}", 1),
                      telemetry=reg)
        kw = {}
        if role != "prefill":
            kw.update(fuse_steps=4, async_depth=0)
        if role == "decode" and decode_pool_pages is not None:
            kw["pool_pages"] = decode_pool_pages
        if role == "decode" and decode_reserve_pages is not None:
            kw["reserve_pages"] = decode_reserve_pages
        eng = ContinuousBatchingEngine(
            ARCH, FunkyCL(mon), slots=slot_split[i], prompt_len=prompt_len,
            max_new_tokens=max_new_cap, registry=reg, paged=True,
            page_size=PAGE_SIZE, engine_id=f"{tag}-{i}", role=role, **kw)
        eng.setup()
        # warm the full admit/decode path outside the timed window,
        # before the transfer queue exists (so the warmup never exports)
        eng.submit(ServeRequest(rid="__warm__", prompt=np.zeros(
            prompt_len, np.int32), max_new_tokens=PAGE_SIZE + 2))
        eng.run_until_drained()
        eng.completed.pop("__warm__")
        eng.drain_completions()
        eng.peak_active = 0
        engines.append((mon, eng))
    tq = None
    if disagg:
        tq = TransferQueue(router=router, registry=reg, service="svc",
                           ttft_target_s=ttft_target_s)
        for _, eng in engines:
            eng.attach_transfer(tq)
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        pending = list(workload)
        while (pending or router.outstanding() or (tq and len(tq))
               or any(not e.idle for _, e in engines)):
            now = time.perf_counter() - t0
            while pending and pending[0]["arrival_t"] <= now:
                w = pending.pop(0)
                router.submit(ServeRequest(
                    rid=w["rid"], prompt=w["prompt"],
                    max_new_tokens=w["n_tokens"],
                    arrival_t=t0 + w["arrival_t"]))
            progressed = engines[0][1].pump(router)
            for _ in range(3 if disagg else 1):
                progressed = engines[1][1].pump(router) or progressed
            if not progressed:
                time.sleep(0.001)
        busy_s = (time.perf_counter() - t0) - workload[0]["arrival_t"]
    finally:
        gc.enable()
        for mon, _ in engines:
            mon.vfpga_exit()
    return router, tq, reg, busy_s


def p99(values):
    """Interpolated p99, matching the registry's Histogram.quantile."""
    if not values:
        return float("nan")
    return float(np.percentile(np.asarray(values), 99))


def make_prefix_workload(n_requests: int, prompt_len: int,
                         tokens_range: tuple, arrival_gap_s: float,
                         groups: int = 3, seed: int = 11):
    """Common-system-prompt mix: ``groups`` distinct prompts, each repeated
    round-robin — every repeat is a full prefix hit for a sharing engine."""
    rng = np.random.Generator(np.random.Philox(seed))
    prompts = [rng.integers(0, 256, prompt_len).astype(np.int32)
               for _ in range(groups)]
    t = 0.0
    out = []
    for i in range(n_requests):
        t += float(rng.exponential(arrival_gap_s))
        out.append({
            "rid": f"pfx-{i:03d}", "arrival_t": t,
            "prompt": prompts[i % groups], "group": i % groups,
            "n_tokens": int(rng.integers(*tokens_range)),
        })
    return out


def main(smoke: bool = False, trace_out: str = None,
         host_budget_us: float = None, device_budget_us: float = None,
         queue_wait_budget_us: float = None, tbt_budget_us: float = None):
    # max_new_cap is the *server-side* per-request cap the reservation
    # baseline must provision for; actual generations (tokens_range) are
    # ragged and stop well short of it — the gap is what paging reclaims
    if smoke:
        n_req, prompt_len, tokens_range = 12, 8, (2, 13)
        slots, arrival_gap, reserved_slots = 4, 0.005, 1
        max_new_cap = 24
    else:
        n_req, prompt_len, tokens_range = 24, 16, (4, 25)
        slots, arrival_gap, reserved_slots = 8, 0.01, 2
        max_new_cap = 40
    workload = make_workload(n_req, prompt_len, tokens_range, arrival_gap)
    total_tokens = sum(w["n_tokens"] for w in workload)

    cfg = get_arch(ARCH)
    bundle = build_model(cfg, cache_margin=max_new_cap)
    params = bundle.init(jax.random.PRNGKey(0))

    naive, naive_busy = run_naive(bundle, params, workload, prompt_len)
    naive_tps = total_tokens / naive_busy
    naive_p99_tbt = p99([r["eff_tbt"] for r in naive])
    emit("fig15/naive", naive_busy * 1e6 / total_tokens,
         f"tokens_per_s={naive_tps:.1f} "
         f"p99_tbt={naive_p99_tbt * 1e3:.1f}ms "
         f"p99_ttft={p99([r['ttft'] for r in naive]) * 1e3:.1f}ms")

    # the plain engine arm runs traced: every request becomes one span
    # tree (router.queue -> engine.admit -> monitor phases -> decode) and
    # every iteration an engine.step trace with EXECUTE children
    tracer = Tracer(clock=time.perf_counter, capacity=4096,
                    sample_rate=1.0, keep_slowest=16)
    eng, reg, eng_busy = run_engine(workload, prompt_len, slots,
                                    max_new_cap, tracer=tracer)
    assert len(eng.completed) == n_req, (len(eng.completed), n_req)
    eng_tps = total_tokens / eng_busy
    tbts = [t for rec in eng.completed.values() for t in rec.tbts]
    eng_p99_tbt = p99(tbts)
    ttfts = [rec.ttft_s for rec in eng.completed.values()]
    emit("fig15/engine", eng_busy * 1e6 / total_tokens,
         f"tokens_per_s={eng_tps:.1f} p99_tbt={eng_p99_tbt * 1e3:.1f}ms "
         f"p99_ttft={p99(ttfts) * 1e3:.1f}ms slots={slots} "
         f"page={PAGE_SIZE}")

    # per-request latencies must be in the shared registry schema
    # (+1s: the warmup request also reports through the registry)
    snap = reg.snapshot()
    assert (snap["histograms"][f"{M_TTFT}{{service=svc}}"]["count"]
            == n_req + 1)
    assert (snap["histograms"][f"{M_TBT}{{service=svc}}"]["count"]
            >= total_tokens - n_req)
    assert (snap["histograms"]["request_latency_seconds{service=svc}"]
            ["count"] == n_req + 1)

    # ---------------------------------------------------------------
    # Host-overhead split on the paged decode path: where does a token's
    # wall time go?  device_s is attributed per-EXECUTE by the monitor
    # (compiled-run + transfer + sync blocking); the remainder is host
    # orchestration (batch assembly, page tables, python glue).
    # ---------------------------------------------------------------
    split = eng.host_device_split()
    assert split["tokens"] >= total_tokens, (split["tokens"], total_tokens)
    assert split["device_us_per_token"] > 0.0, split
    emit("fig15/host_split", split["host_us_per_token"],
         f"device_us_per_token={split['device_us_per_token']:.1f} "
         f"host_us_per_token={split['host_us_per_token']:.1f} "
         f"queue_wait_us={split['queue_wait_us_mean']:.1f} "
         f"tokens={split['tokens']} execs={split['execs']}")
    # ---------------------------------------------------------------
    # Host-out-of-the-loop arm: k decode steps fused into one EXECUTE
    # with the next iteration's EXECUTE pipelined ahead of token
    # readback, over the same workload and the same pool bytes (the
    # plain arm's page count, passed explicitly because the fused
    # engine's per-lane context headroom is k-1 tokens larger).
    # ---------------------------------------------------------------
    fuse_k, fuse_d = 12, 2
    fused_eng, _, fused_busy = run_engine(
        workload, prompt_len, slots, max_new_cap,
        pool_pages=eng.pool_pages, fuse_steps=fuse_k, async_depth=fuse_d,
        tag="fig15-fused")
    assert len(fused_eng.completed) == n_req
    assert fused_eng.pool_bytes == eng.pool_bytes
    assert_transcripts_equal(
        {rid: rec.tokens for rid, rec in fused_eng.completed.items()},
        {rid: rec.tokens for rid, rec in eng.completed.items()},
        context="fig15 fused vs plain")
    fsplit = fused_eng.host_device_split()
    emit("fig15/host_split_fused", fsplit["host_us_per_token"],
         f"k={fuse_k} async_depth={fuse_d} "
         f"tokens_per_s={total_tokens / fused_busy:.1f} "
         f"device_us_per_token={fsplit['device_us_per_token']:.1f} "
         f"host_us_per_token={fsplit['host_us_per_token']:.1f} "
         f"queue_wait_us={fsplit['queue_wait_us_mean']:.1f} "
         f"execs={fsplit['execs']} "
         f"bt_delta_execs={fused_eng.bt_delta_execs} "
         f"bt_full_writes={fused_eng.bt_full_writes}")

    # ---------------------------------------------------------------
    # Host-cut gate.  The baseline is a *legacy* arm — single-step
    # decode, staged 4-op admission and full block-table h2d writes on
    # every dirty flush: the pre-fusion host discipline — measured on
    # this machine in this run so the comparison tracks the hardware
    # instead of a stale constant.  Both arms run a *saturated* burst
    # (back-to-back arrivals): with every pipeline stage busy, the
    # wall-minus-device split measures host discipline, not idle pump
    # sleeps between sparse arrivals.  Wall-clock ratios on a ~0.5s
    # window still jitter with machine load, so one losing draw gets
    # one retry before the gate fails the run.
    # ---------------------------------------------------------------
    sat = make_workload(16, prompt_len, tokens_range, 0.0002, seed=13)

    def host_cut_attempt(attempt):
        leg, _, _ = run_engine(sat, prompt_len, slots, max_new_cap,
                               pool_pages=eng.pool_pages, legacy=True,
                               tag=f"fig15-legacy-{attempt}")
        fus, _, _ = run_engine(sat, prompt_len, slots, max_new_cap,
                               pool_pages=eng.pool_pages,
                               fuse_steps=fuse_k, async_depth=fuse_d,
                               tag=f"fig15-fused-sat-{attempt}")
        assert len(leg.completed) == len(fus.completed) == len(sat)
        assert leg.pool_bytes == fus.pool_bytes == eng.pool_bytes
        assert_transcripts_equal(
            {rid: rec.tokens for rid, rec in fus.completed.items()},
            {rid: rec.tokens for rid, rec in leg.completed.items()},
            context="fig15 fused vs legacy (saturated)")
        ls, fs = leg.host_device_split(), fus.host_device_split()
        cut = ls["host_us_per_token"] / max(fs["host_us_per_token"], 1e-9)
        emit("fig15/host_cut", cut,
             f"attempt={attempt} "
             f"legacy_host_us={ls['host_us_per_token']:.1f} "
             f"fused_host_us={fs['host_us_per_token']:.1f} "
             f"legacy_execs={ls['execs']} fused_execs={fs['execs']}")
        return cut, ls, fs

    host_cut, lsplit, _ = host_cut_attempt(0)
    if host_cut < 3.0:
        host_cut = max(host_cut, host_cut_attempt(1)[0])
    if host_cut < 3.0:
        raise SystemExit(
            f"fused decode (k={fuse_k}) cut host_us_per_token only "
            f"{host_cut:.2f}x vs the legacy single-step arm "
            f"(legacy {lsplit['host_us_per_token']:.1f}us/token); "
            f"the gate requires >=3x")

    # perf regression gates: host-side orchestration (batch assembly,
    # page/prefix-tree bookkeeping, python glue), attributed device time
    # and per-EXECUTE queue wait must not creep up.  Budgets gate the
    # fused arm — the serving configuration the budgets were set for.
    for name, budget, got in (
            ("--host-budget-us", host_budget_us,
             fsplit["host_us_per_token"]),
            ("--device-budget-us", device_budget_us,
             fsplit["device_us_per_token"]),
            ("--queue-wait-budget-us", queue_wait_budget_us,
             fsplit["queue_wait_us_mean"])):
        if budget is not None and got > budget:
            raise SystemExit(
                f"{name} gate: {got:.1f} exceeds budget {budget:.1f}")

    if trace_out:
        export_chrome_trace(tracer, trace_out)
        n_traces = len(tracer.traces())
        emit("fig15/trace", 0.0,
             f"path={trace_out} traces={n_traces}")

    speedup = eng_tps / naive_tps
    emit("fig15/speedup", 0.0,
         f"tokens_per_s={speedup:.2f}x "
         f"p99_tbt={naive_p99_tbt / eng_p99_tbt:.2f}x")
    if eng_tps <= naive_tps:
        raise SystemExit(
            f"continuous batching did not beat sequential generate on "
            f"throughput: {eng_tps:.1f} vs {naive_tps:.1f} tokens/s")
    if eng_p99_tbt >= naive_p99_tbt:
        raise SystemExit(
            f"continuous batching did not beat sequential generate on "
            f"p99 TBT: {eng_p99_tbt * 1e3:.1f} vs "
            f"{naive_p99_tbt * 1e3:.1f} ms")

    # ---------------------------------------------------------------
    # Speculative decode: >1 accepted tokens/iteration, bit-exact stream
    # ---------------------------------------------------------------
    spec_k = 2
    spec_eng, _, spec_busy = run_engine(
        workload, prompt_len, slots, max_new_cap,
        spec=SpecConfig(k=spec_k), tag="fig15-spec")
    assert len(spec_eng.completed) == n_req
    stats = spec_eng.spec_stats()
    emit("fig15/spec", spec_busy * 1e6 / total_tokens,
         f"tokens_per_s={total_tokens / spec_busy:.1f} k={spec_k} "
         f"accept_rate={stats['accept_rate']:.2f} "
         f"tokens_per_iter={stats['tokens_per_lane_iteration']:.2f} "
         f"iterations={stats['iterations']}")
    assert_transcripts_equal(
        {rid: rec.tokens for rid, rec in spec_eng.completed.items()},
        {rid: rec.tokens for rid, rec in eng.completed.items()},
        context="fig15 spec vs plain")
    if stats["tokens_per_lane_iteration"] <= 1.0:
        raise SystemExit(
            "speculative decode did not commit more than one token per "
            f"lane-iteration: {stats['tokens_per_lane_iteration']:.2f}")

    # ---------------------------------------------------------------
    # Paged vs worst-case-reserved at an identical KV pool byte budget
    # ---------------------------------------------------------------
    res_eng, _, res_busy = run_engine(
        workload, prompt_len, reserved_slots, max_new_cap, paged=False,
        tag="fig15-reserved")
    assert len(res_eng.completed) == n_req
    # the reserved engine's whole-cache byte budget, re-cut into pages
    # (rounded DOWN: the paged engine never gets more bytes)
    budget_tokens = reserved_slots * (prompt_len + max_new_cap)
    pool_pages = budget_tokens // PAGE_SIZE
    paged_eng, _, paged_busy = run_engine(
        workload, prompt_len, 2 * reserved_slots, max_new_cap, paged=True,
        pool_pages=pool_pages, tag="fig15-paged")
    assert len(paged_eng.completed) == n_req
    assert paged_eng.pool_bytes <= res_eng.pool_bytes, (
        paged_eng.pool_bytes, res_eng.pool_bytes)
    emit("fig15/reserved", res_busy * 1e6 / total_tokens,
         f"tokens_per_s={total_tokens / res_busy:.1f} "
         f"slots={reserved_slots} peak_active={res_eng.peak_active} "
         f"pool_bytes={res_eng.pool_bytes}")
    emit("fig15/paged", paged_busy * 1e6 / total_tokens,
         f"tokens_per_s={total_tokens / paged_busy:.1f} "
         f"slots={2 * reserved_slots} peak_active={paged_eng.peak_active} "
         f"pool_bytes={paged_eng.pool_bytes} "
         f"oom_preemptions={paged_eng.preemptions}")
    emit("fig15/paged_vs_reserved", 0.0,
         f"concurrency={paged_eng.peak_active}/{res_eng.peak_active} "
         f"tokens_per_s={res_busy / paged_busy:.2f}x")
    if paged_eng.peak_active <= res_eng.peak_active:
        raise SystemExit(
            "paged engine did not admit more concurrent requests than the "
            f"reservation baseline at equal pool bytes: "
            f"{paged_eng.peak_active} vs {res_eng.peak_active}")
    if paged_busy >= res_busy:
        raise SystemExit(
            "paged engine did not beat the reservation baseline on "
            f"throughput at equal pool bytes: {total_tokens / paged_busy:.1f}"
            f" vs {total_tokens / res_busy:.1f} tokens/s")

    # ---------------------------------------------------------------
    # Shared-prefix arm: a common-system-prompt workload at an identical
    # pool byte budget, prefix cache off vs on.  With the cache, repeat
    # prompts map the cached pages (zero admission pages, zero prefill
    # compute), so TTFT collapses to the host-side tree walk and the same
    # pool admits strictly more concurrent requests.
    # ---------------------------------------------------------------
    pfx_pool = 3 * prompt_len // PAGE_SIZE * 2     # tight: ~6 cold prompts
    pfx = make_prefix_workload(n_req, prompt_len, tokens_range,
                               arrival_gap, groups=2)
    pfx_tokens = sum(w["n_tokens"] for w in pfx)
    cold_eng, _, cold_busy = run_engine(
        pfx, prompt_len, n_req, max_new_cap, paged=True,
        pool_pages=pfx_pool, tag="fig15-nosharing")
    assert len(cold_eng.completed) == n_req
    warm_eng, warm_reg, warm_busy = run_engine(
        pfx, prompt_len, n_req, max_new_cap, paged=True,
        pool_pages=pfx_pool, prefix_cache=True, tag="fig15-sharing")
    assert len(warm_eng.completed) == n_req
    assert warm_eng.pool_bytes == cold_eng.pool_bytes
    # bit-exactness within the sharing arm: every repeat of a prompt is a
    # prefix hit and must stream the same greedy tokens as its group's
    # cold-admitted leader (ragged lengths: shorter is a prefix)
    by_group = {}
    for w in pfx:
        by_group.setdefault(w["group"], []).append(
            list(warm_eng.completed[w["rid"]].tokens))
    for g, streams in by_group.items():
        ref = max(streams, key=len)
        for s in streams:
            if s != ref[:len(s)]:
                raise SystemExit(
                    f"prefix-hit stream diverged from cold leader in "
                    f"group {g}: {s} vs {ref}")
    cold_ttft = float(np.mean(
        [rec.ttft_s for rec in cold_eng.completed.values()]))
    warm_ttft = float(np.mean(
        [rec.ttft_s for rec in warm_eng.completed.values()]))
    pstats = warm_eng.prefix_stats()
    gauge_hit = max((v for lbl, v in warm_reg.labeled_gauge_values(
        M_PREFIX_HIT_RATE) if "engine" in lbl), default=0.0)
    emit("fig15/prefix_nosharing", cold_busy * 1e6 / pfx_tokens,
         f"mean_ttft={cold_ttft * 1e3:.1f}ms "
         f"peak_active={cold_eng.peak_active} "
         f"pool_bytes={cold_eng.pool_bytes} "
         f"oom_preemptions={cold_eng.preemptions}")
    emit("fig15/prefix_sharing", warm_busy * 1e6 / pfx_tokens,
         f"mean_ttft={warm_ttft * 1e3:.1f}ms "
         f"peak_active={warm_eng.peak_active} "
         f"hit_rate={gauge_hit:.2f} hits={pstats['hits']} "
         f"cow_copies={pstats['cow_copies']} "
         f"evicted_pages={pstats['evicted_pages']} "
         f"oom_preemptions={warm_eng.preemptions}")
    emit("fig15/prefix_speedup", 0.0,
         f"ttft={cold_ttft / max(warm_ttft, 1e-9):.2f}x "
         f"concurrency={warm_eng.peak_active}/{cold_eng.peak_active}")
    if not gauge_hit > 0:
        raise SystemExit("sharing engine published no prefix_hit_rate")
    if warm_ttft >= cold_ttft:
        raise SystemExit(
            f"prefix sharing did not collapse TTFT: {warm_ttft * 1e3:.1f} "
            f"vs {cold_ttft * 1e3:.1f} ms mean")
    if warm_eng.peak_active <= cold_eng.peak_active:
        raise SystemExit(
            "prefix sharing did not raise admitted concurrency at equal "
            f"pool bytes: {warm_eng.peak_active} vs "
            f"{cold_eng.peak_active}")

    # ---------------------------------------------------------------
    # Prefill/decode disaggregation.  A prefill + decode replica pair
    # joined by a live KV handoff vs two mixed replicas at *equal total
    # slices*, over a near-saturated ragged burst — on the mixed
    # replicas every arriving prompt's EXECUTE lands between decode
    # iterations of resident lanes, which is exactly the interference
    # role separation removes (the decode replica only ever pays a page
    # install).  Gates: bit-exact token streams, strictly lower p99 TBT
    # (one retry, same as the host-cut gate: short wall-clock windows
    # jitter with machine load), and — with the decode pool squeezed to
    # ~one lane — TTFT-aware admission refuses transfers instead of
    # queueing them (handoff_fallback_total > 0) while the streams stay
    # bit-exact: disaggregation degrades to aggregated, never worse.
    # ---------------------------------------------------------------
    dis_wl = make_workload(n_req, prompt_len, tokens_range, 0.001, seed=19)
    dis_tokens = sum(w["n_tokens"] for w in dis_wl)

    def disagg_attempt(attempt):
        agg_router, _, _, agg_busy = run_pair(
            dis_wl, prompt_len, slots, max_new_cap, disagg=False,
            tag=f"fig15-agg{attempt}")
        dis_router, _, dis_reg, dis_busy = run_pair(
            dis_wl, prompt_len, slots, max_new_cap, disagg=True,
            tag=f"fig15-dis{attempt}")
        assert len(agg_router.completed) == n_req
        assert len(dis_router.completed) == n_req
        assert_transcripts_equal(
            {rid: rec.tokens for rid, rec in dis_router.completed.items()},
            {rid: rec.tokens for rid, rec in agg_router.completed.items()},
            context="fig15 disaggregated vs aggregated pair")
        agg_p99 = p99([t for rec in agg_router.completed.values()
                       for t in rec.tbts])
        dis_p99 = p99([t for rec in dis_router.completed.values()
                       for t in rec.tbts])
        dsnap = dis_reg.snapshot()
        handoffs = dsnap["counters"].get(f"{M_HANDOFF}{{service=svc}}", 0)
        emit("fig15/aggregated_pair", agg_busy * 1e6 / dis_tokens,
             f"attempt={attempt} "
             f"tokens_per_s={dis_tokens / agg_busy:.1f} "
             f"p99_tbt={agg_p99 * 1e3:.1f}ms slots=2x{slots}")
        emit("fig15/disagg", dis_busy * 1e6 / dis_tokens,
             f"attempt={attempt} "
             f"tokens_per_s={dis_tokens / dis_busy:.1f} "
             f"p99_tbt={dis_p99 * 1e3:.1f}ms slots=2x{slots} "
             f"handoffs={handoffs:.0f}")
        if not handoffs > 0:
            raise SystemExit("disaggregated arm performed no KV handoffs")
        agg_ref = {rid: list(rec.tokens)
                   for rid, rec in agg_router.completed.items()}
        return agg_p99, dis_p99, agg_ref

    agg_p99, dis_p99, agg_ref = disagg_attempt(0)
    if dis_p99 >= agg_p99:
        agg_p99, dis_p99, agg_ref = disagg_attempt(1)
    emit("fig15/disagg_vs_aggregated", 0.0,
         f"p99_tbt={agg_p99 / max(dis_p99, 1e-9):.2f}x")
    if dis_p99 >= agg_p99:
        raise SystemExit(
            f"disaggregated pair did not beat the aggregated pair on p99 "
            f"TBT at equal total slices: {dis_p99 * 1e3:.2f} vs "
            f"{agg_p99 * 1e3:.2f} ms")
    if tbt_budget_us is not None and dis_p99 * 1e6 > tbt_budget_us:
        raise SystemExit(
            f"--tbt-budget-us gate: disaggregated p99 TBT "
            f"{dis_p99 * 1e6:.1f}us exceeds budget {tbt_budget_us:.1f}us")

    # squeezed decode pool: ~one worst-case lane of headroom (the engine
    # floor) with a reserve carved out, so a single resident lane starves
    # the admission check and most offers are refused — those lanes
    # decode to completion on the prefill replica instead
    sat_pool = (prompt_len + max_new_cap) // PAGE_SIZE + 2
    sat_reserve = sat_pool // 3 + 1
    # symmetric lane split here: several lanes prefill concurrently, so
    # offers overlap decode-side residency and admission actually refuses
    sat_router, _, sat_reg, _ = run_pair(
        dis_wl, prompt_len, slots, max_new_cap, disagg=True,
        decode_pool_pages=sat_pool, decode_reserve_pages=sat_reserve,
        pf_slots=slots, tag="fig15-dis-sat")
    assert len(sat_router.completed) == n_req
    assert_transcripts_equal(
        {rid: rec.tokens for rid, rec in sat_router.completed.items()},
        agg_ref, context="fig15 disaggregated (saturated) vs aggregated")
    ssnap = sat_reg.snapshot()
    fallbacks = ssnap["counters"].get(
        f"{M_HANDOFF_FALLBACK}{{service=svc}}", 0)
    emit("fig15/disagg_saturated", 0.0,
         f"decode_pool_pages={sat_pool} fallbacks={fallbacks:.0f} "
         f"handoffs="
         f"{ssnap['counters'].get(f'{M_HANDOFF}{{service=svc}}', 0):.0f}")
    if not fallbacks > 0:
        raise SystemExit(
            "saturated decode pool produced no aggregated fallbacks "
            f"(pool_pages={sat_pool})")


if __name__ == "__main__":
    argv = sys.argv[1:]
    out = (argv[argv.index("--trace-out") + 1]
           if "--trace-out" in argv else None)

    def _flag(name):
        return (float(argv[argv.index(name) + 1])
                if name in argv else None)

    main(smoke="--smoke" in argv, trace_out=out,
         host_budget_us=_flag("--host-budget-us"),
         device_budget_us=_flag("--device-budget-us"),
         queue_wait_budget_us=_flag("--queue-wait-budget-us"),
         tbt_budget_us=_flag("--tbt-budget-us"))
