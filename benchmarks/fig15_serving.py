"""Fig 15 (extension): continuous-batching engine vs the naive sequential
``generate`` loop under ragged multi-request load.

Both servers face the *same* arrival schedule (a quick burst of requests
with ragged generation lengths) on the same smoke model:

* **naive** — the ``repro.serve.generate`` loop, FIFO, one request at a
  time, batch 1, jitted directly (no monitor in the way — this *favors*
  the baseline).  It is non-streaming: a request's tokens are delivered
  only when its loop finishes, so the client-observed time between tokens
  is ``(finish - arrival) / n_tokens`` — head-of-line queueing included.
* **engine** — ``repro.serve.engine.ContinuousBatchingEngine`` dispatching
  every iteration through a Funky monitor (EXECUTE per step, preemptible
  at token boundaries).  Tokens stream at iteration granularity; TBT is
  the measured inter-token gap from the shared metrics registry.

Reported: tokens/sec over the busy window, p50/p99 TTFT, p99 TBT.  The
run asserts the engine beats the baseline on both throughput and p99 TBT
— the continuous-batching property the serving plane depends on.

    PYTHONPATH=src python -m benchmarks.fig15_serving [--smoke]
"""

from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_arch
from repro.core import FunkyCL, Monitor, SliceAllocator
from repro.models import build_model
from repro.scaling.metrics import MetricsRegistry
from repro.serve import generate
from repro.serve.engine import (M_TBT, M_TTFT, ContinuousBatchingEngine,
                                ServeRequest)

ARCH = "yi-9b-smoke"


def make_workload(n_requests: int, prompt_len: int, tokens_range: tuple,
                  arrival_gap_s: float, seed: int = 7):
    """Ragged burst: ~Poisson arrivals, uniform-ragged generation lengths."""
    rng = np.random.Generator(np.random.Philox(seed))
    t = 0.0
    out = []
    for i in range(n_requests):
        t += float(rng.exponential(arrival_gap_s))
        out.append({
            "rid": f"req-{i:03d}", "arrival_t": t,
            "prompt": rng.integers(0, 256, prompt_len).astype(np.int32),
            "n_tokens": int(rng.integers(*tokens_range)),
        })
    return out


def run_naive(bundle, params, workload, prompt_len):
    """Sequential FIFO server; returns per-request (ttft, eff_tbt, n) and
    the busy-window wall seconds."""
    # warm the jit cache outside the timed window (steady-state serving)
    warm = {"tokens": np.zeros((1, prompt_len), np.int32)}
    jax.block_until_ready(generate(bundle, params, warm, 2))
    t0 = time.perf_counter()
    results = []
    for w in workload:
        now = time.perf_counter() - t0
        if now < w["arrival_t"]:
            time.sleep(w["arrival_t"] - now)
        toks = generate(bundle, params,
                        {"tokens": w["prompt"].reshape(1, -1)},
                        w["n_tokens"])
        jax.block_until_ready(toks)
        finish = time.perf_counter() - t0
        latency = finish - w["arrival_t"]
        results.append({"rid": w["rid"], "ttft": latency,  # 1st delivery
                        "eff_tbt": latency / w["n_tokens"],
                        "n": w["n_tokens"], "finish": finish})
    busy_s = max(r["finish"] for r in results) - workload[0]["arrival_t"]
    return results, busy_s


def run_engine(workload, prompt_len, slots, max_new_cap):
    """Continuous-batching server through a real monitor; returns the
    completion records, the registry, and the busy-window seconds."""
    # perf_counter clock so request arrival_t and engine timestamps share
    # one monotonic timebase
    reg = MetricsRegistry(clock=time.perf_counter)
    alloc = SliceAllocator("bench0", 1)
    mon = Monitor("fig15-engine", alloc, telemetry=reg)
    eng = ContinuousBatchingEngine(ARCH, FunkyCL(mon), slots=slots,
                                   prompt_len=prompt_len,
                                   max_new_tokens=max_new_cap, registry=reg)
    eng.setup()        # compiles outside the timed window, like the baseline
    t0 = time.perf_counter()
    pending = list(workload)
    while pending or not eng.idle:
        now = time.perf_counter() - t0
        while pending and pending[0]["arrival_t"] <= now:
            w = pending.pop(0)
            eng.submit(ServeRequest(
                rid=w["rid"], prompt=w["prompt"],
                max_new_tokens=w["n_tokens"],
                arrival_t=t0 + w["arrival_t"]))   # registry clock basis
        if eng.idle:
            time.sleep(0.001)
            continue
        eng.step()
    busy_s = (time.perf_counter() - t0) - workload[0]["arrival_t"]
    mon.vfpga_exit()
    return eng, reg, busy_s


def p99(values):
    """Interpolated p99, matching the registry's Histogram.quantile."""
    if not values:
        return float("nan")
    return float(np.percentile(np.asarray(values), 99))


def main(smoke: bool = False):
    if smoke:
        n_req, prompt_len, tokens_range = 12, 8, (6, 13)
        slots, arrival_gap = 4, 0.005
    else:
        n_req, prompt_len, tokens_range = 24, 16, (8, 25)
        slots, arrival_gap = 8, 0.01
    max_new_cap = tokens_range[1]
    workload = make_workload(n_req, prompt_len, tokens_range, arrival_gap)
    total_tokens = sum(w["n_tokens"] for w in workload)

    cfg = get_arch(ARCH)
    bundle = build_model(cfg, cache_margin=max_new_cap)
    params = bundle.init(jax.random.PRNGKey(0))

    naive, naive_busy = run_naive(bundle, params, workload, prompt_len)
    naive_tps = total_tokens / naive_busy
    naive_p99_tbt = p99([r["eff_tbt"] for r in naive])
    emit("fig15/naive", naive_busy * 1e6 / total_tokens,
         f"tokens_per_s={naive_tps:.1f} "
         f"p99_tbt={naive_p99_tbt * 1e3:.1f}ms "
         f"p99_ttft={p99([r['ttft'] for r in naive]) * 1e3:.1f}ms")

    eng, reg, eng_busy = run_engine(workload, prompt_len, slots, max_new_cap)
    assert len(eng.completed) == n_req, (len(eng.completed), n_req)
    eng_tps = total_tokens / eng_busy
    tbts = [t for rec in eng.completed.values() for t in rec.tbts]
    eng_p99_tbt = p99(tbts)
    ttfts = [rec.ttft_s for rec in eng.completed.values()]
    emit("fig15/engine", eng_busy * 1e6 / total_tokens,
         f"tokens_per_s={eng_tps:.1f} p99_tbt={eng_p99_tbt * 1e3:.1f}ms "
         f"p99_ttft={p99(ttfts) * 1e3:.1f}ms slots={slots}")

    # per-request latencies must be in the shared registry schema
    snap = reg.snapshot()
    assert snap["histograms"][f"{M_TTFT}{{service=svc}}"]["count"] == n_req
    assert (snap["histograms"][f"{M_TBT}{{service=svc}}"]["count"]
            == total_tokens - n_req)
    assert (snap["histograms"]["request_latency_seconds{service=svc}"]
            ["count"] == n_req)

    speedup = eng_tps / naive_tps
    emit("fig15/speedup", 0.0,
         f"tokens_per_s={speedup:.2f}x "
         f"p99_tbt={naive_p99_tbt / eng_p99_tbt:.2f}x")
    if eng_tps <= naive_tps:
        raise SystemExit(
            f"continuous batching did not beat sequential generate on "
            f"throughput: {eng_tps:.1f} vs {naive_tps:.1f} tokens/s")
    if eng_p99_tbt >= naive_p99_tbt:
        raise SystemExit(
            f"continuous batching did not beat sequential generate on "
            f"p99 TBT: {eng_p99_tbt * 1e3:.1f} vs "
            f"{naive_p99_tbt * 1e3:.1f} ms")


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
