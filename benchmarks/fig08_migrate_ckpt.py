"""Fig 8: VM migration (host-RAM snapshot) vs checkpointing (disk) vs size.

Paper: snapshot sizes 125 MiB - 2.1 GiB; Checkpoint slower than Restore
(dirty-page walk + random writes); FPGA-specific share of VM save is
0.4-10.6 %.  We measure the same breakdown: evict (device->host) time inside
the total snapshot, disk write, restore.
"""

from __future__ import annotations

import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.ckpt import load_snapshot, save_snapshot
from repro.core import FunkyCL, GuestState, Monitor, Program, SliceAllocator


def main():
    tmp = tempfile.mkdtemp(prefix="fig08-")
    for mb in (16, 64, 256):
        alloc = SliceAllocator("n0", 1, mem_cap_bytes=16 << 30)
        m = Monitor(f"ck{mb}", alloc)
        n = mb * (1 << 20) // 4
        spec = jax.ShapeDtypeStruct((n,), jnp.float32)
        m.vfpga_init(Program("id", lambda x: x + 1.0), (spec,))
        cl = FunkyCL(m)
        cl.clCreateBuffer("x", spec)
        cl.write_buffer("x", np.ones(n, np.float32))
        cl.clEnqueueKernel("id", ("x",), ("x",))
        cl.clFinish()

        # --- migration-style: snapshot to host memory --------------------------
        t0 = time.perf_counter()
        snap = m.checkpoint(GuestState(step=1), keep_running=True)
        t_vm_save = time.perf_counter() - t0
        fpga_share = m.metrics_hist["sync_wait"][-1] / max(t_vm_save, 1e-9)

        # --- checkpoint: persist to disk ------------------------------------------
        t0 = time.perf_counter()
        stats = save_snapshot(f"{tmp}/ck{mb}", snap)
        t_disk = time.perf_counter() - t0

        # --- restore ---------------------------------------------------------------
        t0 = time.perf_counter()
        snap2, _ = load_snapshot(f"{tmp}/ck{mb}")
        t_restore = time.perf_counter() - t0

        emit(f"fig08/vm_save_{mb}MiB", t_vm_save * 1e6,
             f"sync share {fpga_share * 100:.1f}% (paper: 0.4-10.6%)")
        emit(f"fig08/checkpoint_disk_{mb}MiB", t_disk * 1e6,
             f"{stats['written_bytes'] / 2**20:.0f} MiB written")
        emit(f"fig08/restore_disk_{mb}MiB", t_restore * 1e6, "")
        m.vfpga_exit()
    shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
