"""Fig 10: preemptive scheduling effectiveness on the LIVE cluster.

3 worker nodes x 1 vSlice, long- and short-running training tasks with the
paper's two priority scenarios (Short-HP / Long-HP, Table 6), policies
FCFS / NO_PRE / PRE_EV / PRE_MG (Table 5).  Reports mean completion time of
high- vs low-priority tasks.
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import Policy, TaskImage, make_cluster
from repro.train import OptConfig

OC = OptConfig(warmup_steps=1, decay_steps=100)
IMAGES = {
    "long": TaskImage(name="long", kind="train", arch="yi-9b-smoke",
                      seq_len=32, global_batch=4, total_steps=12, chunks=1,
                      opt=OC),
    "short": TaskImage(name="short", kind="train", arch="yi-9b-smoke",
                       seq_len=32, global_batch=4, total_steps=2, chunks=1,
                       opt=OC),
}


def _scenario(policy: Policy, short_hp: bool):
    cl = make_cluster(num_nodes=3, slices_per_node=1, images=IMAGES,
                      policy=policy)
    orch = cl.orchestrator
    orch.start(tick_interval=0.01)
    hp, lp = (5, 0)
    subs = []
    # deploy 3 long first (occupy all slots), then 3 short
    for i in range(3):
        subs.append(("long", orch.submit(
            "long", priority=lp if short_hp else hp)))
    time.sleep(0.3)
    for i in range(3):
        subs.append(("short", orch.submit(
            "short", priority=hp if short_hp else lp)))
    ok = orch.wait_all(timeout=3600)
    out = {}
    for kind, cid in subs:
        d = orch.deployments[cid]
        assert d.status == "done", (cid, d.status)
        out.setdefault(kind, []).append(d.end_time - d.submit_time)
    orch.stop()
    cl.stop()
    return {k: sum(v) / len(v) for k, v in out.items()}


def main():
    for scen, short_hp in (("short_hp", True), ("long_hp", False)):
        for pol in (Policy.FCFS, Policy.NO_PRE, Policy.PRE_EV, Policy.PRE_MG):
            r = _scenario(pol, short_hp)
            hp_kind = "short" if short_hp else "long"
            emit(f"fig10/{scen}_{pol.value}_hp", r[hp_kind] * 1e6,
                 f"lp={r['long' if short_hp else 'short']:.2f}s")


if __name__ == "__main__":
    main()
