#!/usr/bin/env python
"""Inspect / validate a Chrome-trace JSON exported by repro.obs.

Usage:
    python tools/trace_dump.py TRACE.json            # summary + span table
    python tools/trace_dump.py TRACE.json --check    # CI validation mode
    python tools/trace_dump.py TRACE.json --trace r7 # one trace's span tree

Load the same file interactively in Perfetto (https://ui.perfetto.dev) or
chrome://tracing — one row ("process") per trace, one track per span-name
prefix (router / engine / monitor / execute / orch / sim).

``--check`` exits non-zero unless the file parses, every event carries
valid ``ph``/``ts``/``pid``/``tid`` fields, each trace's spans form one
connected tree, and at least one EXECUTE span has non-zero device time —
the guard CI runs on the fig15 smoke artifact.
"""

import argparse
import json
import sys
from collections import defaultdict

sys.path.insert(0, "src")

from repro.obs import validate_chrome_trace  # noqa: E402


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def spans_by_trace(doc: dict) -> dict:
    out = defaultdict(list)
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        out[ev.get("args", {}).get("trace_id", ev["pid"])].append(ev)
    return out


def check(doc: dict) -> int:
    stats = validate_chrome_trace(doc)       # raises on malformed events
    execs = [ev for ev in doc["traceEvents"]
             if ev.get("ph") == "X" and ev["name"] == "monitor.execute"]
    devs = [ev for ev in doc["traceEvents"]
            if ev.get("ph") == "X" and ev["name"] == "execute.device"
            and ev.get("dur", 0) > 0]
    print(f"ok: {stats['traces']} traces, {stats['spans']} spans, "
          f"{len(execs)} EXECUTE spans, {len(devs)} with device time")
    if not execs:
        print("FAIL: no monitor.execute span in trace", file=sys.stderr)
        return 1
    if not devs:
        print("FAIL: no execute.device span with non-zero duration",
              file=sys.stderr)
        return 1
    return 0


def links_of(ev: dict) -> list:
    """Cross-trace links carried on a root event (JSON-encoded in args)."""
    raw = ev.get("args", {}).get("links")
    if not raw:
        return []
    try:
        return json.loads(raw)
    except (TypeError, ValueError):
        return []


def print_tree(events: list) -> None:
    by_id = {ev["args"]["span_id"]: ev for ev in events}
    kids = defaultdict(list)
    for ev in events:
        kids[ev["args"]["parent_id"]].append(ev)
    for vs in kids.values():
        vs.sort(key=lambda e: e["ts"])

    def walk(ev, depth):
        ms = ev.get("dur", 0) / 1000.0
        labels = {k: v for k, v in ev["args"].items()
                  if k not in ("span_id", "parent_id", "trace_id", "links")}
        print(f"  {'  ' * depth}{ev['name']:<28} {ms:10.3f} ms  {labels}")
        # cross-trace links (recovery timeline): show which earlier trace
        # this one continues, right under its root
        for link in links_of(ev):
            print(f"  {'  ' * (depth + 1)}"
                  f"~~ {link.get('relation', 'follows')} trace "
                  f"{link.get('trace_id')} ({link.get('name', '?')})")
        for child in kids.get(ev["args"]["span_id"], []):
            walk(child, depth + 1)

    for root in kids.get(0, []):
        walk(root, 0)
    orphans = [ev for ev in events
               if ev["args"]["parent_id"] not in by_id
               and ev["args"]["parent_id"] != 0]
    for ev in orphans:
        walk(ev, 0)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="Chrome-trace JSON file")
    ap.add_argument("--check", action="store_true",
                    help="validate for CI: parse, field check, >=1 EXECUTE "
                         "span with non-zero device time")
    ap.add_argument("--trace", default=None,
                    help="print the span tree of one trace_id")
    args = ap.parse_args(argv)
    doc = load(args.path)
    if args.check:
        return check(doc)
    groups = spans_by_trace(doc)
    if args.trace is not None:
        if args.trace not in groups:
            print(f"trace {args.trace!r} not found; have: "
                  f"{sorted(map(str, groups))[:20]}", file=sys.stderr)
            return 1
        print(f"trace {args.trace}:")
        print_tree(groups[args.trace])
        return 0
    print(f"{len(groups)} traces, "
          f"{sum(len(v) for v in groups.values())} spans")
    for tid, evs in sorted(groups.items(), key=lambda kv: str(kv[0])):
        root = min(evs, key=lambda e: e["ts"])
        dur_ms = root.get("dur", 0) / 1000.0
        roots = [ev for ev in evs if ev["args"].get("parent_id") == 0]
        link_note = ""
        for r in roots:
            for link in links_of(r):
                link_note += (f"  ~~ {link.get('relation', 'follows')} "
                              f"{link.get('trace_id')}")
        print(f"  {str(tid):<24} {root['name']:<16} "
              f"{len(evs):4d} spans  {dur_ms:10.3f} ms{link_note}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
