"""Regenerate the EXPERIMENTS.md roofline/dry-run tables from results JSON."""

import json
import sys


def dryrun_table(path="results/dryrun.json"):
    rs = json.load(open(path))
    ok = [r for r in rs if r["status"] == "ok"]
    lines = ["| arch | shape | mesh | compile_s | HBM frac | fits | collectives |",
             "|---|---|---|---|---|---|---|"]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        c = r["collectives"]["counts"]
        cs = " ".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in sorted(c.items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} "
            f"| {r['hbm_frac']:.2f} | {'Y' if r['fits_hbm'] else 'N'} | {cs} |")
    skipped = [r for r in rs if r["status"] == "skipped"]
    lines.append("")
    lines.append(f"Skipped (inapplicable) cells: "
                 + ", ".join(sorted({f"{r['arch']} x {r['shape']}" for r in skipped})))
    return "\n".join(lines)


def roofline_table(path="results/roofline.json"):
    rs = json.load(open(path))
    ok = [r for r in rs if r["status"] == "ok"]
    lines = ["| arch | shape | compute_s | memory_s | collective_s | bottleneck "
             "| step_s (LB) | roofline | useful | tok/s/chip |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} "
            f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
            f"| {r['bottleneck'][:-2]} | {r['step_seconds_lower_bound']:.4f} "
            f"| {r['roofline_fraction']:.3f} | {r['useful_flops_ratio']:.2f} "
            f"| {r['tokens_per_second_per_chip']:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "both"
    if which in ("both", "dryrun"):
        print(dryrun_table())
        print()
    if which in ("both", "roofline"):
        print(roofline_table())
