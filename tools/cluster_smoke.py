# End-to-end live-cluster smoke: deploy train+serve tasks on 2 nodes,
# evict/resume/migrate/checkpoint/restore through the full stack.
import time
from repro.core import make_cluster, TaskImage, Policy, TaskStatus

images = {
    "train-small": TaskImage(name="train-small", kind="train",
                             arch="yi-9b-smoke", seq_len=16, global_batch=4,
                             total_steps=6, chunks=2),
    "serve-small": TaskImage(name="serve-small", kind="serve",
                             arch="yi-9b-smoke", prompt_len=8, global_batch=2,
                             total_steps=4, tokens_per_step=2),
}
cl = make_cluster(num_nodes=2, slices_per_node=1, images=images,
                  policy=Policy.PRE_MG)
orch = cl.orchestrator
orch.start(tick_interval=0.01)
t1 = orch.submit("train-small", priority=0)
t2 = orch.submit("serve-small", priority=1)
ok = orch.wait_all(timeout=180)
print("all done:", ok)
for cid, d in orch.deployments.items():
    print(" ", cid, d.status)
orch.stop()
assert ok, [ (c, d.status) for c, d in orch.deployments.items() ]
for cid, d in orch.deployments.items():
    assert d.status == "done", (cid, d.status)

cl2 = make_cluster(num_nodes=2, slices_per_node=1, images=images)
rt = cl2.nodes["node0"].runtime
rec = rt.create("m1", images["train-small"])
rt.start("m1")
time.sleep(1.0)
stats = rt.evict("m1")
print("evict stats:", {k: round(v,4) if isinstance(v,float) else v for k,v in stats.items()})
assert rt.status("m1") == TaskStatus.EVICTED
rt2 = cl2.nodes["node1"].runtime
rt2.resume("m1", source=rt)
st = rt2.wait("m1", timeout=120)
print("after migrate:", st, "final step:", rt2.tasks["m1"].guest_state.step)
assert st == TaskStatus.DONE
ckpt_img = TaskImage(name="ck", kind="train", arch="yi-9b-smoke",
                     seq_len=16, global_batch=4, total_steps=60, chunks=2)
rt.tasks.pop("c1", None)
rec = rt.create("c1", ckpt_img)
rt.start("c1")
path = rt.checkpoint("c1")
print("ckpt:", path)
rt.kill("c1")
rt2.restore("c2", path)
st = rt2.wait("c2", timeout=120)
print("restored task:", st, rt2.tasks["c2"].guest_state.step)
assert st == TaskStatus.DONE
print("CLUSTER SMOKE OK")
