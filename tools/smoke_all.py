"""Quick all-arch smoke driver (train+prefill+decode on reduced configs)."""
import jax, jax.numpy as jnp, traceback, sys
from repro.configs import ARCHS, reduced
from repro.models import build_model

def make_batch(cfg, B=2, S=32):
    if cfg.family == "encdec":
        T = max(int(S * cfg.tgt_ratio), 8)
        return {"src_emb": jnp.ones((B, S, cfg.d_model), jnp.bfloat16) * 0.01,
                "tgt_tokens": jnp.zeros((B, T), jnp.int32),
                "tgt_targets": jnp.ones((B, T), jnp.int32)}
    if cfg.family == "vlm":
        return {"tokens": jnp.zeros((B, S), jnp.int32),
                "targets": jnp.ones((B, S), jnp.int32),
                "img_emb": jnp.ones((B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16) * 0.01}
    return {"tokens": jnp.zeros((B, S), jnp.int32), "targets": jnp.ones((B, S), jnp.int32)}

fails = 0
for name, full in ARCHS.items():
    cfg = reduced(full)
    try:
        b = build_model(cfg)
        params = b.init(jax.random.key(0))
        batch = make_batch(cfg)
        loss, m = jax.jit(b.loss_fn)(params, batch)
        assert not jnp.isnan(loss), "nan loss"
        if cfg.family == "encdec":
            pre_batch = {"src_emb": batch["src_emb"], "tgt_tokens": batch["tgt_tokens"]}
        elif cfg.family == "vlm":
            pre_batch = {"tokens": batch["tokens"], "img_emb": batch["img_emb"]}
        else:
            pre_batch = {"tokens": batch["tokens"]}
        logits, caches = jax.jit(b.prefill_fn)(params, pre_batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        S0 = pre_batch.get("tgt_tokens", pre_batch.get("tokens")).shape[1]
        logits2, caches = jax.jit(b.decode_fn)(params, tok, jnp.int32(S0), caches)
        assert not jnp.isnan(logits2).any()
        g = jax.jit(jax.grad(lambda p: b.loss_fn(p, batch)[0]))(params)
        assert not any(bool(jnp.isnan(x).any()) for x in jax.tree.leaves(g)), "nan grad"
        print(f"{name:28s} OK  loss={float(loss):.3f}")
    except Exception as e:
        fails += 1
        print(f"{name:28s} FAIL: {type(e).__name__}: {e}")
        traceback.print_exc(limit=4)
sys.exit(fails)
