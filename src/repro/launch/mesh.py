"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state.  Single-pod: 16x16 = 256 chips (v5e pod),
multi-pod: 2x16x16 = 512 chips with a leading "pod" data-parallel axis.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model: int = 1):
    """Debug mesh over however many local devices exist."""
    n = jax.device_count()
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
