"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state.  Single-pod: 16x16 = 256 chips (v5e pod),
multi-pod: 2x16x16 = 512 chips with a leading "pod" data-parallel axis.
"""

from __future__ import annotations

import jax


def compat_make_mesh(axis_shapes, axis_names, *, devices=None):
    """Version-compat wrapper over ``jax.make_mesh``.

    Newer JAX exposes ``jax.sharding.AxisType`` and ``make_mesh`` takes an
    ``axis_types`` keyword; older releases (e.g. 0.4.x) have neither.  We
    always want plain Auto axes, so request them explicitly where supported
    and fall back to the default behaviour elsewhere.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names, devices=devices,
                axis_types=(axis_type.Auto,) * len(axis_names))
        except TypeError:
            pass  # make_mesh predates the axis_types keyword
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def compat_shard_map(body, *, mesh, in_specs, out_specs,
                     check_vma: bool = False):
    """Version-compat wrapper over ``jax.shard_map``.

    Newer JAX exposes it at top level with a ``check_vma`` keyword; older
    releases only have ``jax.experimental.shard_map.shard_map`` with the
    same switch spelled ``check_rep``.
    """
    try:
        sm = jax.shard_map
    except AttributeError:
        from jax.experimental.shard_map import shard_map as sm
        return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)
    return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_vma=check_vma)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Debug mesh over however many local devices exist."""
    n = jax.device_count()
    assert n % model == 0, (n, model)
    return compat_make_mesh((n // model, model), ("data", "model"))
