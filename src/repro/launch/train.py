"""End-to-end training driver — runs a real (reduced-size) model for a few
hundred steps on this host, *under the Funky runtime*: the training loop is a
guest task whose every device interaction flows through the monitor
(MEMORY/TRANSFER/EXECUTE/SYNC), so it is preemptible and checkpointable.

    PYTHONPATH=src python -m repro.launch.train \
        --arch yi-9b-smoke --steps 200 --batch 8 --seq 64 --chunks 4

Use ``--native`` to bypass the Funky layer (same jitted step functions,
direct dispatch) — the pair is the Fig 4 virtualization-overhead experiment.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_arch
from repro.core import TaskImage, TaskStatus, make_cluster
from repro.train import (DataConfig, OptConfig, make_batch, make_train_state,
                         make_train_step)


def run_native(args) -> dict:
    from repro.configs.base import ShapeConfig
    from repro.models import build_model

    cfg = get_arch(args.arch)
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    bundle = build_model(cfg)
    oc = OptConfig(warmup_steps=10, decay_steps=max(args.steps, 20))
    params, opt = make_train_state(bundle, oc, jax.random.PRNGKey(args.seed))
    step = jax.jit(make_train_step(bundle, oc, num_microbatches=args.chunks))
    t0 = time.perf_counter()
    losses = []
    for i in range(args.steps):
        batch = make_batch(cfg, shape, i, DataConfig(seed=args.seed))
        params, opt, m = step(params, opt, batch)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            losses.append(float(m["loss"]))
            print(f"step {i:5d} loss {losses[-1]:.4f} "
                  f"lr {float(m['lr']):.2e}", flush=True)
    dt = time.perf_counter() - t0
    return {"seconds": dt, "loss_first": losses[0], "loss_last": losses[-1]}


def run_funky(args) -> dict:
    image = TaskImage(
        name="cli-train", kind="train", arch=args.arch, seq_len=args.seq,
        global_batch=args.batch, total_steps=args.steps, chunks=args.chunks,
        seed=args.seed,
        opt=OptConfig(warmup_steps=10, decay_steps=max(args.steps, 20)))
    cluster = make_cluster(num_nodes=1, slices_per_node=1,
                           images={"cli-train": image})
    rt = cluster.nodes["node0"].runtime
    t0 = time.perf_counter()
    rt.create("train0", image)
    rt.start("train0")
    status = rt.wait("train0", timeout=36000)
    dt = time.perf_counter() - t0
    rec = rt.tasks["train0"]
    if status is not TaskStatus.DONE:
        raise SystemExit(f"task ended {status}: {rec.error}")
    mon = rec.monitor
    print(f"done: {rec.guest_state.step} steps in {dt:.1f}s | "
          f"final_loss={rec.guest_state.user.get('final_loss'):.4f} | "
          f"requests: EXECUTE={int(mon.metrics['n_EXECUTE'])} "
          f"TRANSFER={int(mon.metrics['n_TRANSFER'])} "
          f"reconfig={mon.metrics['reconfig_seconds']:.1f}s")
    return {"seconds": dt,
            "final_loss": rec.guest_state.user.get("final_loss")}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b-smoke")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--chunks", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--native", action="store_true")
    args = ap.parse_args()
    out = run_native(args) if args.native else run_funky(args)
    print(out)


if __name__ == "__main__":
    main()
