"""Serving driver: batched greedy decoding under the Funky runtime.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen3-8b-smoke --batch 4 --prompt-len 16 --tokens 64
"""

from __future__ import annotations

import argparse
import time

from repro.core import TaskImage, TaskStatus, make_cluster


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--tokens-per-step", type=int, default=8)
    args = ap.parse_args()

    steps = max(args.tokens // args.tokens_per_step, 1)
    image = TaskImage(
        name="cli-serve", kind="serve", arch=args.arch,
        global_batch=args.batch, prompt_len=args.prompt_len,
        total_steps=steps, tokens_per_step=args.tokens_per_step)
    cluster = make_cluster(num_nodes=1, slices_per_node=1,
                           images={"cli-serve": image})
    rt = cluster.nodes["node0"].runtime
    t0 = time.perf_counter()
    rt.create("serve0", image)
    rt.start("serve0")
    status = rt.wait("serve0", timeout=36000)
    dt = time.perf_counter() - t0
    rec = rt.tasks["serve0"]
    if status is not TaskStatus.DONE:
        raise SystemExit(f"task ended {status}: {rec.error}")
    n_tok = steps * args.tokens_per_step * args.batch
    print(f"decoded {n_tok} tokens in {dt:.1f}s "
          f"({n_tok / dt:.1f} tok/s) | last tokens: "
          f"{rec.guest_state.user.get('last_token')}")


if __name__ == "__main__":
    main()
