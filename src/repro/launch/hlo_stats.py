"""HLO text statistics: collective ops, their byte volumes, fusion counts.

Shapes in an SPMD-partitioned HLO module are *per-device*; the stats here are
therefore per-chip quantities, matching the per-chip roofline denominators.
Collectives inside ``while`` bodies (scan-over-layers) execute once per trip;
the roofline analyzer avoids this pitfall by unrolling depth variants — for
whole-program dry-run records we report static op counts and note the caveat.
"""

from __future__ import annotations

import re
from collections import Counter, defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> tuple[int, int]:
    """Returns (bytes, f32_bytes) for an HLO shape string."""
    total = f32 = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        b = n * _DTYPE_BYTES[dt]
        total += b
        if dt == "f32":
            f32 += b
    return total, f32


def collective_stats(hlo_text: str) -> Dict:
    """Counts and per-device output bytes per collective kind.

    ``bytes_f32`` tracks the f32 share: XLA:CPU legalizes bf16 dots by
    converting operands to f32 *before* SPMD resharding (verified with a
    minimal probe), so weight gathers / grad reduces that would travel as
    bf16 on TPU appear doubled here.  ``wire_bytes`` counts those at bf16
    width — the number a TPU deployment would move.
    """
    counts: Counter = Counter()
    bytes_by: Dict[str, int] = defaultdict(int)
    f32_by: Dict[str, int] = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        counts[op] += 1
        b, f = _shape_bytes(shape_str)
        bytes_by[op] += b
        f32_by[op] += f
    wire = {op: bytes_by[op] - f32_by.get(op, 0) // 2 for op in bytes_by}
    return {
        "counts": dict(counts),
        "bytes": dict(bytes_by),
        "bytes_f32": dict(f32_by),
        "wire_bytes": wire,
        "total_bytes": int(sum(bytes_by.values())),
        "total_count": int(sum(counts.values())),
    }


def collective_seconds(stats: Dict, ici_bw: float,
                       wire_adjusted: bool = True) -> float:
    """Per-chip collective seconds; ring all-reduce moves ~2x its bytes."""
    key = "wire_bytes" if wire_adjusted and "wire_bytes" in stats else "bytes"
    secs = 0.0
    for op, b in stats[key].items():
        factor = 2.0 if op == "all-reduce" else 1.0
        secs += factor * b / ici_bw
    return secs
