"""Shared builder: (arch x shape x mesh) -> jittable step + shardings.

Used by the dry-run (lower/compile gate), the roofline analyzer (depth
variants), and the live drivers.  ``shape.kind`` selects the step:

    train   -> step(params, opt_state, batch) -> (params, opt_state, metrics)
    prefill -> step(params, batch)            -> (logits, caches)
    decode  -> step(params, token, pos, caches) -> (logits, caches)
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model_zoo import build_model, input_specs
from repro.sharding.rules import ShardingRules, dp_axes_of
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step

# Above this parameter count, Adam moments are stored as 8-bit (Dettmers-
# style) so params+optimizer fit a single pod (deepseek-v3-671b: 10.6 GB vs
# 15.7 GB bf16 / 26 GB f32 per chip; see EXPERIMENTS.md §Perf C).
INT8_MOMENTS_ABOVE = 100e9


@dataclass
class CellProgram:
    cfg: ModelConfig
    shape: ShapeConfig
    fn: Callable
    abstract_args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jitted().lower(*self.abstract_args)


def opt_config_for(cfg: ModelConfig) -> OptConfig:
    from repro.models.model_zoo import analytic_param_count

    n = analytic_param_count(cfg)
    mdt = "int8" if n > INT8_MOMENTS_ABOVE else "float32"
    return OptConfig(moment_dtype=mdt)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               policy: str = "fsdp_tp", remat: str = "full",
               train_impl: str = "naive", prefill_impl: str = "blockwise",
               mla_absorb: bool = True, scan_unroll: bool = False,
               num_microbatches: int = 1, donate: bool = True,
               prefill_chunk: int = 1024) -> CellProgram:
    rules = ShardingRules(cfg, mesh, policy)
    dp_axes = rules.batch_axes
    bundle = build_model(
        cfg, mesh=mesh, impl=train_impl, prefill_impl=prefill_impl,
        remat=remat, dp_axes=dp_axes, mla_absorb=mla_absorb,
        scan_unroll=scan_unroll, prefill_chunk=prefill_chunk)
    specs = input_specs(cfg, shape)

    params_abs = jax.eval_shape(bundle.init, jax.random.key(0))
    p_shard = rules.param_shardings(params_abs)
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt_cfg = opt_config_for(cfg)
        opt_abs = jax.eval_shape(partial(init_opt_state, opt_cfg), params_abs)
        opt_shard = {"m": p_shard, "v": p_shard, "count": rep}
        if opt_cfg.moment_dtype == "int8":
            def scale_shard(s):
                spec = tuple(s.spec) if s.spec else ()
                spec = spec[:-1] + (None,) if spec else ()
                return NamedSharding(mesh, P(*spec))

            sc = jax.tree.map(scale_shard, p_shard)
            opt_shard["m_scale"] = sc
            opt_shard["v_scale"] = sc
        batch_abs = specs["batch"]
        b_shard = rules.shardings_for(batch_abs, "batch")
        step = make_train_step(bundle, opt_cfg,
                               num_microbatches=num_microbatches,
                               mesh=mesh, dp_axes=dp_axes)
        return CellProgram(
            cfg=cfg, shape=shape, fn=step,
            abstract_args=(params_abs, opt_abs, batch_abs),
            in_shardings=(p_shard, opt_shard, b_shard),
            out_shardings=(p_shard, opt_shard, None),
            donate_argnums=(0, 1) if donate else (),
        )

    if shape.kind == "prefill":
        batch_abs = specs["batch"]
        b_shard = rules.shardings_for(batch_abs, "batch")

        def prefill(params, batch):
            return bundle.prefill_fn(params, batch)

        # Pin the output cache layout (batch + kv-head/seq sharding): left to
        # propagation, GSPMD replicates the 32k cache across data shards.
        logits_abs, caches_abs = jax.eval_shape(prefill, params_abs, batch_abs)
        c_shard = rules.shardings_for(caches_abs, "cache")
        return CellProgram(
            cfg=cfg, shape=shape, fn=prefill,
            abstract_args=(params_abs, batch_abs),
            in_shardings=(p_shard, b_shard),
            out_shardings=(None, c_shard),
        )

    # decode
    caches_abs = specs["caches"]
    c_shard = rules.shardings_for(caches_abs, "cache")
    tok_abs = specs["token"]
    tok_spec = P(rules._batch_dim(tok_abs.shape[0]))
    tok_shard = NamedSharding(mesh, tok_spec)

    def decode(params, token, pos, caches):
        return bundle.decode_fn(params, token, pos, caches)

    return CellProgram(
        cfg=cfg, shape=shape, fn=decode,
        abstract_args=(params_abs, tok_abs, specs["pos"], caches_abs),
        in_shardings=(p_shard, tok_shard, rep, c_shard),
        out_shardings=(None, c_shard),
        donate_argnums=(3,) if donate else (),
    )
