import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: JAX locks the device count on first
initialization, and the production meshes need 512 host devices.

For every applicable cell this driver:
  1. builds the step function + shardings (repro.launch.steps),
  2. ``jit(...).lower(*abstract_args)`` — nothing is ever allocated,
  3. ``lowered.compile()`` — the SPMD partitioner must accept the shardings,
  4. records ``memory_analysis()`` (per-device bytes: proves it fits HBM),
     ``cost_analysis()`` (per-device FLOPs/bytes) and the collective
     schedule parsed from the compiled HLO,
  5. appends the record to a JSON results file (idempotent/resumable).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shape all --mesh both --out results/dryrun.json
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import SHAPES, applicable, get_arch
from repro.configs.registry import ARCHS
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

HBM_PER_CHIP = 16 << 30  # v5e


def run_cell(arch_name: str, shape_name: str, mesh_name: str, *,
             policy: str = "fsdp_tp", remat: str = "full",
             num_microbatches: int = 1, mla_absorb: bool = True,
             train_impl: str = "naive", moe_dispatch: str = "local") -> dict:
    import dataclasses

    cfg = dataclasses.replace(get_arch(arch_name), moe_dispatch=moe_dispatch)
    shape = SHAPES[shape_name]
    rec: dict = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "policy": policy, "remat": remat,
        "num_microbatches": num_microbatches,
    }
    ok, why = applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    t0 = time.perf_counter()
    try:
        cell = build_cell(cfg, shape, mesh, policy=policy, remat=remat,
                          num_microbatches=num_microbatches,
                          mla_absorb=mla_absorb, train_impl=train_impl)
        lowered = cell.lower()
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        colls = collective_stats(hlo)
        arg_b = int(ma.argument_size_in_bytes)
        out_b = int(ma.output_size_in_bytes)
        tmp_b = int(ma.temp_size_in_bytes)
        # arguments and (donated) outputs alias; peak ~ args + temps
        peak = arg_b + tmp_b
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            arg_bytes=arg_b,
            out_bytes=out_b,
            temp_bytes=tmp_b,
            peak_bytes=peak,
            fits_hbm=bool(peak <= HBM_PER_CHIP),
            hbm_frac=round(peak / HBM_PER_CHIP, 3),
            flops_per_device=float(ca.get("flops", -1.0)),
            bytes_per_device=float(ca.get("bytes accessed", -1.0)),
            collectives=colls,
            hlo_len=len(hlo),
        )
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc(limit=8))
    return rec


def _key(r: dict) -> str:
    return "|".join([r["arch"], r["shape"], r["mesh"], r["policy"],
                     r.get("remat", "full"),
                     str(r.get("num_microbatches", 1))])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--policy", default="fsdp_tp",
                    choices=["tp", "fsdp_tp", "fsdp"])
    ap.add_argument("--moe-dispatch", default="local", choices=["local", "a2a"])
    ap.add_argument("--remat", default="full")
    # 8 microbatches keeps train-step activation memory within HBM for every
    # assigned arch (see EXPERIMENTS.md §Dry-run); ignored by serve cells.
    ap.add_argument("--num-microbatches", type=int, default=8)
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = {_key(r): r for r in json.load(f)}

    n_dev = jax.device_count()
    print(f"devices: {n_dev}")
    todo = [(a, s, m) for a in archs for s in shapes for m in meshes]
    for i, (a, s, m) in enumerate(todo):
        probe = {"arch": a, "shape": s, "mesh": m, "policy": args.policy,
                 "remat": args.remat,
                 "num_microbatches": args.num_microbatches}
        if _key(probe) in results and results[_key(probe)]["status"] in (
                "ok", "skipped"):
            continue
        t0 = time.perf_counter()
        rec = run_cell(a, s, m, policy=args.policy, remat=args.remat,
                       num_microbatches=args.num_microbatches,
                       moe_dispatch=args.moe_dispatch)
        dt = time.perf_counter() - t0
        results[_key(rec)] = rec
        with open(args.out, "w") as f:
            json.dump(list(results.values()), f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f"compile={rec['compile_s']}s "
                     f"hbm={rec['hbm_frac']:.2f} "
                     f"colls={rec['collectives']['total_count']}")
        elif status == "error":
            extra = rec["error"][:120]
        print(f"[{i + 1}/{len(todo)}] {a} x {s} x {m}: {status} "
              f"({dt:.1f}s) {extra}", flush=True)

    bad = [r for r in results.values() if r["status"] == "error"]
    print(f"done: {len(results)} cells, {len(bad)} errors")
    if bad:
        for r in bad:
            print("  ERROR:", r["arch"], r["shape"], r["mesh"], "-", r["error"])
        raise SystemExit(1)


if __name__ == "__main__":
    main()
