import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analyzer: per (arch x shape x mesh) compute/memory/collective terms.

Methodology (see DESIGN.md §5): ``cost_analysis()`` counts a ``lax.scan``
body ONCE regardless of trip count (verified), so whole-program numbers are
useless for scanned models.  Instead we lower small **depth variants** of
each architecture with the layer scans *unrolled* (1 vs 2 layers per layer
type, full widths, production shardings) and solve the linear system

    cost(variant) = base + sum_unit  n_unit(variant) * per_unit

for per-layer-type and base costs; totals are then reconstructed with the
real layer counts.  Collective bytes are parsed from each variant's HLO (all
collectives are top-level once unrolled).

Terms per chip (v5e): compute = FLOPs / 197e12, memory = bytes / 819e9,
collective = sum(op_bytes * factor) / 50e9 (ring all-reduce factor 2).
"""

import argparse
import dataclasses
import json
import time

from repro.configs import SHAPES, applicable, get_arch
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link


# ---------------------------------------------------------------------------
# Depth variants per family
# ---------------------------------------------------------------------------

def _with_layers(cfg: ModelConfig, n: int, **extra) -> ModelConfig:
    return dataclasses.replace(cfg, num_layers=n, **extra)


def depth_plan(cfg: ModelConfig):
    """Returns (probes: {name: cfg}, units: {unit: full_count},
    solve: {unit: (probe_hi, probe_lo)}, base_expr: (probe, {unit: n})).

    per_unit = cost[probe_hi] - cost[probe_lo];
    base     = cost[base_probe] - sum n_unit * per_unit.
    """
    if cfg.family in ("dense", "vlm", "ssm"):
        probes = {"L1": _with_layers(cfg, 1), "L2": _with_layers(cfg, 2)}
        return (probes, {"layer": cfg.num_layers},
                {"layer": ("L2", "L1")}, ("L1", {"layer": 1}))
    if cfg.family == "moe":
        moe1 = dataclasses.replace(cfg.moe, n_dense_layers=1)
        moe2 = dataclasses.replace(cfg.moe, n_dense_layers=2)
        probes = {
            "A": _with_layers(cfg, 2, moe=moe1),   # 1 dense + 1 moe
            "B": _with_layers(cfg, 3, moe=moe1),   # 1 dense + 2 moe
            "C": _with_layers(cfg, 3, moe=moe2),   # 2 dense + 1 moe
        }
        nd = cfg.moe.n_dense_layers
        return (probes,
                {"moe_layer": cfg.num_layers - nd, "dense_layer": nd},
                {"moe_layer": ("B", "A"), "dense_layer": ("C", "A")},
                ("A", {"moe_layer": 1, "dense_layer": 1}))
    if cfg.family == "hybrid":
        rec_r = dataclasses.replace(cfg.rec, block_pattern=("r",))
        rec_a = dataclasses.replace(cfg.rec, block_pattern=("a",))
        probes = {
            "R1": _with_layers(cfg, 1, rec=rec_r),
            "R2": _with_layers(cfg, 2, rec=rec_r),
            "A1": _with_layers(cfg, 1, rec=rec_a),
        }
        pat = cfg.rec.block_pattern
        full = [pat[i % len(pat)] for i in range(cfg.num_layers)]
        n_rec = sum(1 for c in full if c == "r")
        n_attn = cfg.num_layers - n_rec
        return (probes, {"rec_layer": n_rec, "attn_layer": n_attn},
                {"rec_layer": ("R2", "R1"), "attn_layer": ("A1", "__base__")},
                ("R1", {"rec_layer": 1}))
    if cfg.family == "encdec":
        probes = {
            "A": _with_layers(cfg, 1, encoder_layers=1),
            "B": _with_layers(cfg, 1, encoder_layers=2),
            "C": _with_layers(cfg, 2, encoder_layers=1),
        }
        return (probes,
                {"enc_layer": cfg.encoder_layers, "dec_layer": cfg.num_layers},
                {"enc_layer": ("B", "A"), "dec_layer": ("C", "A")},
                ("A", {"enc_layer": 1, "dec_layer": 1}))
    raise ValueError(cfg.family)


def _probe_cost(cfg_small: ModelConfig, shape: ShapeConfig, mesh, *,
                policy: str, remat: str, mla_absorb: bool,
                train_impl: str) -> dict:
    cell = build_cell(cfg_small, shape, mesh, policy=policy, remat=remat,
                      scan_unroll=True, num_microbatches=1,
                      mla_absorb=mla_absorb, train_impl=train_impl,
                      donate=False)
    compiled = cell.lower().compile()
    ca = compiled.cost_analysis() or {}
    st = collective_stats(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        # wire-format bytes (f32 CPU-legalization artifact halved; see
        # hlo_stats.collective_stats) are the primary collective metric
        "coll_bytes": {k: float(v) for k, v in st["wire_bytes"].items()},
        "coll_bytes_raw": {k: float(v) for k, v in st["bytes"].items()},
    }


_DICT_KEYS = ("coll_bytes", "coll_bytes_raw")


def _combine(a: dict, b: dict, sign: float = 1.0) -> dict:
    out = {"flops": a["flops"] + sign * b["flops"],
           "bytes": a["bytes"] + sign * b["bytes"]}
    for dk in _DICT_KEYS:
        da, db = a.get(dk, {}), b.get(dk, {})
        out[dk] = {k: da.get(k, 0.0) + sign * db.get(k, 0.0)
                   for k in set(da) | set(db)}
    return out


def _scale(a: dict, s: float) -> dict:
    out = {"flops": a["flops"] * s, "bytes": a["bytes"] * s}
    for dk in _DICT_KEYS:
        out[dk] = {k: v * s for k, v in a.get(dk, {}).items()}
    return out


def _clamp(a: dict) -> dict:
    out = {"flops": max(a["flops"], 0.0), "bytes": max(a["bytes"], 0.0)}
    for dk in _DICT_KEYS:
        out[dk] = {k: max(v, 0.0) for k, v in a.get(dk, {}).items()}
    return out


def coll_seconds(coll_bytes: dict) -> float:
    secs = 0.0
    for op, b in coll_bytes.items():
        secs += (2.0 if op == "all-reduce" else 1.0) * b / ICI_BW
    return secs


# ---------------------------------------------------------------------------
# Analytic HBM-traffic model (the memory roofline term)
# ---------------------------------------------------------------------------
# ``cost_analysis()['bytes accessed']`` sums operand bytes of every HLO op
# with no fusion awareness — measured ~45x real traffic for fused TPU
# execution.  The memory term therefore uses a transparent structural model
# (verified against napkin math per family); the HLO number is still
# reported as ``hlo_bytes_upper``.

def analytic_memory_bytes(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                          policy: str, remat: str, train_impl: str) -> dict:
    from repro.models.model_zoo import analytic_param_count

    n_chips = mesh.size
    tp = mesh.shape["model"]
    dp = n_chips // tp
    dt = 2  # bf16
    P_total = analytic_param_count(cfg) * dt
    shards = n_chips if policy == "fsdp_tp" else tp
    W = P_total / shards                      # resident param bytes per chip
    # active params actually touched per token (MoE reads only routed experts)
    P_active = analytic_param_count(cfg, active_only=True) * dt / shards

    B_loc = max(shape.global_batch // dp, 1)
    S = shape.seq_len
    D = cfg.d_model
    act_unit = B_loc * S * D * dt             # one activation tensor / chip
    L = cfg.num_layers + cfg.encoder_layers

    detail = {}
    if shape.kind == "train":
        mdt = 2 if P_total / dt > 100e9 else 4
        # fwd read + bwd read of weights; grad write+read; adam m/v r/w;
        # param write.  FSDP gathers add one local write+read of the shard.
        detail["weights"] = P_active * 3 + (P_total / shards) * (
            2 * 2 + 2 * (mdt / dt) * 2 / 2)   # grads(acc dtype~f32) + moments
        # ~8 fusion-boundary activation tensors per layer; full remat remat
        # rereads them (x1.5)
        act_factor = 8 * (1.5 if remat != "none" else 1.0)
        detail["activations"] = act_factor * act_unit * L
        if train_impl == "naive" and cfg.family not in ("ssm",):
            Hl = max(cfg.num_heads // tp, 1)
            n_attn = _attn_layer_count(cfg)
            detail["attn_scores"] = 4 * B_loc * Hl * S * S * dt * n_attn
        Vl = cfg.vocab_size / tp
        detail["logits"] = 3 * B_loc * S * Vl * 4
    elif shape.kind == "prefill":
        detail["weights"] = P_active
        detail["activations"] = 4 * act_unit * L
        detail["cache_write"] = _cache_bytes(cfg, shape, n_chips)
    else:  # decode
        detail["weights"] = P_active
        detail["cache_read"] = _cache_bytes(cfg, shape, n_chips)
        detail["activations"] = 4 * B_loc * 1 * D * dt * L
    detail["total"] = sum(detail.values())
    return detail


def _attn_layer_count(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        pat = cfg.rec.block_pattern
        full = [pat[i % len(pat)] for i in range(cfg.num_layers)]
        return sum(1 for c in full if c == "a")
    if cfg.family == "ssm":
        return 0
    return cfg.num_layers + 2 * cfg.encoder_layers  # encdec: self+cross approx


def _cache_bytes(cfg: ModelConfig, shape: ShapeConfig, n_chips: int) -> float:
    """Global decode-cache bytes / chips (caches shard across the mesh)."""
    from repro.models.model_zoo import input_specs
    import numpy as np
    import jax

    specs = input_specs(cfg, dataclasses.replace(shape, kind="decode",
                                                 name="tmp"))
    total = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                for l in jax.tree.leaves(specs["caches"]))
    return total / n_chips


# ---------------------------------------------------------------------------
# MODEL_FLOPS (the 6ND / 2ND usefulness yardstick)
# ---------------------------------------------------------------------------

def model_flops_total(cfg: ModelConfig, shape: ShapeConfig) -> float:
    from repro.models.model_zoo import analytic_param_count

    n_active = analytic_param_count(cfg, active_only=True)
    if not cfg.tie_embeddings:
        n_active -= cfg.vocab_size * cfg.d_model   # input embedding lookup
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * shape.tokens


# ---------------------------------------------------------------------------
# Cell analysis
# ---------------------------------------------------------------------------

def analyze_cell(arch_name: str, shape_name: str, mesh_name: str, *,
                 policy: str = "fsdp_tp", remat: str = "full",
                 mla_absorb: bool = True, train_impl: str = "naive",
                 moe_dispatch: str = "local") -> dict:
    cfg = dataclasses.replace(get_arch(arch_name), moe_dispatch=moe_dispatch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
           "policy": policy, "remat": remat, "mla_absorb": mla_absorb,
           "train_impl": train_impl, "moe_dispatch": moe_dispatch}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = mesh.size

    probes, units, solve, (base_probe, base_units) = depth_plan(cfg)
    kw = dict(policy=policy, remat=remat, mla_absorb=mla_absorb,
              train_impl=train_impl)
    t0 = time.perf_counter()
    costs = {name: _probe_cost(c, shape, mesh, **kw)
             for name, c in probes.items()}

    per_unit: dict = {}
    deferred = []
    for unit, (hi, lo) in solve.items():
        if lo == "__base__":
            deferred.append((unit, hi))
            continue
        per_unit[unit] = _clamp(_combine(costs[hi], costs[lo], -1.0))
    base = costs[base_probe]
    for unit, n in base_units.items():
        if unit in per_unit:
            base = _combine(base, _scale(per_unit[unit], n), -1.0)
    base = _clamp(base)
    for unit, hi in deferred:   # e.g. hybrid attn layer = A1 - base
        per_unit[unit] = _clamp(_combine(costs[hi], base, -1.0))

    total = dict(base)
    for unit, n in units.items():
        total = _combine(total, _scale(per_unit[unit], n))

    mem_detail = analytic_memory_bytes(cfg, shape, mesh, policy=policy,
                                       remat=remat, train_impl=train_impl)
    compute_s = total["flops"] / PEAK_FLOPS
    memory_s = mem_detail["total"] / HBM_BW
    collective_s = coll_seconds(total["coll_bytes"])
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values())
    mf_dev = model_flops_total(cfg, shape) / n_chips
    roofline_frac = ((mf_dev / PEAK_FLOPS) / step_s) if step_s > 0 else 0.0

    rec.update(
        status="ok",
        analysis_s=round(time.perf_counter() - t0, 1),
        n_chips=n_chips,
        per_unit=per_unit,
        base=base,
        totals=total,
        compute_s=compute_s,
        memory_s=memory_s,
        memory_detail=mem_detail,
        hlo_bytes_upper=total["bytes"],
        collective_s=collective_s,
        collective_s_raw=coll_seconds(total.get("coll_bytes_raw", {})),
        bottleneck=bottleneck,
        step_seconds_lower_bound=step_s,
        model_flops_per_device=mf_dev,
        hlo_flops_per_device=total["flops"],
        useful_flops_ratio=(mf_dev / total["flops"]) if total["flops"] else 0,
        roofline_fraction=roofline_frac,
        tokens_per_second_per_chip=(shape.tokens / n_chips / step_s)
        if step_s else 0.0,
    )
    return rec


def _key(r: dict) -> str:
    return "|".join([r["arch"], r["shape"], r["mesh"], r["policy"],
                     r["remat"], str(r["mla_absorb"]), r["train_impl"],
                     r.get("moe_dispatch", "local")])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--policy", default="fsdp_tp")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--train-impl", default="naive")
    ap.add_argument("--no-mla-absorb", action="store_true")
    ap.add_argument("--moe-dispatch", default="local", choices=["local", "a2a"])
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.configs.registry import ARCHS

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = {_key(r): r for r in json.load(f)}

    todo = [(a, s, m) for a in archs for s in shapes for m in meshes]
    for i, (a, s, m) in enumerate(todo):
        probe = {"arch": a, "shape": s, "mesh": m, "policy": args.policy,
                 "remat": args.remat, "mla_absorb": not args.no_mla_absorb,
                 "train_impl": args.train_impl,
                 "moe_dispatch": args.moe_dispatch}
        if _key(probe) in results and results[_key(probe)]["status"] in (
                "ok", "skipped"):
            continue
        try:
            rec = analyze_cell(a, s, m, policy=args.policy, remat=args.remat,
                               mla_absorb=not args.no_mla_absorb,
                               train_impl=args.train_impl,
                               moe_dispatch=args.moe_dispatch)
        except Exception as e:  # noqa: BLE001
            import traceback

            rec = dict(probe)
            rec.update(status="error", error=f"{type(e).__name__}: {e}",
                       trace=traceback.format_exc(limit=8))
        results[_key(rec)] = rec
        with open(args.out, "w") as f:
            json.dump(list(results.values()), f, indent=1)
        if rec["status"] == "ok":
            print(f"[{i+1}/{len(todo)}] {a} x {s} x {m}: "
                  f"bottleneck={rec['bottleneck']} "
                  f"step>={rec['step_seconds_lower_bound']:.4f}s "
                  f"roofline={rec['roofline_fraction']:.3f} "
                  f"useful={rec['useful_flops_ratio']:.2f}", flush=True)
        else:
            print(f"[{i+1}/{len(todo)}] {a} x {s} x {m}: {rec['status']} "
                  f"{rec.get('error', rec.get('reason', ''))[:110]}",
                  flush=True)


if __name__ == "__main__":
    main()
