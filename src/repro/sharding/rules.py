"""Sharding rules: param/batch/cache PartitionSpecs for every architecture.

Conventions
-----------
* mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
  multi-pod.  Batch shards over all of ``("pod", "data")``; tensor/expert
  parallelism rides ``"model"``.
* Two policies:
    - ``tp``      — params replicated over data axes (classic DP x TP);
    - ``fsdp_tp`` — additionally shards a non-TP dimension of each large
      matrix over ``"data"`` (ZeRO-3-style; XLA inserts the all-gathers).
  ``fsdp_tp`` is the default: it is the only layout where the biggest
  assigned arch (deepseek-v3-671b + optimizer state) fits v5e HBM.
* A dimension is only sharded when divisible by the axis size; otherwise it
  falls back to replication (e.g. GQA kv-heads = 4 < model=16, batch=1 for
  long_500k).

The rules are *name-and-rank* based over the param pytree produced by
``repro.models``; stacked-layer params (under segments/stacks) get a leading
``None`` for the scan dimension.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0 and n >= size


def _axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    s = 1
    for n in names:
        s *= mesh.shape[n]
    return s


class ShardingRules:
    """Three layout policies:

    tp       — params replicated over data; tensor parallel over "model".
               Weights stay device-resident: right for decode/serving.
    fsdp_tp  — tp + ZeRO-3-style sharding of one extra dim over "data".
               Default: the only layout where deepseek-v3 + optimizer fits.
    fsdp     — pure ZeRO/DP: no tensor parallelism at all; batch shards over
               *every* mesh axis and weights shard over ("data","model").
               Removes the per-layer TP activation all-reduces entirely —
               the §Perf beyond-paper layout for dense training.
    """

    def __init__(self, cfg: ModelConfig, mesh, policy: str = "fsdp_tp"):
        assert policy in ("tp", "fsdp_tp", "fsdp"), policy
        self.cfg = cfg
        self.mesh = mesh
        self.policy = policy
        self.tp = "model"
        self.tp_size = mesh.shape["model"]
        self.dp = dp_axes_of(mesh)
        self.dp_size = _axis_size(mesh, self.dp)

    @property
    def batch_axes(self) -> tuple[str, ...]:
        """Axes the batch dimension shards over (model too under pure fsdp,
        except for MoE archs whose expert-parallel region needs 'model')."""
        if self.policy == "fsdp" and not self.cfg.moe.enabled:
            return self.dp + ("model",)
        return self.dp

    # -- helpers -------------------------------------------------------------
    def _fsdp(self, dim: int) -> Any:
        """Axis/axes for the ZeRO-sharded dimension of a weight."""
        if self.policy == "fsdp":
            axes = ("data", "model")
            if _div(dim, _axis_size(self.mesh, axes)):
                return axes
            if _div(dim, self.mesh.shape.get("data", 1)):
                return "data"
            return None
        if self.policy == "fsdp_tp" and _div(dim, self.mesh.shape.get("data", 1)):
            return "data"
        return None

    def _tp(self, dim: int) -> Any:
        if self.policy == "fsdp":
            return None
        return self.tp if _div(dim, self.tp_size) else None

    # -- per-leaf rule --------------------------------------------------------
    def spec_for(self, name: str, shape: tuple[int, ...], stacked: bool) -> P:
        base_shape = shape[1:] if stacked else shape
        spec = self._base_spec(name, base_shape)
        if stacked:
            spec = P(None, *spec)
        return spec

    def _base_spec(self, name: str, s: tuple[int, ...]) -> P:
        tp, fsdp = self._tp, self._fsdp
        if name == "embedding":                      # (V, D)
            if self.policy == "fsdp":
                # shard the vocab (non-contraction) dim: lookup is a masked
                # gather + small psum; sharding D would force full-D gathers
                return P(fsdp(s[0]), None)
            return P(tp(s[0]), fsdp(s[1]))
        if name == "lm_head":                        # (D, V)
            if self.policy == "fsdp":
                # vocab-sharded output: logits stay sharded through the CE
                # (no logits all-reduce, no weight gather)
                return P(None, fsdp(s[1]))
            return P(fsdp(s[0]), tp(s[1]))
        if name in ("wq", "wk", "wv"):               # (D, H, hd)
            return P(fsdp(s[0]), tp(s[1]), None)
        if name == "wo":                             # (H, hd, D)
            return P(tp(s[0]), None, fsdp(s[2]))
        if name in ("wq_a", "wkv_a"):                # (D, r)
            return P(fsdp(s[0]), None)
        if name in ("wq_b", "wk_b", "wv_b"):         # (r, H, hd)
            return P(None, tp(s[1]), None)
        if name in ("w_up", "w_gate") and len(s) == 2:   # mlp (D, F)
            return P(fsdp(s[0]), tp(s[1]))
        if name == "w_down" and len(s) == 2:             # mlp (F, D)
            return P(tp(s[0]), fsdp(s[1]))
        if name in ("w_up", "w_gate", "w_down") and len(s) == 3:
            # moe expert stacks (E, D, F) / (E, F, D)
            if self.cfg.moe_dispatch == "a2a":
                axes = ("data", "model")
                if _div(s[0], _axis_size(self.mesh, axes)):
                    return P(axes, None, None)   # resident 2D EP
            if name == "w_down":
                return P(tp(s[0]), None, fsdp(s[2]))
            return P(tp(s[0]), fsdp(s[1]), None)
        if name == "router":                         # (D, E) - small, replicated
            return P(None, None)
        if name in ("w_z", "w_x", "w_dt") and len(s) == 2:  # ssm (D, di|H)
            return P(fsdp(s[0]), tp(s[1]))
        if name in ("w_B", "w_C"):                   # ssm (D, N) - N small
            return P(fsdp(s[0]), None)
        if name == "conv_x":                         # (K, di)
            return P(None, tp(s[1]))
        if name in ("conv_B", "conv_C"):             # (K, N)
            return P(None, None)
        if name in ("dt_bias", "A_log", "D_skip"):   # (H,)
            return P(tp(s[0]))
        if name == "norm_scale":                     # (di,)
            return P(tp(s[0]))
        if name in ("w_in",):                        # rec (D, W)
            return P(fsdp(s[0]), tp(s[1]))
        if name == "conv_w":                         # rec (K, W)
            return P(None, tp(s[1]))
        if name in ("w_a", "w_x") and len(s) == 3:   # rec block-diag (nb, bs, bs)
            return P(tp(s[0]), None, None)
        if name in ("b_a", "b_x", "lambda_p"):       # (W,)
            return P(tp(s[0]))
        if name == "w_out":                          # rec (W, D)
            return P(tp(s[0]), fsdp(s[1]))
        # norms, scalars, q_norm/k_norm, everything else: replicated
        return P(*([None] * len(s)))

    # -- whole-tree specs -----------------------------------------------------
    def param_specs(self, abstract_params) -> Any:
        stacked_markers = ("segments", "enc_stack", "dec_stack")

        def rule(path, leaf):
            names = [k.key for k in path if hasattr(k, "key")]
            stacked = any(n in stacked_markers for n in names)
            return self.spec_for(names[-1] if names else "", leaf.shape, stacked)

        return jax.tree_util.tree_map_with_path(rule, abstract_params)

    def param_shardings(self, abstract_params) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.param_specs(abstract_params))

    # -- batches ---------------------------------------------------------------
    def _batch_dim(self, b: int) -> Any:
        """Shard batch over the policy's batch axes when divisible."""
        axes = self.batch_axes
        if _div(b, _axis_size(self.mesh, axes)):
            return axes
        if _div(b, self.dp_size):
            return self.dp
        return None

    def batch_specs(self, abstract_batch) -> Any:
        def rule(path, leaf):
            bdim = self._batch_dim(leaf.shape[0]) if leaf.ndim else None
            rest = [None] * (leaf.ndim - 1)
            if leaf.ndim == 0:
                return P()
            return P(bdim, *rest)

        return jax.tree_util.tree_map_with_path(rule, abstract_batch)

    def cache_specs(self, abstract_caches) -> Any:
        """Decode caches: stacked (L, B, ...) -> shard batch + head dims."""
        def rule(path, leaf):
            names = [k.key for k in path if hasattr(k, "key")]
            name = names[-1] if names else ""
            if name == "kv_pos":
                return P(*([None] * leaf.ndim))
            # leading layer-stack dim, then batch
            if leaf.ndim >= 2:
                bdim = self._batch_dim(leaf.shape[1])
                rest = [None] * (leaf.ndim - 2)
                # shard kv-head / head dims over model where they exist
                if name in ("k", "v", "cross_k", "cross_v") and leaf.ndim == 5:
                    # (L, B, cap, Hkv, hd): prefer kv-head sharding; when the
                    # heads don't divide the model axis, shard the sequence
                    # dim instead (context-parallel cache) so a 32k x 128
                    # cache never replicates 16x.
                    tp_h = self._tp(leaf.shape[3])
                    tp_s = self._tp(leaf.shape[2]) if tp_h is None else None
                    rest = [tp_s, tp_h, None]
                elif name == "ckv" and leaf.ndim == 4 and bdim is None:
                    # MLA latent cache (L, B, cap, r) at batch=1: shard cap
                    rest = [self._tp(leaf.shape[2]), None]
                elif name == "ssm_state" and leaf.ndim == 5:
                    # (L, B, H, P, N)
                    rest = [self._tp(leaf.shape[2]), None, None]
                elif name == "h" and leaf.ndim == 3:
                    # (L, B, W)
                    rest = [self._tp(leaf.shape[2])]
                elif name in ("x",) and leaf.ndim == 4:
                    # conv state (L, B, K-1, di)
                    rest = [None, self._tp(leaf.shape[3])]
                elif name == "conv_state" and leaf.ndim == 4:
                    rest = [None, self._tp(leaf.shape[3])]
                elif name == "ckv" and leaf.ndim == 4:
                    # (L, B, cap, r): replicate r
                    rest = [None, None]
                elif name == "k_pe" and leaf.ndim == 4:
                    rest = [None, None]
                return P(None, bdim, *rest)
            return P(*([None] * leaf.ndim))

        return jax.tree_util.tree_map_with_path(rule, abstract_caches)

    def shardings_for(self, tree, kind: str) -> Any:
        specs = {"params": self.param_specs, "batch": self.batch_specs,
                 "cache": self.cache_specs}[kind](tree)
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs)


def opt_state_specs(rules: ShardingRules, param_specs) -> Any:
    """Adam moments share the param layout; counters replicated."""
    return {
        "m": param_specs,
        "v": param_specs,
        "count": P(),
    }
