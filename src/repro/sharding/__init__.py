from repro.sharding.rules import ShardingRules, dp_axes_of, opt_state_specs

__all__ = ["ShardingRules", "dp_axes_of", "opt_state_specs"]
