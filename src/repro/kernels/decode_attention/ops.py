"""Jit'd public wrapper for the decode-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k, v, pos, *, kv_pos=None, window: int = 0,
                     softcap: float = 0.0, bk: int = 512):
    if kv_pos is None:
        kv_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    return decode_attention_fwd(
        q, k, v, pos, kv_pos, window=window, softcap=softcap, bk=bk,
        interpret=not _on_tpu())
