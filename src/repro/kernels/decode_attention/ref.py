"""Pure-jnp oracle for the decode-attention kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import sdpa_naive


def decode_ref(q, k, v, pos, kv_pos, *, window: int = 0,
               softcap: float = 0.0):
    """q: (B,1,Hq,hd) over cache (B,cap,Hkv,hd) with absolute kv_pos."""
    q_pos = jnp.asarray(pos, jnp.int32).reshape(1)
    return sdpa_naive(q, k, v, causal=True, window=window,
                      q_pos=q_pos, kv_pos=kv_pos, softcap=softcap)
