"""Single-token decode attention kernel for TPU (Pallas).

Serves the ``decode_32k`` / ``long_500k`` shapes: one new query token per
sequence attends over a (possibly ring-buffered) KV cache.

Design:
  * grid ``(batch, kv_head, kv_blocks)``, kv_blocks sequential; the G = Hq/Hkv
    query heads of one kv head are processed together as a (G, hd) tile, so
    the score matmul is (G x hd) @ (hd x BK) — MXU-friendly for GQA groups.
  * the current position ``pos`` is a prefetched scalar (SMEM); cached
    absolute positions ``kv_pos`` ride along as a (1, cap) int32 input so
    ring-buffer slots and unwritten slots (sentinel 2^30) mask naturally:
    keep = kv_pos <= pos (and window).
  * online softmax in VMEM scratch across kv blocks, f32 accumulation.

This kernel is memory-bound by design (reads the whole cache once); the
roofline analysis in EXPERIMENTS.md treats it as the HBM-bandwidth term.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# older JAX spells pltpu.CompilerParams 'TPUCompilerParams'
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, kvp_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, bk: int, window: int,
                   softcap: float, scale: float):
    kb = pl.program_id(2)
    nkb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[0]
    q = q_ref[0, 0, :, :]                      # (G, hd)
    k = k_ref[0, :, 0, :]                      # (BK, hd)
    v = v_ref[0, :, 0, :]                      # (BK, hd)
    kvp = kvp_ref[0, :]                        # (BK,) int32

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale        # (G, BK)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    keep = kvp <= pos
    if window:
        keep = jnp.logical_and(keep, pos - kvp < window)
    s = jnp.where(keep[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = m_new

    @pl.when(kb == nkb - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "bk", "interpret"))
def decode_attention_fwd(q, k, v, pos, kv_pos, *, window: int = 0,
                         softcap: float = 0.0, bk: int = 512,
                         interpret: bool = False):
    """q: (B, 1, Hq, hd); k/v: (B, cap, Hkv, hd); kv_pos: (cap,) int32;
    pos: scalar int32. Returns (B, 1, Hq, hd)."""
    B, one, Hq, hd = q.shape
    _, cap, Hkv, _ = k.shape
    G = Hq // Hkv
    bk = min(bk, cap)
    assert cap % bk == 0, (cap, bk)
    qg = q.reshape(B, Hkv, G, hd)
    kvp2 = kv_pos.reshape(1, cap).astype(jnp.int32)
    scale = hd ** -0.5

    kernel = functools.partial(_decode_kernel, bk=bk, window=window,
                               softcap=softcap, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, cap // bk),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, kb, pos: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, kb, pos: (b, kb, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, kb, pos: (b, kb, h, 0)),
            pl.BlockSpec((1, bk), lambda b, h, kb, pos: (0, kb)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, kb, pos: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32).reshape(1), qg, k, v, kvp2)
    return out.reshape(B, 1, Hq, hd)
