"""Jit'd public wrapper for the SSD chunk-scan kernel."""

from __future__ import annotations

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 256):
    return ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=chunk,
                        interpret=not _on_tpu())
