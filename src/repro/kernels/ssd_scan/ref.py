"""Pure-jnp oracle for the SSD chunk-scan kernel."""

from __future__ import annotations

from repro.models.ssm import ssd_chunked


def ssd_ref(x, dt, A, Bm, Cm, *, chunk: int = 256):
    return ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
