"""Mamba2 SSD chunked-scan kernel for TPU (Pallas).

One grid step processes one (batch, head, chunk) tile entirely in VMEM:

    dA   = dt * A_h                      (cs, 1)   VPU
    L    = tril(exp(dAcs_i - dAcs_j))    (cs, cs)  VPU
    S    = C B^T                         (cs, cs)  MXU
    Ydiag = (S . L) (x dt)               (cs, P)   MXU
    Yoff  = (exp(dAcs) C) state^T        (cs, P)   MXU
    state = state * exp(dAcs[-1]) + (x dt * decay)^T B    (P, N) MXU

The chunk dimension is sequential ("arbitrary"); the (P, N) running state is
carried in f32 VMEM scratch — the inter-chunk recurrence never leaves the
core.  All matmul shapes (cs=128..256, P=64, N=128) are MXU-aligned.  B/C are
shared across heads (single SSD group), so their index maps drop ``h``.

Oracle: ``repro.models.ssm.ssd_chunked``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# older JAX spells pltpu.CompilerParams 'TPUCompilerParams'
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref,
                state_scr, *, cs: int):
    cb = pl.program_id(2)
    ncb = pl.num_programs(2)

    @pl.when(cb == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (cs, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)[:, None]  # (cs, 1)
    A = a_ref[0, 0]                                  # scalar (f32)
    Bm = b_ref[0].astype(jnp.float32)                # (cs, N)
    Cm = c_ref[0].astype(jnp.float32)                # (cs, N)

    dA = dt * A                                      # (cs, 1), <= 0
    dA_cs = jnp.cumsum(dA, axis=0)                   # (cs, 1)

    # --- intra-chunk -----------------------------------------------------
    diff = dA_cs - dA_cs.reshape(1, cs)              # (cs, cs)
    ii = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (cs, cs)
    xdt = x * dt                                     # (cs, P)
    y_diag = jax.lax.dot_general(
        scores * L, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (cs, P)

    # --- off-diagonal (previous chunks' state) ------------------------------
    state = state_scr[...]                           # (P, N)
    c_scaled = Cm * jnp.exp(dA_cs)                   # (cs, N)
    y_off = jax.lax.dot_general(
        c_scaled, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (cs, P)

    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    # --- state update --------------------------------------------------------
    last = dA_cs[cs - 1, 0]
    decay_last = jnp.exp(last - dA_cs)               # (cs, 1)
    contrib = jax.lax.dot_general(
        xdt * decay_last, Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (P, N)
    state_scr[...] = state * jnp.exp(last) + contrib

    @pl.when(cb == ncb - 1)
    def _fin():
        st_ref[0, 0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_fwd(x, dt, A, Bm, Cm, *, chunk: int = 256,
                 interpret: bool = False):
    """x: (B,S,H,P), dt: (B,S,H), A: (H,), Bm/Cm: (B,S,N).

    Returns (y: (B,S,H,P), final_state: (B,H,P,N) f32).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    cs = min(chunk, S)
    assert S % cs == 0, (S, cs)
    grid = (B, H, S // cs)
    A2 = A.astype(jnp.float32).reshape(H, 1)

    kernel = functools.partial(_ssd_kernel, cs=cs)
    y, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cs, 1, P), lambda b, h, cb: (b, cb, h, 0)),
            pl.BlockSpec((1, cs, 1), lambda b, h, cb: (b, cb, h)),
            pl.BlockSpec((1, 1), lambda b, h, cb: (h, 0)),
            pl.BlockSpec((1, cs, N), lambda b, h, cb: (b, cb, 0)),
            pl.BlockSpec((1, cs, N), lambda b, h, cb: (b, cb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cs, 1, P), lambda b, h, cb: (b, cb, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, cb: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A2, Bm, Cm)
    return y, st
