"""Jit'd public wrapper for the RG-LRU scan kernel."""

from __future__ import annotations

import jax

from repro.kernels.rglru_scan.kernel import rglru_scan_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def rglru_scan(a, b, *, bs: int = 256, bw: int = 512):
    return rglru_scan_fwd(a, b, bs=bs, bw=bw, interpret=not _on_tpu())
