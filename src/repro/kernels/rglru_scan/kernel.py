"""RG-LRU linear-recurrence scan kernel for TPU (Pallas).

Computes h_t = a_t * h_{t-1} + b_t over the sequence, given precomputed
gates a, b (the gate matmuls stay in XLA where the MXU already runs them
well — the *recurrence* is the memory-latency-bound part worth a kernel).

Design:
  * grid ``(batch, width_blocks, seq_blocks)``; the sequence dimension is
    sequential ("arbitrary") and the carried state h lives in a (1, BW) f32
    VMEM scratch — one HBM round-trip per (BS, BW) tile instead of one per
    timestep.
  * within a tile the recurrence steps over BS timesteps with VPU ops on
    (1, BW) lanes — W is the 128-lane dimension, so all 128 lanes advance
    per cycle.
  * the final state (for decode handoff) is written once per (b, wb).

Oracle: ``repro.models.rglru.rglru_ref`` (associative scan).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# older JAX spells pltpu.CompilerParams 'TPUCompilerParams'
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _rglru_kernel(a_ref, b_ref, h_ref, hfin_ref, state_scr, *, bs: int):
    sb = pl.program_id(2)
    nsb = pl.num_programs(2)

    @pl.when(sb == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    a = a_ref[0].astype(jnp.float32)          # (BS, BW)
    b = b_ref[0].astype(jnp.float32)          # (BS, BW)

    def body(t, h):
        h = a[t][None, :] * h + b[t][None, :]
        h_ref[0, t, :] = h[0].astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, body, state_scr[...])
    state_scr[...] = h

    @pl.when(sb == nsb - 1)
    def _fin():
        hfin_ref[0, :] = h[0].astype(hfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "bw", "interpret"))
def rglru_scan_fwd(a, b, *, bs: int = 256, bw: int = 512,
                   interpret: bool = False):
    """a, b: (B, S, W) -> (h: (B, S, W), h_final: (B, W)) in f32."""
    B, S, W = a.shape
    bs = min(bs, S)
    bw = min(bw, W)
    assert S % bs == 0 and W % bw == 0, (S, bs, W, bw)
    grid = (B, W // bw, S // bs)

    kernel = functools.partial(_rglru_kernel, bs=bs)
    h, h_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda bb, wb, sb: (bb, sb, wb)),
            pl.BlockSpec((1, bs, bw), lambda bb, wb, sb: (bb, sb, wb)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bw), lambda bb, wb, sb: (bb, sb, wb)),
            pl.BlockSpec((1, bw), lambda bb, wb, sb: (bb, wb)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return h, h_final
