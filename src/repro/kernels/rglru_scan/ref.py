"""Pure-jnp oracle for the RG-LRU scan kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b):
    """h_t = a_t h_{t-1} + b_t via associative scan; returns (h, h_final)."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (af, bf), axis=1)
    return h, h[:, -1, :]
