"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import sdpa_naive


def sdpa_ref(q, k, v, *, causal: bool = True, window: int = 0,
             softcap: float = 0.0):
    """Reference scaled-dot-product attention (materializes scores)."""
    Sq, Skv = q.shape[1], k.shape[1]
    return sdpa_naive(q, k, v, causal=causal, window=window,
                      q_pos=jnp.arange(Sq), kv_pos=jnp.arange(Skv),
                      softcap=softcap)
