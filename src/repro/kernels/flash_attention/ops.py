"""Jit'd public wrapper for the flash-attention kernel.

On CPU hosts (this container) the kernel runs with ``interpret=True``; on a
real TPU it lowers to Mosaic.  ``repro.models.attention.sdpa(impl="pallas")``
routes here.
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, q_pos=None, kv_pos=None,
                    bq: int = 128, bk: int = 128):
    """Drop-in for sdpa(...): positions must be contiguous from 0."""
    del q_pos, kv_pos  # kernel assumes contiguous [0, S) positions
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, interpret=not _on_tpu())
