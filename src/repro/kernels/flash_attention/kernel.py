"""FlashAttention forward kernel for TPU (Pallas).

TPU-native design (not a CUDA port):
  * 4-D grid ``(batch, q_head, q_blocks, kv_blocks)``; the last dimension is
    sequential ("arbitrary"), so the online-softmax state for one (b, h, qb)
    lives in VMEM scratch across kv steps — the canonical TPU flash layout.
  * BlockSpecs tile q/out by (BQ, hd) and k/v by (BK, hd) into VMEM; both
    matmuls are MXU-shaped (BQ x hd x BK and BQ x BK x hd) with f32
    accumulation via ``preferred_element_type``.
  * GQA folds into the index maps: q-head h reads kv-head ``h // group``.
  * causal + sliding-window masking from block-local iotas; fully-masked kv
    blocks are skipped with ``pl.when`` (no MXU work issued).

Validated against ``ref.sdpa_ref`` in interpret mode (tests/test_kernels_*).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# older JAX spells pltpu.CompilerParams 'TPUCompilerParams'
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, causal: bool, window: int,
                  softcap: float, scale: float, kv_len: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    nkb = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qb * bq
    k_start = kb * bk

    # Skip kv blocks that are entirely masked out.
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window:
        run = jnp.logical_and(run, k_start + bk - 1 >= q_start - window + 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, :, 0, :]                     # (BQ, hd)
        k = k_ref[0, :, 0, :]                     # (BK, hd)
        v = v_ref[0, :, 0, :]                     # (BK, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        keep = kpos < kv_len
        if causal:
            keep = jnp.logical_and(keep, kpos <= qpos)
        if window:
            keep = jnp.logical_and(keep, qpos - kpos < window)
        s = jnp.where(keep, s, NEG_INF)

        m_prev = m_scr[...]                       # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                    # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)           # (BQ, 1)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)   # (BQ, hd)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    @pl.when(kb == nkb - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "bq", "bk",
                              "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0, bq: int = 128, bk: int = 128,
                        interpret: bool = False):
    """q: (B, Sq, Hq, hd); k/v: (B, Skv, Hkv, hd) -> (B, Sq, Hq, hd)."""
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, hd_v = v.shape
    assert hd_v == hd and k.shape == v.shape, "flash kernel needs hd_k == hd_v"
    group = Hq // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    grid = (B, Hq, Sq // bq, Skv // bk)
    scale = hd ** -0.5

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, window=window,
        softcap=softcap, scale=scale, kv_len=Skv)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, qb, kb: (b, qb, h, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, qb, kb, g=group: (b, kb, h // g, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, qb, kb, g=group: (b, kb, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda b, h, qb, kb: (b, qb, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
