"""Chrome-trace-event exporter (loadable in Perfetto / chrome://tracing).

Emits the JSON object format ``{"traceEvents": [...]}`` with complete
(``ph: "X"``) events plus ``ph: "M"`` metadata naming each process and
thread.  Mapping:

* one *process* (pid) per trace — process_name is ``"<name> <trace_id>"``,
* one *thread* (tid) per span-name prefix (the segment before the first
  ``.``), so ``router.queue``, ``engine.admit`` and ``monitor.execute``
  land on separate, labelled rows,
* ``ts``/``dur`` in microseconds of the trace's (possibly virtual) clock,
* ``args`` carries the span labels plus ``span_id``/``parent_id`` so the
  original tree is recoverable from the export alone.

Unfinished spans are exported with ``dur`` measured to the trace clock's
now, flagged with ``args.unfinished``.
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def chrome_trace_events(traces: Iterable[Any]) -> Dict[str, Any]:
    events: List[Dict[str, Any]] = []
    for pid, tr in enumerate(traces, start=1):
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0,
                       "args": {"name": f"{tr.name} {tr.trace_id}"}})
        tids: Dict[str, int] = {}
        links = getattr(tr, "links", None) or []
        for sp in tr.spans():
            prefix = sp.name.split(".", 1)[0]
            tid = tids.get(prefix)
            if tid is None:
                tid = tids[prefix] = len(tids) + 1
                events.append({"name": "thread_name", "ph": "M",
                               "pid": pid, "tid": tid,
                               "args": {"name": prefix}})
            end = sp.end_t if sp.end_t is not None else tr.clock()
            args = {k: _jsonable(v) for k, v in sp.labels.items()}
            args["span_id"] = sp.span_id
            args["parent_id"] = sp.parent_id
            args["trace_id"] = tr.trace_id
            if sp.end_t is None:
                args["unfinished"] = True
            if links and sp.parent_id == 0:
                # cross-trace links ride on the root event: a recovery
                # trace names the trace it continues
                args["links"] = json.dumps(links)
            events.append({
                "name": sp.name,
                "cat": prefix,
                "ph": "X",
                "ts": sp.start_t * 1e6,
                "dur": max(0.0, end - sp.start_t) * 1e6,
                "pid": pid,
                "tid": tid,
                "args": args,
            })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def export_chrome_trace(tracer: Any, path: str,
                        include_live: bool = True) -> str:
    """Write a tracer's retained traces to ``path`` as Chrome-trace JSON."""
    doc = chrome_trace_events(tracer.traces(include_live=include_live))
    with open(path, "w") as f:
        json.dump(doc, f)
    return path


def validate_chrome_trace(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Sanity-check an exported document; returns summary stats.

    Raises ``ValueError`` on malformed events or a disconnected span tree
    (a parent_id that resolves to no span in the same trace).
    """
    if "traceEvents" not in doc:
        raise ValueError("missing traceEvents")
    spans_by_trace: Dict[Any, Dict[int, int]] = {}
    complete = 0
    for ev in doc["traceEvents"]:
        for field in ("name", "ph", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"event missing {field!r}: {ev}")
        if ev["ph"] == "M":
            continue
        if ev["ph"] != "X":
            raise ValueError(f"unexpected ph {ev['ph']!r}")
        if "ts" not in ev or "dur" not in ev:
            raise ValueError(f"complete event missing ts/dur: {ev}")
        complete += 1
        args = ev.get("args", {})
        tid_key = (ev["pid"], args.get("trace_id"))
        spans_by_trace.setdefault(tid_key, {})[args["span_id"]] = \
            args["parent_id"]
    for key, spans in spans_by_trace.items():
        roots = [s for s, p in spans.items() if p == 0]
        if len(roots) != 1:
            raise ValueError(f"trace {key}: expected 1 root, got {roots}")
        for sid, pid_ in spans.items():
            if pid_ != 0 and pid_ not in spans:
                raise ValueError(
                    f"trace {key}: span {sid} orphaned (parent {pid_})")
    return {"traces": len(spans_by_trace), "spans": complete}
