"""Observability: span tracing + Chrome-trace export (see tracer.py)."""

from .export import (chrome_trace_events, export_chrome_trace,
                     validate_chrome_trace)
from .tracer import Span, Trace, Tracer

__all__ = ["Span", "Trace", "Tracer", "chrome_trace_events",
           "export_chrome_trace", "validate_chrome_trace"]
