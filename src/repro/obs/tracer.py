"""Span-based tracing with an injectable clock.

One `Tracer` serves both planes: the live serving path clocks spans with
``time.perf_counter`` (the default), while the virtual-clock
``ServingSimulator`` injects ``lambda: self.now`` so simulated traces carry
deterministic virtual timestamps.  A *trace* is a tree of *spans* keyed by a
caller-chosen ``trace_id`` (the request rid for request traces, an
``engine:itN`` key for per-iteration decode traces, ``"cluster"`` for the
orchestration plane).

Retention is bounded two ways:

* a ring of the most recent ``capacity`` finished traces, admitted with
  probability ``sample_rate`` (seeded ``random.Random`` — deterministic
  under a fixed seed), and
* a keep-slowest heap of the ``keep_slowest`` finished traces with the
  largest root-span duration, which are retained *regardless* of the
  probabilistic decision — slow outliers are exactly the traces worth
  keeping.

Spans are cheap plain objects; when a ``Tracer`` is absent every call site
degrades to ``span=None`` and the serving path pays nothing.
"""

from __future__ import annotations

import heapq
import itertools
import json
import random
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional


class Span:
    """One timed operation inside a trace.  ``end()`` is idempotent."""

    __slots__ = ("trace", "span_id", "parent_id", "name", "labels",
                 "start_t", "end_t")

    def __init__(self, trace: "Trace", span_id: int, parent_id: int,
                 name: str, labels: Dict[str, Any], start_t: float):
        self.trace = trace
        self.span_id = span_id
        self.parent_id = parent_id          # 0 == root (no parent)
        self.name = name
        self.labels = labels
        self.start_t = start_t
        self.end_t: Optional[float] = None

    def child(self, name: str, t0: Optional[float] = None,
              **labels: Any) -> "Span":
        return self.trace.span(name, parent=self, t0=t0, **labels)

    def annotate(self, **labels: Any) -> "Span":
        self.labels.update(labels)
        return self

    def end(self, t: Optional[float] = None) -> "Span":
        if self.end_t is None:
            self.end_t = self.trace.clock() if t is None else t
        return self

    @property
    def duration(self) -> float:
        end = self.end_t if self.end_t is not None else self.trace.clock()
        return max(0.0, end - self.start_t)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.end()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r} id={self.span_id} "
                f"parent={self.parent_id} dur={self.duration:.6f})")


class Trace:
    """A bounded tree of spans sharing one trace_id.

    Span storage is a ring (``max_spans``) so a runaway producer cannot
    grow a trace without bound; the root span is held separately and never
    evicted.  New spans default their parent to the root, so the tree stays
    connected even when a call site lacks the precise parent.
    """

    def __init__(self, tracer: "Tracer", trace_id: str, name: str,
                 labels: Dict[str, Any], max_spans: int = 4096,
                 sampled: bool = True):
        self.tracer = tracer
        self.trace_id = trace_id
        self.name = name
        self.clock: Callable[[], float] = tracer.clock
        self.sampled = sampled
        self._lock = threading.Lock()
        self._ids = itertools.count(2)      # 1 is the root
        self._spans: deque = deque(maxlen=max_spans)
        self.dropped_spans = 0
        self.finished = False
        # cross-trace links: a recovery trace points back at the trace it
        # continues (pre-crash / pre-evacuation), so tooling can stitch a
        # request's whole lifetime into one timeline
        self.links: List[Dict[str, Any]] = []
        self.root = Span(self, 1, 0, name, dict(labels), self.clock())

    def link(self, other: "Trace", relation: str = "follows") -> "Trace":
        """Record that this trace ``relation``s ``other`` (e.g. a replayed
        request's new trace ``recovers`` its crashed predecessor)."""
        self.links.append({"trace_id": other.trace_id,
                           "name": other.name,
                           "relation": relation})
        return self

    def span(self, name: str, parent: Optional[Span] = None,
             t0: Optional[float] = None, **labels: Any) -> Span:
        pid = (parent.span_id if parent is not None else self.root.span_id)
        with self._lock:
            sid = next(self._ids)
            sp = Span(self, sid, pid, name, labels,
                      self.clock() if t0 is None else t0)
            if len(self._spans) == self._spans.maxlen:
                self.dropped_spans += 1
            self._spans.append(sp)
        return sp

    def spans(self) -> List[Span]:
        """Root first, then retained spans in creation order."""
        with self._lock:
            return [self.root] + list(self._spans)

    def find_spans(self, name: str) -> List[Span]:
        return [s for s in self.spans() if s.name == name]

    def finish(self, t: Optional[float] = None, **labels: Any) -> "Trace":
        """End the root span and hand the trace to tracer retention."""
        if not self.finished:
            self.finished = True
            self.root.annotate(**labels).end(t)
            self.tracer._retire(self)
        return self

    @property
    def duration(self) -> float:
        return self.root.duration

    def to_dict(self) -> Dict[str, Any]:
        return {
            "trace_id": self.trace_id,
            "name": self.name,
            "sampled": self.sampled,
            "finished": self.finished,
            "dropped_spans": self.dropped_spans,
            "duration": self.duration,
            "links": list(self.links),
            "spans": [{
                "span_id": s.span_id,
                "parent_id": s.parent_id,
                "name": s.name,
                "start": s.start_t,
                "end": s.end_t,
                "labels": dict(s.labels),
            } for s in self.spans()],
        }


class Tracer:
    """Factory + bounded retention for traces.

    ``clock`` is injectable (virtual time in the simulator); ``seed`` makes
    the probabilistic sampler deterministic.  Live (unfinished) traces are
    tracked separately so an export mid-run still sees in-flight requests.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None, *,
                 capacity: int = 256, sample_rate: float = 1.0,
                 keep_slowest: int = 8, max_spans_per_trace: int = 4096,
                 seed: int = 0):
        self.clock = clock if clock is not None else time.perf_counter
        self.capacity = capacity
        self.sample_rate = float(sample_rate)
        self.keep_slowest = keep_slowest
        self.max_spans_per_trace = max_spans_per_trace
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=capacity)
        self._slow: list = []                      # min-heap (dur, seq, trace)
        self._seq = itertools.count()
        self._live: Dict[int, Trace] = {}
        self.started = 0
        self.finished = 0

    def start_trace(self, name: str, trace_id: Optional[str] = None,
                    sampled: Optional[bool] = None, **labels: Any) -> Trace:
        with self._lock:
            n = next(self._seq)
            if sampled is None:
                sampled = (self.sample_rate >= 1.0
                           or self._rng.random() < self.sample_rate)
            tr = Trace(self, trace_id if trace_id is not None else f"t{n}",
                       name, labels, max_spans=self.max_spans_per_trace,
                       sampled=sampled)
            self._live[id(tr)] = tr
            self.started += 1
        return tr

    def _retire(self, tr: Trace) -> None:
        with self._lock:
            self._live.pop(id(tr), None)
            self.finished += 1
            if tr.sampled:
                self._ring.append(tr)
            if self.keep_slowest > 0:
                item = (tr.duration, next(self._seq), tr)
                if len(self._slow) < self.keep_slowest:
                    heapq.heappush(self._slow, item)
                elif item[0] > self._slow[0][0]:
                    heapq.heapreplace(self._slow, item)

    def traces(self, include_live: bool = True) -> List[Trace]:
        """Retained traces (ring ∪ keep-slowest), oldest root first."""
        with self._lock:
            out = list(self._ring)
            seen = {id(t) for t in out}
            for _, _, t in self._slow:
                if id(t) not in seen:
                    out.append(t)
                    seen.add(id(t))
            if include_live:
                out.extend(t for t in self._live.values()
                           if id(t) not in seen)
        out.sort(key=lambda t: t.root.start_t)
        return out

    def find(self, trace_id: str) -> Optional[Trace]:
        for t in self.traces():
            if t.trace_id == trace_id:
                return t
        return None

    def event_span(self, name: str, trace_id: Optional[str] = None,
                   **labels: Any) -> Trace:
        """One-shot single-span trace; ``finish()`` it when done (or use as
        a context manager via the returned trace's root span)."""
        return self.start_trace(name, trace_id=trace_id, sampled=True,
                                **labels)

    # -- export ----------------------------------------------------------
    def chrome_trace(self, include_live: bool = True) -> Dict[str, Any]:
        from .export import chrome_trace_events
        return chrome_trace_events(self.traces(include_live=include_live))

    def export(self, path: str, include_live: bool = True) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(include_live=include_live), f)
        return path
