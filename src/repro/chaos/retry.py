"""Bounded retry with exponential backoff + deadline.

The recovery half of the chaos layer: orchestrator actions and monitor
EXECUTEs wrap their fallible calls in ``retry_call`` so a transient fault
(injected or environmental) costs a backoff, not a dead task.  Anything
that is not a ``TransientFault`` — validation errors, ``NodeFailed``,
``InjectedCrash`` — propagates immediately: retrying a deterministic
failure only hides it, and a crash must exercise the crash path.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional, Tuple, Type

from repro.chaos.faults import TransientFault


@dataclass(frozen=True)
class RetryPolicy:
    """``max_attempts`` total tries; backoff doubles from
    ``base_backoff_s`` capped at ``max_backoff_s``; ``deadline_s`` (when
    set) bounds the whole retried call including sleeps."""

    max_attempts: int = 3
    base_backoff_s: float = 0.01
    max_backoff_s: float = 0.5
    deadline_s: Optional[float] = None

    def backoff_s(self, attempt: int) -> float:
        """Sleep before attempt ``attempt + 1`` (attempt is 1-based)."""
        return min(self.max_backoff_s,
                   self.base_backoff_s * (2 ** (attempt - 1)))


DEFAULT_EXECUTE_RETRY = RetryPolicy(max_attempts=3, base_backoff_s=0.01,
                                    max_backoff_s=0.25, deadline_s=5.0)
DEFAULT_ACTION_RETRY = RetryPolicy(max_attempts=3, base_backoff_s=0.05,
                                   max_backoff_s=1.0, deadline_s=15.0)


def retry_call(fn: Callable, policy: RetryPolicy, *,
               retryable: Tuple[Type[BaseException], ...] = (TransientFault,),
               on_retry: Optional[Callable] = None,
               sleep: Callable[[float], None] = time.sleep):
    """Call ``fn()`` with up to ``policy.max_attempts`` tries.

    ``on_retry(attempt, backoff_s, exc)`` fires before each backoff sleep
    (telemetry / span annotation).  The final failure re-raises the last
    retryable exception; non-retryable exceptions propagate on first
    occurrence.
    """
    t0 = time.perf_counter()
    attempt = 1
    while True:
        try:
            return fn()
        except retryable as e:
            backoff = policy.backoff_s(attempt)
            out_of_time = (policy.deadline_s is not None and
                           time.perf_counter() - t0 + backoff
                           > policy.deadline_s)
            if attempt >= policy.max_attempts or out_of_time:
                raise
            if on_retry is not None:
                on_retry(attempt, backoff, e)
            sleep(backoff)
            attempt += 1
