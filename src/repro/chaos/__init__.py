"""Deterministic fault injection + retry scaffolding (chaos engineering
for the live plane).  See ``faults`` for the injection-site catalogue and
``retry`` for the backoff policies the recovery paths use."""

from repro.chaos.faults import (FaultPlan, FaultSpec, InjectedCrash,
                                InjectedFault, TransientFault)
from repro.chaos.retry import (DEFAULT_ACTION_RETRY, DEFAULT_EXECUTE_RETRY,
                               RetryPolicy, retry_call)

__all__ = ["FaultPlan", "FaultSpec", "InjectedCrash", "InjectedFault",
           "TransientFault", "RetryPolicy", "retry_call",
           "DEFAULT_ACTION_RETRY", "DEFAULT_EXECUTE_RETRY"]
