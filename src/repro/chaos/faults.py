"""Deterministic, seeded fault injection for the live plane.

A ``FaultPlan`` is a list of ``FaultSpec`` injection points evaluated at
named *sites* threaded through the stack behind no-op hooks:

    agent.deploy / agent.evict / agent.resume / agent.migrate_in /
    agent.checkpoint / agent.restore / agent.replicate_in / agent.drain /
    agent.remove            node-agent ops (kind: crash | error | delay)
    monitor.execute         per-EXECUTE dispatch (kind: error | delay)
    ckpt.save               per-buffer write during save_snapshot
                            (kind: torn | error — torn raises mid-write,
                            before the manifest publishes)
    ckpt.corrupt            after a successful publish (kind: corrupt —
                            flips bytes in one on-disk buffer file)
    ckpt.restore            before load_snapshot reads (kind: error)
    router.pop              request intake (kind: delay)
    kv.transfer             KV handoff install on the decode replica
                            (kind: torn | error | delay — a torn transfer
                            loses the lane in transit; the request
                            replays through its router lease)

Every decision is a pure function of (seed, spec list, per-site event
counts): two runs with the same plan over the same event sequence fire
identically — the property the chaos soak test relies on.  A site with no
matching spec costs one dict lookup and an int increment; components built
without a plan (``chaos=None``) skip even that.

Exception taxonomy:

* ``TransientFault`` — retryable; the monitor's EXECUTE retry loop and the
  orchestrator's action retries catch exactly this.
* ``InjectedFault`` — a transient injected by a plan (subclass).
* ``InjectedCrash`` — simulated process death mid-operation; never retried
  (crash-consistency, not retry, is what must save the day).
"""

from __future__ import annotations

import random
import threading
from dataclasses import dataclass, field
from typing import List, Optional, Tuple


class TransientFault(RuntimeError):
    """An error worth retrying (injected or environmental)."""


class InjectedFault(TransientFault):
    """Transient failure raised by a FaultPlan."""


class InjectedCrash(RuntimeError):
    """Simulated hard crash (process death) raised by a FaultPlan."""


@dataclass
class FaultSpec:
    """One injection point.

    Triggering (first match wins, evaluated per matching event):
      ``at``    fire on the Nth matching event at ``site`` (1-based);
      ``every`` fire on every Nth matching event;
      ``prob``  fire with this probability (seeded — deterministic).
    ``match`` filters events by substring of the event key (cid, program
    id, path...); empty matches all.  ``max_fires`` bounds total fires.
    """

    site: str
    kind: str = "error"             # error | crash | delay | torn | corrupt
    at: Optional[int] = None
    every: Optional[int] = None
    prob: float = 0.0
    match: str = ""
    max_fires: int = 1
    delay_s: float = 0.0
    note: str = ""
    fires: int = field(default=0, compare=False)


class FaultPlan:
    """Seeded, thread-safe schedule of faults. ``check`` is the only hook
    primitive; ``raise_if``/``maybe_delay`` are convenience wrappers for
    sites with a single sensible reaction."""

    def __init__(self, specs: Optional[List[FaultSpec]] = None, *,
                 seed: int = 0, registry=None):
        self.specs = list(specs or [])
        self.seed = seed
        self.rng = random.Random(seed)
        self.registry = registry
        self.fired: List[Tuple[str, str, str]] = []   # (site, kind, key)
        self._counts: dict = {}
        self._lock = threading.Lock()

    def add(self, spec: FaultSpec) -> "FaultPlan":
        with self._lock:
            self.specs.append(spec)
        return self

    def check(self, site: str, key: str = "") -> Optional[FaultSpec]:
        """Count one event at ``site`` and return the spec that fires on
        it, if any (at most one per event; specs are evaluated in order)."""
        with self._lock:
            hit = None
            for spec in self.specs:
                if spec.site != site or spec.match not in key:
                    continue
                ck = (site, spec.match)
                n = self._counts[ck] = self._counts.get(ck, 0) + 1
                if spec.fires >= spec.max_fires:
                    continue
                fire = ((spec.at is not None and n == spec.at)
                        or (spec.every is not None and n % spec.every == 0)
                        or (spec.prob > 0
                            and self.rng.random() < spec.prob))
                if fire and hit is None:
                    spec.fires += 1
                    hit = spec
                    self.fired.append((site, spec.kind, key))
            if hit is not None and self.registry is not None:
                self.registry.record_event("fault_injected", site=site,
                                           fault=hit.kind, key=key,
                                           note=hit.note)
            return hit

    # -- convenience wrappers -------------------------------------------
    def raise_if(self, site: str, key: str = "") -> None:
        """error -> InjectedFault, crash/torn -> InjectedCrash,
        delay -> sleep."""
        spec = self.check(site, key)
        if spec is None:
            return
        if spec.kind == "delay":
            import time
            time.sleep(spec.delay_s)
            return
        if spec.kind in ("crash", "torn"):
            raise InjectedCrash(f"injected crash at {site} ({key})")
        raise InjectedFault(f"injected fault at {site} ({key})")

    def maybe_delay(self, site: str, key: str = "") -> None:
        spec = self.check(site, key)
        if spec is not None and spec.kind == "delay":
            import time
            time.sleep(spec.delay_s)
