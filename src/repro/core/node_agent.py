"""Node agent: forwards orchestrator requests to the container engine via
CRI, attaching Funky metadata as annotations (paper §3.5, Table 3).  Each
operation and the node's slice occupancy are published into the shared
telemetry registry (repro.scaling.metrics) for the scaling service."""

from __future__ import annotations

import time
from typing import Dict, Optional

from repro.chaos import InjectedFault
from repro.core.cri import (A_PREEMPTIBLE, A_PRIORITY, A_REPLICA_OF,
                            A_SNAPSHOT, A_SOURCE_NODE, A_VFPGA_NUM,
                            ContainerConfig, ContainerEngine)
from repro.core.runtime import TaskStatus
from repro.scaling.metrics import MetricsRegistry


class NodeFailed(RuntimeError):
    pass


class NodeAgent:
    def __init__(self, node_id: str, engine: ContainerEngine,
                 metrics: Optional[MetricsRegistry] = None,
                 failure_domain: Optional[str] = None,
                 chaos=None):
        self.node_id = node_id
        self.engine = engine
        self.chaos = chaos
        self.failed = False
        self._hb = time.time()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # failure/tenant domain label for replica anti-affinity (rack, PDU,
        # host...); defaults to the node itself — every node its own domain
        self.failure_domain = failure_domain or node_id

    def _count_op(self, op: str):
        self.metrics.counter("node_ops_total", node=self.node_id,
                             op=op).inc()
        self.metrics.gauge("node_free_slices", node=self.node_id).set(
            self.engine.runtime.allocator.free_count())

    # -- health ---------------------------------------------------------------
    def heartbeat(self) -> float:
        if self.failed:
            raise NodeFailed(self.node_id)
        self._hb = time.time()
        return self._hb

    def fail(self):
        """Simulate a node crash: agent stops responding."""
        self.failed = True

    def _check(self):
        if self.failed:
            raise NodeFailed(self.node_id)

    def _chaos(self, op: str, cid: str = ""):
        """Fault-plan hook for site ``agent.<op>``: kind ``crash`` marks
        the whole node failed (and surfaces as ``NodeFailed``), ``error``
        raises a retryable ``InjectedFault``, ``delay`` sleeps."""
        if self.chaos is None:
            return
        spec = self.chaos.check(f"agent.{op}", key=f"{self.node_id}:{cid}")
        if spec is None:
            return
        if spec.kind == "delay":
            time.sleep(spec.delay_s)
            return
        if spec.kind == "crash":
            self.fail()
            raise NodeFailed(self.node_id)
        raise InjectedFault(
            f"injected fault at agent.{op} ({self.node_id}:{cid})")

    # -- orchestration ops -> CRI (Table 3) -------------------------------------
    def deploy(self, cid: str, image_ref: str, priority: int = 0,
               preemptible: bool = True):
        self._check()
        self._chaos('deploy', cid)
        self.engine.CreateContainer(ContainerConfig(
            cid=cid, image_ref=image_ref, annotations={
                A_PREEMPTIBLE: "true" if preemptible else "false",
                A_PRIORITY: str(priority),
            }))
        self.engine.StartContainer(cid)
        self._count_op("deploy")

    def evict(self, cid: str):
        self._check()
        self._chaos('evict', cid)
        self.engine.StopContainer(cid)
        self._count_op("evict")

    def resume(self, cid: str):
        self._check()
        self._chaos('resume', cid)
        self.engine.StartContainer(cid)
        self._count_op("resume")

    def migrate_in(self, cid: str, image_ref: str, source_node: str):
        self._check()
        self._chaos('migrate_in', cid)
        self.engine.CreateContainer(ContainerConfig(
            cid=cid, image_ref=image_ref,
            annotations={A_SOURCE_NODE: source_node}))
        self.engine.StartContainer(cid)
        self._count_op("migrate_in")

    def checkpoint(self, cid: str) -> str:
        self._check()
        self._chaos('checkpoint', cid)
        path = self.engine.CheckpointContainer(cid)
        self._count_op("checkpoint")
        return path

    def restore(self, cid: str, snapshot_path: str, image_ref: str = ""):
        self._check()
        self._chaos('restore', cid)
        self.engine.CreateContainer(ContainerConfig(
            cid=cid, image_ref=image_ref,
            annotations={A_SNAPSHOT: snapshot_path}))
        self.engine.StartContainer(cid)
        self._count_op("restore")

    def replicate_in(self, new_cid: str, source_cid: str, source_node: str,
                     image_ref: str = ""):
        self._check()
        self._chaos('replicate_in', new_cid)
        self.engine.CreateContainer(ContainerConfig(
            cid=new_cid, image_ref=image_ref, annotations={
                A_REPLICA_OF: source_cid, A_SOURCE_NODE: source_node}))
        self.engine.StartContainer(new_cid)
        self._count_op("replicate_in")

    def update(self, cid: str, vfpga_num: int):
        self._check()
        self._chaos('update', cid)
        self.engine.UpdateContainerResources(
            cid, {A_VFPGA_NUM: str(vfpga_num)})
        self._count_op("update")

    def drain(self, cid: str, timeout_s: float = 30.0) -> dict:
        """Scale-in prelude: stop the replica's admissions and let its
        in-flight lanes finish (request-boundary decommission) before the
        kill.  Falls through after ``timeout_s`` — the subsequent remove
        then requeues whatever is still unfinished."""
        self._check()
        self._chaos('drain', cid)
        stats = self.engine.DrainContainer(cid, timeout_s=timeout_s)
        self._count_op("drain")
        return stats

    def remove(self, cid: str):
        """Scale-in: kill the replica and delete its record."""
        self._check()
        self._chaos('remove', cid)
        self.engine.RemoveContainer(cid)
        self._count_op("remove")

    # -- introspection ----------------------------------------------------------
    def free_slices(self) -> int:
        self._check()
        return self.engine.runtime.allocator.free_count()

    def num_slices(self) -> int:
        return len(self.engine.runtime.allocator.slices)

    def task_status(self, cid: str) -> Optional[TaskStatus]:
        self._check()
        rec = self.engine.runtime.tasks.get(cid)
        return rec.status if rec else None

    def latest_snapshot(self, cid: str) -> Optional[str]:
        rec = self.engine.runtime.tasks.get(cid)
        return rec.latest_snapshot if rec else None

    def task_progress(self, cid: str) -> Optional[int]:
        """Guest step counter — published into the shared registry as the
        ``task_progress_steps`` series the ``MigrationController`` reads."""
        self._check()
        rec = self.engine.runtime.tasks.get(cid)
        return rec.guest_state.step if rec else None

    def warm_programs(self) -> tuple:
        """Program ids resident in this node's compile ("bitstream") cache
        — the placement layer's warm-cache affinity signal: a node already
        holding a service's programs skips reconfiguration on deploy."""
        self._check()
        return tuple(self.engine.runtime.programs.program_ids())

    def task_programs(self, cid: str) -> Optional[tuple]:
        """Program ids a task's guest needs; the orchestrator caches them
        per image so future replicas can be steered toward warm nodes.
        ``None`` while the guest is still booting (setup not finished —
        ask again later); an empty tuple is a definitive "no programs"."""
        self._check()
        rec = self.engine.runtime.tasks.get(cid)
        if rec is None or rec.status is TaskStatus.CREATED:
            return None
        return tuple(rec.task.program_ids())
