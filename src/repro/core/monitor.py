"""The Funky monitor: a thin per-task hypervisor layer (paper §3.2, §3.4).

One ``Monitor`` supervises one guest task:

* **worker thread** — drains the shared request queue, validates every
  request (buffer ownership, program registration, vSlice memory cap) and
  performs the delegated device work via JAX; async by construction — the
  guest only blocks on SYNC.
* **monitor-side commands** — ``evict`` / ``resume`` / ``checkpoint`` /
  ``migrate_out``, invoked by the Funky runtime (the paper's monitor thread
  exposing an IPC interface).  All of them synchronize to a request boundary
  first — FPGAs (and XLA programs) cannot be suspended mid-flight — and the
  measured *sync wait* is recorded (Fig 9).

State management follows §3.4 exactly: only DIRTY buffers are saved on
evict; ``checkpoint`` optionally keeps the task running; freed device memory
is zeroed (here: references dropped and the table cleared) before the slot is
handed to another tenant.
"""

from __future__ import annotations

import enum
import queue
import threading
import time
from collections import defaultdict
from typing import Any, Optional

import jax

from repro.chaos import DEFAULT_EXECUTE_RETRY, RetryPolicy, TransientFault
from repro.core.programs import Program, ProgramCache
from repro.core.requests import (Completion, Direction, FunkyRequest,
                                 RequestKind)
from repro.core.state import (BufferTable, GuestState, TaskSnapshot,
                              same_avals)
from repro.core.vslice import SliceAllocator, VSlice
from repro.scaling.metrics import MetricsRegistry


class MonitorError(RuntimeError):
    pass


class NoSliceAvailable(MonitorError):
    pass


class DeviceMemoryExceeded(MonitorError):
    pass


class MonitorState(enum.Enum):
    CREATED = "created"
    RUNNING = "running"
    EVICTED = "evicted"
    EXITED = "exited"


class Monitor:
    def __init__(self, task_id: str, allocator: SliceAllocator,
                 programs: Optional[ProgramCache] = None,
                 telemetry: Optional[MetricsRegistry] = None,
                 tracer: Any = None, chaos: Any = None,
                 retry: Optional[RetryPolicy] = None):
        self.task_id = task_id
        # fault injection plan (repro.chaos.FaultPlan) + EXECUTE retry
        # policy; transient EXECUTE failures are retried with backoff
        # *before* any output buffer is written, so a retry is idempotent
        self.chaos = chaos
        self.retry = retry if retry is not None else DEFAULT_EXECUTE_RETRY
        # optional repro.obs.Tracer; guests that submit requests carrying a
        # ``span`` get queue-wait/device/sync child spans hung off it
        self.tracer = tracer
        self.allocator = allocator
        self.programs = programs if programs is not None else ProgramCache()
        self.buffers = BufferTable()
        self.request_queue: "queue.Queue[FunkyRequest]" = queue.Queue()
        self.vslice: Optional[VSlice] = None
        self.state = MonitorState.CREATED
        self._worker: Optional[threading.Thread] = None
        self._last_completion: Optional[Completion] = None
        self._lock = threading.Lock()
        self.metrics: dict = defaultdict(float)
        self.metrics_hist: dict = defaultdict(list)
        # shared node/cluster registry (scaling service); per-task local
        # dicts above stay as the micro-benchmark source (Figs 4-9).
        # Handles are resolved once: inc()/observe() are lock-free, so the
        # per-request dispatch loop never touches the registry lock.
        self.telemetry = (telemetry if telemetry is not None
                          else MetricsRegistry())
        self._tel_count = {
            k.value: self.telemetry.counter("monitor_requests_total",
                                            kind=k.value)
            for k in RequestKind if k is not RequestKind.SHUTDOWN}
        self._tel_hist = {
            k.value: self.telemetry.histogram("monitor_request_seconds",
                                              kind=k.value)
            for k in RequestKind if k is not RequestKind.SHUTDOWN}
        self._tel_sync_wait = self.telemetry.histogram(
            "monitor_sync_wait_seconds")
        self._tel_queue_wait = self.telemetry.histogram(
            "monitor_queue_wait_seconds")
        self._tel_h2d_bytes = self.telemetry.counter(
            "monitor_transfer_bytes_total", direction="h2d")
        self._tel_d2h_bytes = self.telemetry.counter(
            "monitor_transfer_bytes_total", direction="d2h")
        self._tel_exec_retries = self.telemetry.counter(
            "monitor_execute_retries_total")
        self._tel_exec_failed = self.telemetry.counter(
            "monitor_execute_failed_total")
        # execute-signature cache (hot path): (program_id, buffer wiring,
        # const shapes) -> (CompiledEntry, donate_argnums, in spec tokens).
        # A hit skips the per-request jax.tree.map over every arg leaf AND
        # the ProgramCache fingerprint walk; spec tokens (bumped only on
        # shape-changing writes) invalidate it when a buffer is reshaped.
        self._exec_cache: dict = {}

    # ------------------------------------------------------------------
    # Hypercalls (paper §3.2): vfpga_init / vfpga_free
    # ------------------------------------------------------------------
    def vfpga_init(self, program: Program, abstract_args: tuple,
                   donate_argnums: tuple = ()) -> VSlice:
        """Acquire a vSlice and 'reconfigure' it (AOT-compile the program)."""
        t0 = time.perf_counter()
        vs = self.allocator.vfpga_init(self.task_id, program.program_id)
        if vs is None:
            raise NoSliceAvailable(
                f"no free vSlice on node {self.allocator.node_id}")
        self.vslice = vs
        self.programs.register(program)
        entry = self.programs.get_or_compile(
            program.program_id, abstract_args, donate_argnums)
        self.metrics["reconfig_seconds"] += time.perf_counter() - t0
        self.metrics_hist["reconfig"].append(entry.compile_seconds)
        self._spawn_worker()
        self.state = MonitorState.RUNNING
        return vs

    def register_program(self, program: Program, abstract_args: tuple,
                         donate_argnums: tuple = ()):
        """Additional programs on the already-acquired slice."""
        self.programs.register(program)
        self.programs.get_or_compile(program.program_id, abstract_args,
                                     donate_argnums)

    def vfpga_exit(self):
        """Release the slot; zero device memory (paper: isolation, §3.4)."""
        self._stop_worker()
        self.buffers.zero_and_clear()
        # fresh buffers restart spec tokens at zero; drop stale signatures
        self._exec_cache.clear()
        if self.vslice is not None:
            self.allocator.vfpga_free(self.vslice)
            self.vslice = None
        self.state = MonitorState.EXITED

    # ------------------------------------------------------------------
    # Guest-facing request submission (exitless I/O queue)
    # ------------------------------------------------------------------
    def submit(self, req: FunkyRequest) -> Completion:
        if self.state is not MonitorState.RUNNING:
            raise MonitorError(f"monitor not running (state={self.state})")
        if req.span is not None:
            req.enqueue_t = req.span.trace.clock()
        self.request_queue.put(req)
        return req.completion

    # ------------------------------------------------------------------
    # Worker thread
    # ------------------------------------------------------------------
    def _spawn_worker(self):
        t0 = time.perf_counter()
        self._worker = threading.Thread(
            target=self._worker_loop, name=f"funky-worker-{self.task_id}",
            daemon=True)
        self._worker.start()
        self.metrics_hist["worker_spawn"].append(time.perf_counter() - t0)

    def _stop_worker(self):
        if self._worker is None:
            return
        req = FunkyRequest(kind=RequestKind.SHUTDOWN)
        self.request_queue.put(req)
        self._worker.join()
        self._worker = None

    def _worker_loop(self):
        while True:
            req = self.request_queue.get()
            if req.kind is RequestKind.SHUTDOWN:
                req.completion.set()
                return
            t0 = time.perf_counter()
            # queue wait: from request construction (the guest submits
            # immediately after) to the worker picking it up
            qw = max(0.0, t0 - req.completion.submitted_at)
            req.completion.phases = {"kind": req.kind.value,
                                     "queue_wait_s": qw}
            if req.span is not None:
                tc = req.span.trace.clock()
                req.span.child("monitor.queue_wait",
                               t0=req.enqueue_t if req.enqueue_t is not None
                               else tc).end(tc)
                req.mon_span = req.span.child(
                    f"monitor.{req.kind.value.lower()}", t0=tc)
            try:
                value, error = self._handle_with_retry(req), None
            except BaseException as e:  # noqa: BLE001 - forwarded to guest
                value, error = None, e
                if req.mon_span is not None:
                    req.mon_span.annotate(error=repr(e))
            dt = time.perf_counter() - t0
            # phases must be complete before set() wakes the guest
            req.completion.phases["total_s"] = dt
            if req.mon_span is not None:
                req.mon_span.end()
            req.completion.set(value, error=error)
            self._tel_queue_wait.observe(qw)
            self.metrics[f"n_{req.kind.value}"] += 1
            self.metrics_hist[req.kind.value].append(dt)
            self._tel_count[req.kind.value].inc()
            self._tel_hist[req.kind.value].observe(dt)
            self._last_completion = req.completion

    def _handle_with_retry(self, req: FunkyRequest) -> Any:
        """EXECUTEs get bounded retry-with-backoff on ``TransientFault``:
        injection and the device call both happen *before* any
        ``on_execute_write``, so a failed attempt left no partial state.
        Other request kinds fail straight through to the guest."""
        if req.kind is not RequestKind.EXECUTE:
            return self._handle(req)
        from repro.chaos import retry_call

        def on_retry(attempt, backoff_s, exc):
            self._tel_exec_retries.inc()
            self.telemetry.record_event(
                "execute_retry", task=self.task_id,
                program=req.program_id, attempt=attempt,
                backoff_s=backoff_s, error=repr(exc))
            if req.mon_span is not None:
                req.mon_span.child("monitor.retry", attempt=attempt,
                                   backoff_s=backoff_s,
                                   error=repr(exc)).end()

        try:
            return retry_call(lambda: self._handle(req), self.retry,
                              on_retry=on_retry)
        except TransientFault as e:
            self._tel_exec_failed.inc()
            self.telemetry.record_event(
                "execute_failed", task=self.task_id,
                program=req.program_id,
                attempts=self.retry.max_attempts, error=repr(e))
            raise

    # -- request handlers ------------------------------------------------
    def _handle(self, req: FunkyRequest) -> Any:
        if req.kind is RequestKind.MEMORY:
            return self._do_memory(req)
        if req.kind is RequestKind.TRANSFER:
            return self._do_transfer(req)
        if req.kind is RequestKind.EXECUTE:
            return self._do_execute(req)
        if req.kind is RequestKind.SYNC:
            return self._do_sync(req)
        raise MonitorError(f"unknown request {req}")

    def _validate_buffs(self, ids):
        for i in ids:
            if i not in self.buffers:
                raise MonitorError(
                    f"task {self.task_id}: unknown/foreign buffer {i!r}")

    def _do_memory(self, req: FunkyRequest):
        from repro.core.state import tree_bytes

        new_bytes = tree_bytes(req.spec)
        cap = self.vslice.mem_cap_bytes if self.vslice else 0
        if self.buffers.total_bytes() + new_bytes > cap:
            raise DeviceMemoryExceeded(
                f"vSlice memory cap {cap} exceeded by buffer "
                f"{req.buff_id!r} (+{new_bytes} bytes)")
        self.buffers.register(req.buff_id, req.spec, paged=req.paged)
        return req.buff_id

    def _do_transfer(self, req: FunkyRequest):
        from repro.core.state import tree_bytes

        self._validate_buffs([req.buff_id])
        # the transfer call blocks on the device: h2d is the copy-in, d2h
        # blocks until every in-flight program writing the buffer lands
        # (async JAX dispatch) and then copies out — both count as the
        # request's device phase
        t0 = time.perf_counter()
        if req.direction is Direction.H2D:
            nbytes = tree_bytes(req.host_value)
            dev = jax.device_put(req.host_value)
            self.buffers.on_h2d(req.buff_id, req.host_value, dev)
            self._tel_h2d_bytes.inc(nbytes)
            out = None
        else:
            out = self.buffers.on_d2h(req.buff_id)
            nbytes = tree_bytes(out)
            self._tel_d2h_bytes.inc(nbytes)
        device_s = time.perf_counter() - t0
        req.completion.phases.update(bytes=nbytes, device_s=device_s,
                                     direction=req.direction.value)
        if req.mon_span is not None:
            req.mon_span.annotate(buff=req.buff_id, bytes=nbytes,
                                  direction=req.direction.value)
        return out

    @staticmethod
    def _const_sig(c) -> tuple:
        """Shape/dtype signature of a const arg (values are runtime inputs
        to the compiled program, so only the aval matters)."""
        shape = getattr(c, "shape", None)
        if shape is None:
            return (type(c).__name__,)
        return (tuple(shape), str(getattr(c, "dtype", "")))

    def _do_execute(self, req: FunkyRequest):
        t_prep0 = time.perf_counter()
        if self.chaos is not None:
            self.chaos.raise_if("monitor.execute",
                                key=f"{self.task_id}:{req.program_id}")
        self._validate_buffs(list(req.in_buffs) + list(req.out_buffs))
        if req.program_id not in self.programs:
            raise MonitorError(f"program {req.program_id!r} not registered")
        key = (req.program_id, req.in_buffs, req.out_buffs, req.donate,
               tuple(self._const_sig(c) for c in req.const_args))
        # spec tokens cover the out buffers too: an h2d that reshapes a
        # pure-output buffer must invalidate the entry, or a stable-marked
        # write would skip the nbytes walk and corrupt memory-cap accounting
        watched = req.in_buffs + tuple(
            b for b in req.out_buffs if b not in req.in_buffs)
        tokens = tuple(self.buffers.get(i).spec_token for i in watched)
        cached = self._exec_cache.get(key)
        hit = cached is not None and cached[1] == tokens
        if hit:
            entry = cached[0]
            self.metrics["exec_sig_cache_hits"] += 1
        else:
            args_abs = tuple(self.buffers.get(i).device_value
                             for i in req.in_buffs) + tuple(req.const_args)
            abstract = jax.tree.map(
                lambda x: (jax.ShapeDtypeStruct(x.shape, x.dtype)
                           if hasattr(x, "shape") else x), args_abs)
            donate_argnums = ()
            if req.donate:
                donate_argnums = tuple(
                    i for i, b in enumerate(req.in_buffs)
                    if b in req.out_buffs)
            entry = self.programs.get_or_compile(req.program_id, abstract,
                                                 donate_argnums)
        args = tuple(self.buffers.get(i).device_value for i in req.in_buffs)
        args = args + tuple(req.const_args)
        # device phase: the compiled-program call is the only point this
        # path touches the accelerator; everything around it is host work.
        # The runtime dispatches asynchronously — the call returns before
        # the computation finishes — so the phase must close at
        # block_until_ready, not at dispatch: otherwise the compute tail
        # blocks under some *later* request (usually the next EXECUTE's
        # dispatch or a d2h TRANSFER) and gets misattributed as host time
        t_run0 = time.perf_counter()
        prep_s = t_run0 - t_prep0
        sp = req.mon_span
        if sp is not None:
            tc = sp.trace.clock()
            sp.child("execute.sig_lookup", t0=sp.start_t,
                     hit=hit, program=req.program_id).end(tc)
            dev_sp = sp.child("execute.device", t0=tc,
                              program=req.program_id)
        out = jax.block_until_ready(entry.compiled(*args))
        device_s = time.perf_counter() - t_run0
        if sp is not None:
            dev_sp.end()
            sp.annotate(program=req.program_id, sig_hit=hit)
        req.completion.phases.update(prep_s=prep_s, device_s=device_s,
                                     sig_hit=hit, program=req.program_id)
        if len(req.out_buffs) == 1:
            outs = (out,)
        else:
            outs = tuple(out)
            if len(outs) != len(req.out_buffs):
                raise MonitorError(
                    f"program {req.program_id} returned {len(outs)} outputs "
                    f"for {len(req.out_buffs)} out_buffs")
        for buff_id, val in zip(req.out_buffs, outs):
            # a hit means the same entry produced these shapes last time;
            # on a miss, a buffer whose aval is unchanged keeps its spec
            # token, so steady-state programs converge to cache hits
            # instead of re-fingerprinting forever
            stable = hit or same_avals(
                self.buffers.get(buff_id).device_value, val)
            dp = (None if req.dirty_pages is None
                  else req.dirty_pages.get(buff_id))
            self.buffers.on_execute_write(buff_id, val, stable=stable,
                                          dirty_pages=dp)
        if not hit:
            # keyed on the PRE-execute tokens: stable writes leave them
            # unchanged (next call hits), while a shape-changing write
            # bumps its buffer past the stored value, so the stale entry
            # can never be replayed against the new shape
            self._exec_cache[key] = (entry, tokens)
        return None

    def _do_sync(self, req: FunkyRequest):
        # Worker is serial: everything enqueued earlier already dispatched.
        # Block only on buffers written since the last SYNC drained — the
        # rest of the table is already quiescent (Fig 9 sync-wait budget).
        synced = 0
        t0 = time.perf_counter()
        for i in self.buffers.take_unsynced():
            b = self.buffers.get(i)
            if b.device_value is not None:
                jax.block_until_ready(b.device_value)
                synced += 1
        req.completion.phases.update(synced_buffers=synced,
                                     device_s=time.perf_counter() - t0)
        if req.mon_span is not None:
            req.mon_span.annotate(synced_buffers=synced)
        return None

    # ------------------------------------------------------------------
    # Monitor-thread commands (evict / resume / checkpoint), paper §3.4
    # ------------------------------------------------------------------
    def sync_barrier(self) -> float:
        """Wait for all in-flight requests; returns the sync wait seconds."""
        t0 = time.perf_counter()
        req = FunkyRequest(kind=RequestKind.SYNC)
        self.request_queue.put(req)
        req.completion.wait()
        dt = time.perf_counter() - t0
        self.metrics_hist["sync_wait"].append(dt)
        self._tel_sync_wait.observe(dt)
        return dt

    def evict(self) -> dict:
        """Save FPGA context to host memory, release the slot (paper evict)."""
        with self._lock:
            if self.state is not MonitorState.RUNNING:
                raise MonitorError(f"cannot evict from {self.state}")
            t0 = time.perf_counter()
            sync_wait = self.sync_barrier()
            stats = self.buffers.evict_device_state()
            self._stop_worker()
            if self.vslice is not None:
                self.allocator.vfpga_free(self.vslice)
                self.vslice = None
            self.state = MonitorState.EVICTED
            stats["sync_wait_seconds"] = sync_wait
            stats["evict_seconds"] = time.perf_counter() - t0
            self.metrics_hist["evict"].append(stats["evict_seconds"])
            return stats

    def resume(self, allocator: Optional[SliceAllocator] = None) -> dict:
        """Re-acquire a slot (same or different node) and restore buffers."""
        with self._lock:
            if self.state is not MonitorState.EVICTED:
                raise MonitorError(f"cannot resume from {self.state}")
            t0 = time.perf_counter()
            if allocator is not None:
                self.allocator = allocator
            vs = self.allocator.vfpga_init(self.task_id)
            if vs is None:
                raise NoSliceAvailable(
                    f"no free vSlice on node {self.allocator.node_id}")
            self.vslice = vs
            stats = self.buffers.restore_device_state()
            self._spawn_worker()
            self.state = MonitorState.RUNNING
            stats["resume_seconds"] = time.perf_counter() - t0
            self.metrics_hist["resume"].append(stats["resume_seconds"])
            return stats

    def checkpoint(self, guest_state: GuestState,
                   keep_running: bool = True) -> TaskSnapshot:
        """Snapshot VM+device state; optionally keep the task running."""
        with self._lock:
            t0 = time.perf_counter()
            if self.state is MonitorState.RUNNING:
                self.sync_barrier()
                for i in self.buffers.dirty_ids():
                    self.buffers.on_d2h(i)
                if not keep_running:
                    stats = self.buffers.evict_device_state()
                    self._stop_worker()
                    if self.vslice is not None:
                        self.allocator.vfpga_free(self.vslice)
                        self.vslice = None
                    self.state = MonitorState.EVICTED
                    del stats
            snap = TaskSnapshot(
                task_id=self.task_id,
                guest_state=guest_state.clone(),
                buffers=self.buffers.host_snapshot(),
                program_ids=self.programs.program_ids(),
                step=guest_state.step,
                versions=self.buffers.versions(),
                buffer_specs=self.buffers.spec_map(),
            )
            self.metrics_hist["checkpoint"].append(time.perf_counter() - t0)
            return snap

    def load_snapshot(self, snap: TaskSnapshot):
        """Initialize buffers from a snapshot (restore path). Buffers stay on
        the host until ``resume`` re-materializes them on a slice."""
        self.buffers.load_snapshot(snap.buffers, snap.buffer_specs)
        self.state = MonitorState.EVICTED
