"""CRI-compatible layer (paper §3.5, Table 3).

Funky-specific metadata travels in CRI **annotations** (unstructured
key-value pairs in the CRI message structure) so the spec is never violated:

    funky.io/preemptible   "true" | "false"
    funky.io/priority      int
    funky.io/source-node   node that holds the task's context (migrate/restore)
    funky.io/snapshot      checkpoint path (restore)
    funky.io/replica-of    source cid (horizontal scaling)
    funky.io/vfpga-num     vertical-scaling target

The ``ContainerEngine`` (containerd stand-in) translates CRI calls into
Funky OCI runtime commands exactly per Table 3.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.runtime import FunkyRuntime, TaskStatus
from repro.core.tasks import TaskImage

A_PREEMPTIBLE = "funky.io/preemptible"
A_PRIORITY = "funky.io/priority"
A_SOURCE_NODE = "funky.io/source-node"
A_SNAPSHOT = "funky.io/snapshot"
A_REPLICA_OF = "funky.io/replica-of"
A_VFPGA_NUM = "funky.io/vfpga-num"


@dataclass
class ContainerConfig:
    """CRI CreateContainerRequest (subset)."""
    cid: str
    image_ref: str
    annotations: Dict[str, str] = field(default_factory=dict)


class ContainerEngine:
    """CRI RuntimeService -> Funky OCI runtime command translation."""

    def __init__(self, runtime: FunkyRuntime, images: Dict[str, TaskImage],
                 peers: Optional[Dict[str, "ContainerEngine"]] = None):
        self.runtime = runtime
        self.images = images
        self.peers = peers if peers is not None else {}
        self._pending: Dict[str, dict] = {}      # cid -> deferred create info

    # -- CRI RuntimeService ------------------------------------------------
    def CreateContainer(self, config: ContainerConfig) -> str:
        ann = config.annotations
        if A_SNAPSHOT in ann or A_SOURCE_NODE in ann or A_REPLICA_OF in ann:
            # migrate / restore / replicate target: defer to StartContainer
            self._pending[config.cid] = {
                "image_ref": config.image_ref, "annotations": dict(ann)}
            return config.cid
        image = self.images[config.image_ref]
        self.runtime.create(config.cid, image, annotations={
            "preemptible": ann.get(A_PREEMPTIBLE, "true"),
            "priority": ann.get(A_PRIORITY, "0"),
        })
        return config.cid

    def StartContainer(self, cid: str):
        pending = self._pending.pop(cid, None)
        if pending is not None:
            ann = pending["annotations"]
            if A_SNAPSHOT in ann:                       # restore (Table 3)
                self.runtime.restore(cid, ann[A_SNAPSHOT])
                return
            if A_REPLICA_OF in ann:                     # horizontal scaling
                src_engine = self.peers[ann[A_SOURCE_NODE]]
                src_engine.runtime.replicate(
                    ann[A_REPLICA_OF], self.runtime, new_cid=cid)
                return
            # migrate: pull context from the source node's runtime
            src_engine = self.peers[ann[A_SOURCE_NODE]]
            self.runtime.resume(cid, source=src_engine.runtime)
            return
        rec = self.runtime.tasks[cid]
        if rec.status is TaskStatus.EVICTED:
            self.runtime.resume(cid)                    # resume (Table 3)
        else:
            self.runtime.start(cid)                     # deploy

    def StopContainer(self, cid: str):
        rec = self.runtime.tasks[cid]
        if rec.preemptible and rec.status in (TaskStatus.CREATED,
                                              TaskStatus.RUNNING):
            # evict waits for setup/sync (the paper's request-boundary rule)
            self.runtime.evict(cid)                     # evict, keep context
        else:
            self.runtime.kill(cid)

    def CheckpointContainer(self, cid: str) -> str:
        return self.runtime.checkpoint(cid)

    def UpdateContainerResources(self, cid: str,
                                 annotations: Dict[str, str]):
        if A_VFPGA_NUM in annotations:
            self.runtime.update(cid, int(annotations[A_VFPGA_NUM]))

    def DrainContainer(self, cid: str, timeout_s: float = 30.0) -> dict:
        """Graceful-decommission prelude to RemoveContainer: stop the
        task's admissions and wait (bounded) for held work to finish."""
        if cid not in self.runtime.tasks:
            return {"drained": True, "waited_s": 0.0}
        return self.runtime.drain(cid, timeout_s=timeout_s)

    def RemoveContainer(self, cid: str):
        rec = self.runtime.tasks.get(cid)
        if rec and rec.status is TaskStatus.RUNNING:
            self.runtime.kill(cid)
        self.runtime.delete(cid)
