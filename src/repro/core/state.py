"""Buffer state machine and task snapshots (paper §3.4).

Each logical buffer (params, optimizer state, KV caches, input batches, ...)
is tracked with one of three states:

    INIT   allocated, no meaningful device contents
    SYNC   device contents mirrored by a host copy (or reproducible from one)
    DIRTY  device contents newer than any host copy

Eviction/checkpointing saves **only DIRTY buffers** — the paper's key
optimization for cheap preemption (Fig 7): input batches stay SYNC after
their H2D transfer and cost nothing to evict; params/optimizer become DIRTY
after every EXECUTE that writes them.
"""

from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import numpy as np


class BufferState(enum.Enum):
    INIT = "init"
    SYNC = "sync"
    DIRTY = "dirty"


def tree_bytes(tree: Any) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            total += int(leaf.size) * np.dtype(leaf.dtype).itemsize
    return total


def to_host(tree: Any) -> Any:
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)


def same_avals(a: Any, b: Any) -> bool:
    """True when two pytrees have identical structure and leaf shape/dtype
    (values ignored) — the invariant the monitor's execute-signature cache
    keys on."""
    if a is None or b is None:
        return False
    if jax.tree.structure(a) != jax.tree.structure(b):
        return False
    return all(
        getattr(x, "shape", None) == getattr(y, "shape", None)
        and getattr(x, "dtype", None) == getattr(y, "dtype", None)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@dataclass
class Buffer:
    buff_id: str
    spec: Any                           # abstract pytree
    state: BufferState = BufferState.INIT
    device_value: Any = None            # pytree of jax arrays (or None)
    host_value: Any = None              # pytree of numpy arrays (or None)
    nbytes: int = 0
    version: int = 0                    # bumped on every device-side write
    spec_token: int = 0                 # bumped only when shapes may change

    def __post_init__(self):
        if not self.nbytes:
            self.nbytes = tree_bytes(self.spec)


class BufferTable:
    """Per-task buffer registry with state transitions (monitor-owned)."""

    def __init__(self):
        self._buffers: Dict[str, Buffer] = {}
        # buffers written (h2d or execute) since the last SYNC drain; the
        # monitor's SYNC blocks on exactly these instead of the whole table
        self._unsynced: set = set()

    # -- registry -------------------------------------------------------------
    def register(self, buff_id: str, spec: Any) -> Buffer:
        if buff_id in self._buffers:
            raise KeyError(f"buffer {buff_id!r} already exists")
        b = Buffer(buff_id=buff_id, spec=spec)
        self._buffers[buff_id] = b
        return b

    def get(self, buff_id: str) -> Buffer:
        return self._buffers[buff_id]

    def __contains__(self, buff_id: str) -> bool:
        return buff_id in self._buffers

    def ids(self):
        return list(self._buffers)

    def total_bytes(self) -> int:
        return sum(b.nbytes for b in self._buffers.values())

    # -- transitions ----------------------------------------------------------
    def on_h2d(self, buff_id: str, host_value: Any, device_value: Any):
        b = self.get(buff_id)
        # same-shaped overwrites (streamed prompts/batches) keep the spec
        # token so downstream execute-signature cache entries stay warm
        if not same_avals(b.device_value, device_value):
            b.spec_token += 1
        b.host_value = host_value
        b.device_value = device_value
        b.state = BufferState.SYNC
        b.nbytes = tree_bytes(device_value)
        b.version += 1
        self._unsynced.add(buff_id)

    def on_d2h(self, buff_id: str) -> Any:
        b = self.get(buff_id)
        b.host_value = to_host(b.device_value)
        b.state = BufferState.SYNC
        return b.host_value

    def on_execute_write(self, buff_id: str, device_value: Any,
                         stable: bool = False):
        """``stable=True`` marks a write whose shapes are known to match the
        previous contents (same compiled program, same signature): the
        per-leaf byte walk is skipped and the spec token is preserved, so
        the monitor's execute-signature cache stays valid."""
        b = self.get(buff_id)
        b.device_value = device_value
        b.state = BufferState.DIRTY
        if not stable:
            b.nbytes = tree_bytes(device_value)
            b.spec_token += 1
        b.version += 1
        self._unsynced.add(buff_id)

    # -- sync tracking --------------------------------------------------------
    def take_unsynced(self) -> list:
        """Ids written since the last drain; clears the pending set."""
        out = list(self._unsynced)
        self._unsynced.clear()
        return out

    def unsynced_count(self) -> int:
        return len(self._unsynced)

    # -- evict / restore --------------------------------------------------------
    def dirty_ids(self):
        return [i for i, b in self._buffers.items()
                if b.state is BufferState.DIRTY]

    def evict_device_state(self) -> dict:
        """Save DIRTY buffers to host, drop all device references.

        Returns stats {saved_bytes, skipped_bytes, n_dirty}.
        """
        saved = skipped = n_dirty = 0
        for b in self._buffers.values():
            if b.state is BufferState.DIRTY:
                b.host_value = to_host(b.device_value)
                b.state = BufferState.SYNC
                saved += b.nbytes
                n_dirty += 1
            else:
                skipped += b.nbytes
            b.device_value = None
        self._unsynced.clear()          # every device ref was just dropped
        return {"saved_bytes": saved, "skipped_bytes": skipped,
                "n_dirty": n_dirty}

    def restore_device_state(self, put_fn=None) -> dict:
        """Re-materialize device buffers from host copies."""
        put = put_fn or jax.device_put
        restored = 0
        for b in self._buffers.values():
            if b.host_value is not None:
                b.device_value = put(b.host_value)
                b.state = BufferState.SYNC
                restored += b.nbytes
                self._unsynced.add(b.buff_id)   # device_put is async
        return {"restored_bytes": restored}

    def host_snapshot(self) -> dict:
        """Host-side view for checkpointing: {buff_id: host pytree}."""
        out = {}
        for i, b in self._buffers.items():
            if b.host_value is not None:
                out[i] = b.host_value
        return out

    def versions(self) -> dict:
        return {i: b.version for i, b in self._buffers.items()}

    def spec_map(self) -> dict:
        """Abstract registry of every buffer (incl. INIT ones) — snapshots
        carry this so restore re-registers buffers that had no value yet."""
        return {i: b.spec for i, b in self._buffers.items()}

    def load_snapshot(self, snap: dict, specs: dict | None = None):
        for i, spec in (specs or {}).items():
            if i not in self._buffers:
                self._buffers[i] = Buffer(buff_id=i, spec=spec)
        for i, host_value in snap.items():
            if i not in self._buffers:
                self._buffers[i] = Buffer(buff_id=i, spec=None, nbytes=0)
            b = self._buffers[i]
            b.host_value = host_value
            b.state = BufferState.SYNC
            b.nbytes = tree_bytes(host_value)

    def zero_and_clear(self):
        """Release everything (monitor zeroes freed device memory, §3.4)."""
        self._buffers.clear()
        self._unsynced.clear()


@dataclass
class GuestState:
    """The "VM state" of a task: everything the guest needs to resume.

    Funky snapshots the unikernel's vCPU + dirty guest pages; our guests are
    step-wise resumable tasks, so the VM state is their explicit progress
    record (step counter, RNG seed, data-stream position, user dict).
    """
    step: int = 0
    seed: int = 0
    data_position: int = 0
    user: dict = field(default_factory=dict)

    def clone(self) -> "GuestState":
        return GuestState(self.step, self.seed, self.data_position,
                          dict(self.user))


@dataclass
class TaskSnapshot:
    """A full checkpoint: buffers + guest (VM) state + provenance."""
    task_id: str
    guest_state: GuestState
    buffers: dict                       # buff_id -> host pytree
    program_ids: tuple = ()
    created_at: float = field(default_factory=time.time)
    step: int = 0
    versions: dict = field(default_factory=dict)   # buff_id -> write version
    buffer_specs: dict = field(default_factory=dict)  # full registry

    def nbytes(self) -> int:
        return tree_bytes(self.buffers)
