"""Buffer state machine and task snapshots (paper §3.4).

Each logical buffer (params, optimizer state, KV caches, input batches, ...)
is tracked with one of three states:

    INIT   allocated, no meaningful device contents
    SYNC   device contents mirrored by a host copy (or reproducible from one)
    DIRTY  device contents newer than any host copy

Eviction/checkpointing saves **only DIRTY buffers** — the paper's key
optimization for cheap preemption (Fig 7): input batches stay SYNC after
their H2D transfer and cost nothing to evict; params/optimizer become DIRTY
after every EXECUTE that writes them.

Buffers registered as **paged** refine dirtiness to page granularity: every
leaf of a paged buffer has the page axis as axis 0 (the serving engine's KV
page pool is the canonical case), and EXECUTE requests report which pages
they wrote.  Evict/checkpoint then serialize only the dirty pages, merging
them into the prior host copy — a decode iteration that touches 4 of 4096
pool pages costs 4 pages of d2h, not the whole pool.
"""

from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import numpy as np


class BufferState(enum.Enum):
    INIT = "init"
    SYNC = "sync"
    DIRTY = "dirty"


def tree_bytes(tree: Any) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            total += int(leaf.size) * np.dtype(leaf.dtype).itemsize
    return total


def to_host(tree: Any) -> Any:
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)


def same_avals(a: Any, b: Any) -> bool:
    """True when two pytrees have identical structure and leaf shape/dtype
    (values ignored) — the invariant the monitor's execute-signature cache
    keys on."""
    if a is None or b is None:
        return False
    if jax.tree.structure(a) != jax.tree.structure(b):
        return False
    return all(
        getattr(x, "shape", None) == getattr(y, "shape", None)
        and getattr(x, "dtype", None) == getattr(y, "dtype", None)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@dataclass
class Buffer:
    buff_id: str
    spec: Any                           # abstract pytree
    state: BufferState = BufferState.INIT
    device_value: Any = None            # pytree of jax arrays (or None)
    host_value: Any = None              # pytree of numpy arrays (or None)
    nbytes: int = 0
    version: int = 0                    # bumped on every device-side write
    spec_token: int = 0                 # bumped only when shapes may change
    # page-granular dirtiness (paged buffers only): every leaf's axis 0 is
    # the page axis; ``page_dirty`` holds ids written since the last host
    # sync, and ``None`` means "unknown — treat every page as dirty"
    paged: bool = False
    page_dirty: Optional[set] = None
    # True while host_value is aliased by a TaskSnapshot: the next merge
    # must copy-on-write instead of patching the snapshot's arrays
    host_shared: bool = False

    def __post_init__(self):
        if not self.nbytes:
            self.nbytes = tree_bytes(self.spec)

    @property
    def n_pages(self) -> int:
        leaves = jax.tree.leaves(
            self.device_value if self.device_value is not None else self.spec)
        return int(leaves[0].shape[0]) if leaves else 0

    def mark_pages_dirty(self, page_ids) -> None:
        if page_ids is None:
            self.page_dirty = None          # degraded to whole-buffer dirty
        elif self.page_dirty is not None:
            self.page_dirty.update(int(p) for p in page_ids)

    def merge_dirty_pages_to_host(self) -> int:
        """Pull only the dirty pages d2h, merging into the host copy.

        Returns the bytes actually saved; falls back to a full ``to_host``
        when no host copy exists or dirtiness is unknown.  Clears the dirty
        set — the host copy is current afterwards.
        """
        n = self.n_pages
        if (not self.paged or self.host_value is None
                or self.page_dirty is None or n == 0):
            self.host_value = to_host(self.device_value)
            self.host_shared = False    # fresh arrays, nothing aliased
            saved = self.nbytes
        elif not self.page_dirty:
            saved = 0
        else:
            ids = np.asarray(sorted(self.page_dirty), np.int64)
            cow = self.host_shared     # a snapshot aliases the host copy

            def merge(host_leaf, dev_leaf):
                out = np.asarray(host_leaf)
                # copy when a snapshot aliases us (COW) or when the leaf
                # is a read-only device_get view; afterwards the buffer
                # owns a writable array and merges patch it in place,
                # keeping steady-state evicts O(pages touched)
                if cow or not out.flags.writeable:
                    out = out.copy()
                out[ids] = np.asarray(jax.device_get(dev_leaf[ids]))
                return out

            self.host_value = jax.tree.map(merge, self.host_value,
                                           self.device_value)
            self.host_shared = False
            saved = int(round(self.nbytes * len(ids) / n))
        self.page_dirty = set() if self.paged else None
        return saved


class BufferTable:
    """Per-task buffer registry with state transitions (monitor-owned)."""

    def __init__(self):
        self._buffers: Dict[str, Buffer] = {}
        # buffers written (h2d or execute) since the last SYNC drain; the
        # monitor's SYNC blocks on exactly these instead of the whole table
        self._unsynced: set = set()

    # -- registry -------------------------------------------------------------
    def register(self, buff_id: str, spec: Any,
                 paged: bool = False) -> Buffer:
        if buff_id in self._buffers:
            raise KeyError(f"buffer {buff_id!r} already exists")
        b = Buffer(buff_id=buff_id, spec=spec, paged=paged)
        self._buffers[buff_id] = b
        return b

    def get(self, buff_id: str) -> Buffer:
        return self._buffers[buff_id]

    def __contains__(self, buff_id: str) -> bool:
        return buff_id in self._buffers

    def ids(self):
        return list(self._buffers)

    def total_bytes(self) -> int:
        return sum(b.nbytes for b in self._buffers.values())

    # -- transitions ----------------------------------------------------------
    def on_h2d(self, buff_id: str, host_value: Any, device_value: Any):
        b = self.get(buff_id)
        # same-shaped overwrites (streamed prompts/batches) keep the spec
        # token so downstream execute-signature cache entries stay warm
        if not same_avals(b.device_value, device_value):
            b.spec_token += 1
        b.host_value = host_value
        b.device_value = device_value
        b.state = BufferState.SYNC
        b.nbytes = tree_bytes(device_value)
        b.version += 1
        if b.paged:
            b.page_dirty = set()        # host copy just became current
            b.host_shared = False       # fresh reference replaced the alias
        self._unsynced.add(buff_id)

    def on_d2h(self, buff_id: str) -> Any:
        b = self.get(buff_id)
        if b.paged:
            b.merge_dirty_pages_to_host()
        else:
            b.host_value = to_host(b.device_value)
        b.state = BufferState.SYNC
        return b.host_value

    def on_execute_write(self, buff_id: str, device_value: Any,
                         stable: bool = False, dirty_pages=None):
        """``stable=True`` marks a write whose shapes are known to match the
        previous contents (same compiled program, same signature): the
        per-leaf byte walk is skipped and the spec token is preserved, so
        the monitor's execute-signature cache stays valid.  ``dirty_pages``
        names the pages a paged buffer's write touched; omitting it on a
        paged buffer degrades that buffer to whole-buffer dirtiness."""
        b = self.get(buff_id)
        b.device_value = device_value
        b.state = BufferState.DIRTY
        if not stable:
            b.nbytes = tree_bytes(device_value)
            b.spec_token += 1
        if b.paged:
            b.mark_pages_dirty(dirty_pages)
        b.version += 1
        self._unsynced.add(buff_id)

    # -- sync tracking --------------------------------------------------------
    def take_unsynced(self) -> list:
        """Ids written since the last drain; clears the pending set."""
        out = list(self._unsynced)
        self._unsynced.clear()
        return out

    def unsynced_count(self) -> int:
        return len(self._unsynced)

    # -- evict / restore --------------------------------------------------------
    def dirty_ids(self):
        return [i for i, b in self._buffers.items()
                if b.state is BufferState.DIRTY]

    def evict_device_state(self) -> dict:
        """Save DIRTY buffers to host, drop all device references.

        Paged buffers save only their dirty pages (merged into the prior
        host copy); the clean remainder counts as skipped, same as a SYNC
        buffer.  Returns stats {saved_bytes, skipped_bytes, n_dirty,
        paged_saved_pages, paged_total_pages}.
        """
        saved = skipped = n_dirty = 0
        paged_saved = paged_total = 0
        for b in self._buffers.values():
            if b.state is BufferState.DIRTY:
                if b.paged:
                    n = b.n_pages
                    n_dirty_pages = (n if b.page_dirty is None
                                     else len(b.page_dirty))
                    part = b.merge_dirty_pages_to_host()
                    saved += part
                    skipped += b.nbytes - part
                    paged_saved += n_dirty_pages
                    paged_total += n
                else:
                    b.host_value = to_host(b.device_value)
                    saved += b.nbytes
                b.state = BufferState.SYNC
                n_dirty += 1
            else:
                skipped += b.nbytes
            b.device_value = None
        self._unsynced.clear()          # every device ref was just dropped
        return {"saved_bytes": saved, "skipped_bytes": skipped,
                "n_dirty": n_dirty, "paged_saved_pages": paged_saved,
                "paged_total_pages": paged_total}

    def restore_device_state(self, put_fn=None) -> dict:
        """Re-materialize device buffers from host copies."""
        put = put_fn or jax.device_put
        restored = 0
        for b in self._buffers.values():
            if b.host_value is not None:
                b.device_value = put(b.host_value)
                b.state = BufferState.SYNC
                if b.paged:
                    b.page_dirty = set()    # device mirrors the host copy
                restored += b.nbytes
                self._unsynced.add(b.buff_id)   # device_put is async
        return {"restored_bytes": restored}

    def host_snapshot(self) -> dict:
        """Host-side view for checkpointing: {buff_id: host pytree}.

        The snapshot aliases the live host copies (zero-copy); paged
        buffers are flagged so their next dirty-page merge copies on
        write instead of mutating the snapshot's arrays."""
        out = {}
        for i, b in self._buffers.items():
            if b.host_value is not None:
                out[i] = b.host_value
                if b.paged:
                    b.host_shared = True
        return out

    def versions(self) -> dict:
        return {i: b.version for i, b in self._buffers.items()}

    def spec_map(self) -> dict:
        """Abstract registry of every buffer (incl. INIT ones) — snapshots
        carry this so restore re-registers buffers that had no value yet."""
        return {i: b.spec for i, b in self._buffers.items()}

    def load_snapshot(self, snap: dict, specs: dict | None = None):
        for i, spec in (specs or {}).items():
            if i not in self._buffers:
                self._buffers[i] = Buffer(buff_id=i, spec=spec)
        for i, host_value in snap.items():
            if i not in self._buffers:
                self._buffers[i] = Buffer(buff_id=i, spec=None, nbytes=0)
            b = self._buffers[i]
            b.host_value = host_value
            b.state = BufferState.SYNC
            b.nbytes = tree_bytes(host_value)

    def zero_and_clear(self):
        """Release everything (monitor zeroes freed device memory, §3.4)."""
        self._buffers.clear()
        self._unsynced.clear()


@dataclass
class GuestState:
    """The "VM state" of a task: everything the guest needs to resume.

    Funky snapshots the unikernel's vCPU + dirty guest pages; our guests are
    step-wise resumable tasks, so the VM state is their explicit progress
    record (step counter, RNG seed, data-stream position, user dict).
    """
    step: int = 0
    seed: int = 0
    data_position: int = 0
    user: dict = field(default_factory=dict)

    def clone(self) -> "GuestState":
        return GuestState(self.step, self.seed, self.data_position,
                          dict(self.user))


@dataclass
class TaskSnapshot:
    """A full checkpoint: buffers + guest (VM) state + provenance."""
    task_id: str
    guest_state: GuestState
    buffers: dict                       # buff_id -> host pytree
    program_ids: tuple = ()
    created_at: float = field(default_factory=time.time)
    step: int = 0
    versions: dict = field(default_factory=dict)   # buff_id -> write version
    buffer_specs: dict = field(default_factory=dict)  # full registry

    def nbytes(self) -> int:
        return tree_bytes(self.buffers)
