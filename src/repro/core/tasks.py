"""Guest tasks: the "unikernel applications" of this framework.

Tasks are written against the FunkyCL API only — they never touch JAX devices
directly.  They are *step-wise resumable*: ``setup()`` builds programs and
buffers (or re-attaches after restore), ``step()`` performs one preemptible
unit of work.  The runtime's driver thread calls ``step()`` in a loop; all
orchestration (evict/resume/migrate/checkpoint) lands between steps plus a
monitor-level SYNC — exactly the paper's request-boundary preemption model.

``TrainTask`` uses the *chunked* train functions (paper §3.4 data splitting):
one logical optimizer step = K microbatch EXECUTE requests + one apply
EXECUTE, so preemption waits at most one microbatch (Fig 9).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_arch
from repro.configs.base import ShapeConfig
from repro.core.guest import FunkyCL
from repro.core.programs import Program
from repro.core.state import GuestState
from repro.train import OptConfig, make_batch, make_chunked_train_fns
from repro.train.optimizer import init_opt_state


@dataclass
class TaskImage:
    """The "OCI image" of a task: guest binary + config (+ bitstreams)."""

    name: str
    kind: str                       # train | serve | engine-serve
    arch: str = "yi-9b-smoke"
    seq_len: int = 32
    global_batch: int = 4
    total_steps: int = 8
    chunks: int = 2                 # microbatches per step (request splitting)
    tokens_per_step: int = 4        # serve: decode tokens per step() call
    prompt_len: int = 16
    max_new_tokens: int = 8         # engine-serve: per-request cap
    # engine-serve paged KV memory (None/() keep the engine defaults)
    paged_kv: bool = True
    page_size: int = 8
    kv_pool_pages: Optional[int] = None
    kv_reserve_pages: int = 1
    prompt_buckets: tuple = ()      # e.g. (8, 16, 32); empty = (prompt_len,)
    # engine-serve disaggregation role (mixed | prefill | decode)
    role: str = "mixed"
    # engine-serve speculative decode (0 = off)
    spec_k: int = 0
    spec_draft_arch: Optional[str] = None   # None = self-draft (target arch)
    spec_draft_seed: Optional[int] = None   # None = engine seed
    spec_dynamic_k: bool = False    # adapt lookahead from live accept rate
    seed: int = 0
    opt: OptConfig = field(default_factory=lambda: OptConfig(
        warmup_steps=2, decay_steps=100))

    def instantiate(self) -> "GuestTask":
        if self.kind == "train":
            return TrainTask(self)
        if self.kind == "serve":
            return ServeTask(self)
        if self.kind == "engine-serve":
            return EngineServeTask(self)
        raise ValueError(self.kind)


class GuestTask:
    image: TaskImage

    def setup(self, cl: FunkyCL, gs: GuestState, restore: bool) -> None:
        raise NotImplementedError

    def step(self, cl: FunkyCL, gs: GuestState) -> bool:
        """One preemptible unit of work; returns True when finished."""
        raise NotImplementedError

    def teardown(self, cl: FunkyCL, gs: GuestState) -> None:
        pass

    def on_update(self, vfpga_num: int) -> None:
        """Vertical-scaling hook (paper `update` command)."""

    def on_kill(self) -> None:
        """Forced-removal hook (scale-in / node drain): release any work
        the task holds that outlives it (e.g. requeue in-flight requests)."""

    def drain(self) -> None:
        """Graceful-decommission hook: stop taking new work and finish what
        is already held.  Tasks without a notion of draining ignore it."""

    @property
    def drained(self) -> bool:
        """True once a draining task holds no unfinished work."""
        return True

    def program_ids(self) -> tuple:
        """Program ("bitstream") ids this guest compiles — the placement
        layer matches them against node program caches for warm-cache
        affinity.  Empty means unknown (e.g. before setup)."""
        return ()


class TrainTask(GuestTask):
    PROGRAMS = ("init_state", "grad_init", "grad_step", "apply")

    def __init__(self, image: TaskImage):
        self.image = image
        self.cfg = get_arch(image.arch)
        self.shape = ShapeConfig("task", "train", image.seq_len,
                                 image.global_batch)

    def program_ids(self) -> tuple:
        return self.PROGRAMS

    # -- programs -------------------------------------------------------------
    def _build_programs(self):
        from repro.models import build_model

        bundle = build_model(self.cfg)
        oc = self.image.opt
        grad_init, grad_step, apply_step = make_chunked_train_fns(bundle, oc)

        def init_state(seed):
            params = bundle.init(jax.random.PRNGKey(seed))
            return params, init_opt_state(oc, params)

        def apply_fn(params, opt_state, grad_acc):
            p, o, stats = apply_step(params, opt_state, grad_acc,
                                     self.image.chunks)
            return p, o, stats["grad_norm"]

        self._bundle = bundle
        self._progs = {
            "init_state": Program("init_state", init_state),
            "grad_init": Program("grad_init", grad_init),
            "grad_step": Program("grad_step", grad_step),
            "apply": Program("apply", apply_fn),
        }

    def _abstracts(self):
        p_abs = jax.eval_shape(lambda: self._progs["init_state"].fn(0))
        params_abs, opt_abs = p_abs
        grad_abs = jax.eval_shape(self._progs["grad_init"].fn, params_abs)
        mb = make_batch(self.cfg, self.shape, 0)
        mb_abs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                (x.shape[0] // self.image.chunks,) + x.shape[1:], x.dtype), mb)
        return params_abs, opt_abs, grad_abs, mb_abs

    def setup(self, cl: FunkyCL, gs: GuestState, restore: bool) -> None:
        self._build_programs()
        params_abs, opt_abs, grad_abs, mb_abs = self._abstracts()
        # clCreateProgramWithBinary -> vfpga_init + reconfiguration
        cl.clCreateProgramWithBinary(self._progs["init_state"], (0,))
        cl.clCreateProgramWithBinary(self._progs["grad_init"], (params_abs,))
        cl.clCreateProgramWithBinary(
            self._progs["grad_step"], (params_abs, grad_abs, mb_abs))
        cl.clCreateProgramWithBinary(
            self._progs["apply"], (params_abs, opt_abs, grad_abs))
        if not restore:
            cl.clCreateBuffer("params", params_abs)
            cl.clCreateBuffer("opt_state", opt_abs)
            cl.clCreateBuffer("grad_acc", grad_abs)
            cl.clCreateBuffer("batch", mb_abs)
            cl.clCreateBuffer("loss", jax.ShapeDtypeStruct((), jnp.float32))
            cl.clCreateBuffer("grad_norm", jax.ShapeDtypeStruct((), jnp.float32))
            cl.clEnqueueKernel("init_state", (), ("params", "opt_state"),
                               const_args=(self.image.seed,))
            cl.clFinish()

    def step(self, cl: FunkyCL, gs: GuestState) -> bool:
        """One *chunk* of a logical optimizer step (paper §3.4 splitting).

        Each driver-loop iteration submits exactly one microbatch EXECUTE, so
        preemption waits at most one chunk — and a task evicted mid-
        accumulation resumes bit-exactly: ``chunk_idx`` lives in the guest
        (VM) state and ``grad_acc`` is a DIRTY tracked buffer.
        """
        k = self.image.chunks
        ci = gs.user.get("chunk_idx", 0)
        if ci == 0:
            cl.clEnqueueKernel("grad_init", ("params",), ("grad_acc",))
        full = make_batch(self.cfg, self.shape, gs.step,
                          batch_override=self.image.global_batch)
        mb_size = self.image.global_batch // k
        mb = jax.tree.map(
            lambda x: x[ci * mb_size:(ci + 1) * mb_size], full)
        cl.write_buffer("batch", mb)
        cl.clEnqueueKernel("grad_step", ("params", "grad_acc", "batch"),
                           ("grad_acc", "loss"))
        if ci + 1 < k:
            cl.clFinish()
            gs.user["chunk_idx"] = ci + 1
            return False
        cl.clEnqueueKernel("apply", ("params", "opt_state", "grad_acc"),
                           ("params", "opt_state", "grad_norm"))
        cl.clFinish()
        gs.user["chunk_idx"] = 0
        gs.step += 1
        gs.data_position = gs.step
        return gs.step >= self.image.total_steps

    def teardown(self, cl: FunkyCL, gs: GuestState) -> None:
        gs.user["final_loss"] = float(jnp.asarray(cl.read_buffer("loss")))
        # read results out before releasing: the monitor zeroes device memory
        # on vfpga_exit (paper §3.4 isolation). Host-side only; never hits a
        # JSON manifest (checkpoints only happen while RUNNING).
        gs.user["final_params"] = cl.read_buffer("params")
        for pid in ("init_state", "grad_init", "grad_step", "apply"):
            cl.clReleaseProgram(pid)


class ServeTask(GuestTask):
    """Batched greedy decoding service; one step() = tokens_per_step tokens."""

    PROGRAMS = ("init_params", "prefill", "decode")

    def __init__(self, image: TaskImage):
        self.image = image
        self.cfg = get_arch(image.arch)

    def program_ids(self) -> tuple:
        return self.PROGRAMS

    def _build_programs(self):
        from repro.models import build_model

        bundle = build_model(self.cfg)

        def init_params(seed):
            return bundle.init(jax.random.PRNGKey(seed))

        def prefill(params, tokens):
            logits, caches = bundle.prefill_fn(params, {"tokens": tokens})
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            return tok, jnp.int32(tokens.shape[1]), caches

        def decode(params, token, pos, caches):
            logits, caches = bundle.decode_fn(params, token, pos, caches)
            return (jnp.argmax(logits, -1).astype(jnp.int32), pos + 1, caches)

        self._bundle = bundle
        self._progs = {
            "init_params": Program("init_params", init_params),
            "prefill": Program("prefill", prefill),
            "decode": Program("decode", decode),
        }

    def setup(self, cl: FunkyCL, gs: GuestState, restore: bool) -> None:
        self._build_programs()
        im = self.image
        params_abs = jax.eval_shape(lambda: self._progs["init_params"].fn(0))
        toks_abs = jax.ShapeDtypeStruct((im.global_batch, im.prompt_len),
                                        jnp.int32)
        pre_abs = jax.eval_shape(self._progs["prefill"].fn, params_abs,
                                 toks_abs)
        tok_abs, pos_abs, caches_abs = pre_abs
        cl.clCreateProgramWithBinary(self._progs["init_params"], (0,))
        cl.clCreateProgramWithBinary(self._progs["prefill"],
                                     (params_abs, toks_abs))
        cl.clCreateProgramWithBinary(
            self._progs["decode"], (params_abs, tok_abs, pos_abs, caches_abs))
        if not restore:
            cl.clCreateBuffer("params", params_abs)
            cl.clCreateBuffer("prompt", toks_abs)
            cl.clCreateBuffer("token", tok_abs)
            cl.clCreateBuffer("pos", pos_abs)
            cl.clCreateBuffer("caches", caches_abs)
            cl.clEnqueueKernel("init_params", (), ("params",),
                               const_args=(im.seed,))
            prompt = make_batch(self.cfg,
                                ShapeConfig("p", "train", im.prompt_len,
                                            im.global_batch), 0)["tokens"]
            cl.write_buffer("prompt", prompt)
            cl.clEnqueueKernel("prefill", ("params", "prompt"),
                               ("token", "pos", "caches"))
            cl.clFinish()

    def step(self, cl: FunkyCL, gs: GuestState) -> bool:
        for _ in range(self.image.tokens_per_step):
            cl.clEnqueueKernel("decode", ("params", "token", "pos", "caches"),
                               ("token", "pos", "caches"))
        cl.clFinish()
        gs.step += 1
        return gs.step >= self.image.total_steps

    def teardown(self, cl: FunkyCL, gs: GuestState) -> None:
        gs.user["last_token"] = cl.read_buffer("token").tolist()
        for pid in ("init_params", "prefill", "decode"):
            cl.clReleaseProgram(pid)


class EngineServeTask(GuestTask):
    """Per-request serving replica: a continuous-batching engine pulling
    admissible requests from the service's ``RequestRouter`` and pushing
    engine-reported completions back.

    One ``step()`` = one engine iteration (admissions + one vmapped decode
    EXECUTE), so orchestration commands land between iterations and the
    whole in-flight batch is preemptible at token boundaries.  The task
    finishes when the router is closed and every lane has drained; a
    replicate-clone starts with empty lanes (the source keeps its own
    in-flight sequences) and immediately joins the admission pool.

    ``drain()`` puts the replica into a *draining* state: it stops pulling
    admissions from the router and finishes the sequences it already holds,
    so request-boundary scale-in decommissions the replica without
    requeueing (and recomputing) in-flight work.
    """

    def __init__(self, image: TaskImage):
        self.image = image
        self._engine = None
        self._draining = False

    def setup(self, cl: FunkyCL, gs: GuestState, restore: bool) -> None:
        from repro.scaling.serving import get_router
        from repro.serve.engine import ContinuousBatchingEngine, SpecConfig

        im = self.image
        self._router = get_router(im.name,
                                  registry=cl._monitor.telemetry)
        spec = (SpecConfig(k=im.spec_k, draft_arch=im.spec_draft_arch,
                           draft_seed=im.spec_draft_seed,
                           dynamic_k=im.spec_dynamic_k)
                if im.spec_k > 0 else None)
        self._engine = ContinuousBatchingEngine(
            im.arch, cl, slots=im.global_batch, prompt_len=im.prompt_len,
            max_new_tokens=im.max_new_tokens, service=im.name,
            engine_id=cl._monitor.task_id, seed=im.seed,
            paged=im.paged_kv, page_size=im.page_size,
            pool_pages=im.kv_pool_pages,
            reserve_pages=im.kv_reserve_pages,
            prompt_buckets=im.prompt_buckets or None, spec=spec,
            role=im.role)
        self._engine.setup(restore=restore)

    def step(self, cl: FunkyCL, gs: GuestState) -> bool:
        moved = self._engine.pump(self._router, admit=not self._draining)
        gs.step += 1
        if self._draining and self._engine.idle:
            return True                  # drained: exit at request boundary
        if not moved:
            if self._router.closed:
                return True
            time.sleep(0.002)            # idle poll; don't spin the monitor
        return gs.step >= self.image.total_steps

    def drain(self) -> None:
        self._draining = True

    @property
    def drained(self) -> bool:
        return self._engine is None or self._engine.idle

    def program_ids(self) -> tuple:
        return self._engine.program_ids() if self._engine is not None else ()

    def teardown(self, cl: FunkyCL, gs: GuestState) -> None:
        gs.user["completed"] = len(self._engine.completed)
        for pid in self._engine.program_ids():
            cl.clReleaseProgram(pid)

    def on_kill(self) -> None:
        # scale-in removed this replica: report anything already finished,
        # then hand un-finished sequences back to the router so another
        # replica re-serves them (greedy decode is deterministic — the
        # client sees the same tokens again)
        if self._engine is None:
            return
        for rec in self._engine.drain_completions():
            self._router.complete(rec)
        reqs = self._engine.evacuate()
        if reqs:
            self._router.requeue(reqs)
