"""Funky core: the paper's contribution (virtualization + state management +
orchestration), adapted from FPGA clusters to TPU/JAX (see DESIGN.md §2)."""

from repro.core.cluster import Cluster, Node, make_cluster
from repro.core.guest import FunkyCL
from repro.core.monitor import (DeviceMemoryExceeded, Monitor, MonitorError,
                                MonitorState, NoSliceAvailable)
from repro.core.placement import (MigrationConfig, MigrationController,
                                  MigrationDecision, PlacementPolicy,
                                  PlacementWeights, ServiceGroup)
from repro.core.programs import Program, ProgramCache
from repro.core.requests import (Completion, Direction, FunkyRequest,
                                 RequestKind)
from repro.core.runtime import FunkyRuntime, TaskRecord, TaskStatus
from repro.core.scheduler import (Action, FunkyScheduler, Policy, SchedTask,
                                  TaskState)
from repro.core.state import (Buffer, BufferState, BufferTable, GuestState,
                              TaskSnapshot, tree_bytes)
from repro.core.tasks import (EngineServeTask, GuestTask, ServeTask,
                              TaskImage, TrainTask)
from repro.core.vslice import SliceAllocator, VSlice

__all__ = [
    "Action", "Buffer", "BufferState", "BufferTable", "Cluster", "Completion",
    "DeviceMemoryExceeded", "Direction", "EngineServeTask", "FunkyCL",
    "FunkyRequest",
    "FunkyRuntime", "FunkyScheduler", "GuestState", "GuestTask",
    "MigrationConfig", "MigrationController", "MigrationDecision", "Monitor",
    "MonitorError", "MonitorState", "Node", "NoSliceAvailable",
    "PlacementPolicy", "PlacementWeights", "Policy",
    "Program", "ProgramCache", "RequestKind", "SchedTask", "ServeTask",
    "ServiceGroup",
    "SliceAllocator", "TaskImage", "TaskRecord", "TaskSnapshot", "TaskState",
    "TaskStatus", "TrainTask", "VSlice", "make_cluster", "tree_bytes",
]
