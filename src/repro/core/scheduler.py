"""Funky preemptive task scheduler (paper Algorithm 1 + Table 5 policies).

Policies:
    FCFS    deploy in arrival order, never reorder, never preempt
    NO_PRE  priority-sorted wait queue, no eviction
    PRE_EV  + evict lower-priority running tasks; evicted tasks resume on
            the node that holds their context
    PRE_MG  + migrate evicted tasks to other nodes when their home is busy

The scheduler is a pure policy engine over an abstract ``ClusterView`` and
emits ``Action``s — the *same* engine drives the live runtime (Fig 10) and
the trace simulator (Figs 11/13), which is how the paper's two evaluations
stay consistent.
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Any, Iterable, List, Optional, Protocol


class Policy(str, enum.Enum):
    FCFS = "FCFS"
    NO_PRE = "NO_PRE"
    PRE_EV = "PRE_EV"
    PRE_MG = "PRE_MG"


class TaskState(str, enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    EVICTED = "evicted"
    DONE = "done"


@dataclass
class SchedTask:
    tid: str
    priority: int = 0
    submit_time: float = 0.0
    state: TaskState = TaskState.WAITING
    node_id: Optional[str] = None       # where it runs / where context lives
    preemptible: bool = True
    # service-group id: replicas of one service share it, so placement can
    # spread them across failure domains and victim selection never takes a
    # group's last running replica while an alternative exists
    group: Optional[str] = None
    meta: dict = field(default_factory=dict)


@dataclass
class Action:
    kind: str                           # deploy | evict | resume | migrate
    tid: str
    node: Optional[str] = None
    src_node: Optional[str] = None


class ClusterView(Protocol):
    def nodes(self) -> List[str]: ...
    def free_slices(self, node: str) -> int: ...
    def running_tasks(self, node: str) -> List[SchedTask]: ...


class FunkyScheduler:
    def __init__(self, policy: Policy = Policy.PRE_MG, placement=None):
        self.policy = Policy(policy)
        if placement is None:
            # lazy import: placement builds on SchedTask/TaskState above
            from repro.core.placement import PlacementPolicy
            placement = PlacementPolicy()
        self.placement = placement
        self.wait_queue: List[SchedTask] = []
        self.run_queue: List[SchedTask] = []
        self._seq = itertools.count()

    # ------------------------------------------------------------------
    def submit(self, task: SchedTask):
        task.meta.setdefault("seq", next(self._seq))
        task.state = TaskState.WAITING if task.state is not TaskState.EVICTED \
            else TaskState.EVICTED
        self.wait_queue.append(task)

    def task_done(self, tid: str):
        self.run_queue = [t for t in self.run_queue if t.tid != tid]

    # ------------------------------------------------------------------
    def _sorted_wait(self) -> List[SchedTask]:
        if self.policy is Policy.FCFS:
            return sorted(self.wait_queue,
                          key=lambda t: (t.submit_time, t.meta["seq"]))
        return sorted(self.wait_queue,
                      key=lambda t: (-t.priority, t.submit_time, t.meta["seq"]))

    def _select_node(self, task: SchedTask, view: ClusterView,
                     reserved: dict) -> Optional[str]:
        """Most suitable node with a free slice (Alg 1 L4) — delegated to
        the unified ``PlacementPolicy`` (warm-cache affinity, failure-domain
        anti-affinity, per-node telemetry)."""
        return self.placement.select_node(
            task, view, reserved, running=self.run_queue,
            allow_migrate=self.policy is Policy.PRE_MG)

    def _find_victim(self, task: SchedTask, view: ClusterView,
                     evicting: set) -> Optional[SchedTask]:
        """Preemption victim — delegated to the group-aware policy."""
        return self.placement.find_victim(task, self.run_queue, evicting)

    # ------------------------------------------------------------------
    def schedule_once(self, view: ClusterView) -> List[Action]:
        """One pass of Algorithm 1 over the wait queue."""
        actions: List[Action] = []
        reserved: dict = {}
        evicting: set = set()
        preempt = self.policy in (Policy.PRE_EV, Policy.PRE_MG)

        for task in self._sorted_wait():
            node = self._select_node(task, view, reserved)
            if node is None and preempt:
                victim = self._find_victim(task, view, evicting)
                if victim is not None:
                    # L5-8: evict the low-priority task, keep its context
                    actions.append(Action("evict", victim.tid,
                                          node=victim.node_id))
                    evicting.add(victim.tid)
                    victim_node = victim.node_id
                    victim.state = TaskState.EVICTED
                    self.run_queue.remove(victim)
                    self.wait_queue.append(victim)
                    # incoming may be resumable only on its own node (PRE_EV)
                    if (task.state is TaskState.EVICTED
                            and task.node_id is not None
                            and self.policy is not Policy.PRE_MG
                            and task.node_id != victim_node):
                        continue
                    node = victim_node
            if node is None:
                if self.policy is Policy.FCFS:
                    break              # strict FCFS: head-of-line blocking
                continue

            if task.state is TaskState.EVICTED:
                if task.node_id == node:
                    actions.append(Action("resume", task.tid, node=node))
                else:
                    actions.append(Action("migrate", task.tid, node=node,
                                          src_node=task.node_id))
            else:
                actions.append(Action("deploy", task.tid, node=node))
            reserved[node] = reserved.get(node, 0) + 1
            task.state = TaskState.RUNNING
            task.node_id = node
            task.meta.pop("migrate_from", None)   # migration flag consumed
            self.wait_queue.remove(task)
            self.run_queue.append(task)
        return actions
