"""Synthetic Borg-like production traces.

The paper replays the Google ClusterData 2019 traces; that dataset is not
available in this offline container, so we generate statistically similar
synthetic traces (documented deviation, DESIGN.md §7):

* arrivals: Poisson process over the horizon;
* durations: heavy-tailed lognormal, clipped to [30 s, 3 h] (Borg-like);
* priorities: three tiers — best-effort (60 %), batch (30 %), prod (10 %);
* memory: lognormal, capped at the device memory (8 GiB on Alveo U50);
* failures: each job optionally fails once at a uniform fraction of its
  runtime — El-Sayed et al. (cited by the paper) report failed jobs run
  ~40 % of their duration before the first failure; U(1%,99%) reproduces the
  paper's setup with ~50 % mean.

The paper applies a measured 1.6x FPGA speedup to job durations; the
simulator takes the same ``acceleration_rate`` sweep as Fig 11.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np


@dataclass
class TraceJob:
    jid: str
    submit_time: float              # seconds from trace start
    duration: float                 # un-accelerated work, seconds
    priority: int                   # 0 best-effort, 1 batch, 2 prod
    memory_bytes: int               # device-memory working set
    fail_frac: Optional[float]      # fraction of work at which the job fails
    # placement enrichment (optional): replicas of one service share a
    # group (spread across failure domains); ``programs`` are the job's
    # bitstream ids — a node that already compiled them is warm and skips
    # reconfiguration on deploy
    group: Optional[str] = None
    programs: tuple = ()


def generate_trace(n_jobs: int = 2000, horizon_s: float = 24 * 3600.0,
                   seed: int = 0, with_failures: bool = False,
                   mean_duration_s: float = 600.0,
                   device_mem_cap: int = 8 << 30) -> List[TraceJob]:
    rng = np.random.Generator(np.random.Philox(seed))
    arrivals = np.sort(rng.uniform(0.0, horizon_s, n_jobs))
    # lognormal with median ~ mean_duration_s/2, heavy tail
    mu = np.log(mean_duration_s / 2)
    durations = np.clip(rng.lognormal(mu, 1.2, n_jobs), 30.0, 3 * 3600.0)
    priorities = rng.choice([0, 1, 2], size=n_jobs, p=[0.6, 0.3, 0.1])
    mem = np.minimum(rng.lognormal(np.log(512e6), 1.0, n_jobs),
                     float(device_mem_cap)).astype(np.int64)
    fail = rng.uniform(0.01, 0.99, n_jobs) if with_failures else None
    jobs = []
    for i in range(n_jobs):
        jobs.append(TraceJob(
            jid=f"job-{i:06d}",
            submit_time=float(arrivals[i]),
            duration=float(durations[i]),
            priority=int(priorities[i]),
            memory_bytes=int(mem[i]),
            fail_frac=float(fail[i]) if with_failures else None,
        ))
    return jobs
