"""The Funky orchestrator (leader node): API server + scheduler + services.

Services (paper §3.5, Table 3):
  * preemptive scheduling  — Algorithm 1 actions executed through node agents
  * checkpoint & restore   — periodic/manual snapshots; failure recovery
  * workload scaling       — horizontal (replicate/remove) and vertical
                             (update), driven by an SLO/utilization
                             autoscaler reconcile loop (repro.scaling)

The orchestrator never talks to monitors directly: every operation flows
orchestrator -> node agent -> CRI -> container engine -> OCI runtime, as in
the paper's Figure 1.  All services publish telemetry into a
``repro.scaling.metrics`` registry — the same schema the trace simulator
emits under its virtual clock.
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.chaos import (DEFAULT_ACTION_RETRY, RetryPolicy, TransientFault,
                         retry_call)
from repro.core.node_agent import NodeAgent, NodeFailed
from repro.core.placement import (M_NODE_UTILIZATION, MigrationController,
                                  PlacementPolicy)
from repro.core.runtime import TaskStatus
from repro.core.scheduler import (Action, FunkyScheduler, Policy, SchedTask,
                                  TaskState)
from repro.scaling.autoscaler import (Autoscaler, ReplicaTarget,
                                      ScalingSignals, signals_from_registry)
from repro.scaling.metrics import MetricsRegistry


@dataclass
class Deployment:
    cid: str
    image_ref: str
    priority: int = 0
    preemptible: bool = True
    submit_time: float = field(default_factory=time.time)
    start_time: Optional[float] = None
    end_time: Optional[float] = None
    status: str = "pending"
    group: Optional[str] = None         # service group (replica set) id


class Orchestrator:
    def __init__(self, agents: Dict[str, NodeAgent],
                 policy: Policy = Policy.PRE_MG,
                 checkpoint_interval: Optional[float] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 placement: Optional[PlacementPolicy] = None,
                 straggler_interval: Optional[float] = None,
                 tracer=None, retry: Optional[RetryPolicy] = None):
        self.agents = agents
        # bounded retry-with-backoff for orchestrator actions (deploy /
        # evict / resume / migrate / restore): a transient agent fault
        # costs a backoff, exhaustion produces a structured failure event
        self.retry = retry if retry is not None else DEFAULT_ACTION_RETRY
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # orchestration-plane tracing: one long-lived "cluster" trace whose
        # spans are the control actions (deploy/evict/resume/migrate,
        # scale-in drains, failure restores, straggler migrations) — an
        # exported run is a loadable cluster timeline
        self.tracer = tracer
        self._cluster_trace = (tracer.start_trace("cluster",
                                                  trace_id="cluster")
                               if tracer is not None else None)
        # one placement engine for every decision (scheduling, scale-out,
        # failure recovery, straggler migration) — scored from this
        # orchestrator's enriched ClusterView + the shared registry
        self.placement = (placement if placement is not None
                          else PlacementPolicy(registry=self.metrics))
        self.scheduler = FunkyScheduler(policy, placement=self.placement)
        self.migration = MigrationController(self.metrics)
        self.deployments: Dict[str, Deployment] = {}
        self._sched_tasks: Dict[str, SchedTask] = {}
        # straggler migrations in flight: cid -> the pre-migration trace,
        # span-linked (relation="migrates") from the post-migration trace
        # when the task lands again, mirroring the router's "recovers"
        # links — trace_dump stitches evict and re-land into one story
        self._pending_migrate_links: Dict[str, object] = {}
        self._image_programs: Dict[str, tuple] = {}   # image_ref -> programs
        self._cid_counter = itertools.count(1)
        self._lock = threading.RLock()
        self.checkpoint_interval = checkpoint_interval
        self.straggler_interval = straggler_interval
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self.events: List[tuple] = []
        self._started = False
        # (autoscaler, target, signal_fn, interval_s) reconcile loops
        self._autoscalers: List[tuple] = []

    # ------------------------------------------------------------------
    # API server
    # ------------------------------------------------------------------
    def submit(self, image_ref: str, priority: int = 0,
               preemptible: bool = True, cid: Optional[str] = None,
               group: Optional[str] = None) -> str:
        with self._lock:
            cid = cid or f"task-{next(self._cid_counter):04d}"
            dep = Deployment(cid=cid, image_ref=image_ref, priority=priority,
                             preemptible=preemptible, group=group)
            self.deployments[cid] = dep
            st = SchedTask(tid=cid, priority=priority,
                           submit_time=dep.submit_time,
                           preemptible=preemptible, group=group)
            progs = self._image_programs.get(image_ref)
            if progs:
                st.meta["programs"] = progs     # warm-cache affinity hint
            self._sched_tasks[cid] = st
            self.scheduler.submit(st)
            self._log("submit", cid=cid, priority=priority)
            return cid

    def checkpoint(self, cid: str) -> str:
        node = self._sched_tasks[cid].node_id
        path = self.agents[node].checkpoint(cid)
        self._log("checkpoint", cid=cid, path=path)
        return path

    def scale_horizontal(self, cid: str, target_node: str) -> str:
        # Reserve the slot under the scheduler lock so a concurrent tick()
        # cannot double-book it, but run the multi-second checkpoint-clone
        # outside the lock — holding it would freeze scheduling and
        # failure recovery for the whole replicate.
        with self._lock:
            base_st = self._sched_tasks[cid]
            base_dep = self.deployments[cid]
            src = base_st.node_id
            image_ref = base_dep.image_ref
            gid = self._ensure_group(cid)
            new_cid = f"{cid}-r{next(self._cid_counter)}"
            dep = Deployment(cid=new_cid, image_ref=image_ref, group=gid)
            dep.status = "running"
            self.deployments[new_cid] = dep
            st = SchedTask(tid=new_cid, state=TaskState.RUNNING,
                           node_id=target_node, group=gid)
            progs = self._image_programs.get(image_ref)
            if progs:
                st.meta["programs"] = progs
            self._sched_tasks[new_cid] = st
            self.scheduler.run_queue.append(st)
        sp = self._span("orch.replicate", cid=cid, new_cid=new_cid,
                        node=target_node)
        try:
            self.agents[target_node].replicate_in(new_cid, cid, src,
                                                  image_ref)
        except BaseException as e:
            with self._lock:        # roll the reservation back
                self.scheduler.task_done(new_cid)
                self._sched_tasks.pop(new_cid, None)
                self.deployments.pop(new_cid, None)
            if sp is not None:
                sp.annotate(outcome="error", error=repr(e)).end()
            raise
        if sp is not None:
            sp.end()
        self._log("replicate", cid=cid, new_cid=new_cid, node=target_node)
        return new_cid

    def _ensure_group(self, cid: str) -> str:
        """Replicas of ``cid`` share a service group (default: the base
        task's cid), so placement can spread them across failure domains."""
        dep = self.deployments[cid]
        gid = dep.group or cid
        dep.group = gid
        st = self._sched_tasks[cid]
        if st.group is None:
            st.group = gid
        return gid

    def place_replica(self, cid: str) -> Optional[str]:
        """Pick the node for a new replica of ``cid`` through the unified
        placement engine: warm program-cache affinity (the clone reuses the
        base image's compiled programs) and failure-domain anti-affinity
        against the group's running members.  Returns None when no node has
        a free slice."""
        sp = self._span("orch.place", cid=cid)
        with self._lock:
            dep = self.deployments[cid]
            gid = self._ensure_group(cid)
            probe = SchedTask(
                tid=f"{cid}::place", priority=dep.priority, group=gid,
                meta={"programs": self._image_programs.get(dep.image_ref,
                                                           ())})
            target = self.placement.select_node(
                probe, self, {}, running=self.scheduler.run_queue)
        if sp is not None:
            sp.annotate(node=target).end()
        return target

    def scale_vertical(self, cid: str, vfpga_num: int):
        node = self._sched_tasks[cid].node_id
        self.agents[node].update(cid, vfpga_num)
        self._log("update", cid=cid, vfpga_num=vfpga_num)

    def scale_in(self, cid: str, drain_s: float = 0.0):
        """Remove a replica (scale-down): optionally drain first (stop
        admissions, let in-flight lanes finish at their request boundary),
        then kill + delete through the agent.  Draining happens outside the
        lock — it blocks for up to ``drain_s``."""
        sp = self._span("orch.scale_in", cid=cid)
        if drain_s > 0:
            node = self._sched_tasks[cid].node_id
            if node is not None and node in self.agents:
                dsp = (sp.child("orch.drain", cid=cid, node=node)
                       if sp is not None else None)
                try:
                    stats = self.agents[node].drain(cid, timeout_s=drain_s)
                    self._log("drain", cid=cid, node=node, **stats)
                except Exception as e:  # noqa: BLE001 - node may be gone
                    self._log("drain_error", cid=cid, node=node,
                              error=repr(e))
                finally:
                    if dsp is not None:
                        dsp.end()
        with self._lock:
            st = self._sched_tasks[cid]
            node = st.node_id
            if node is not None and node in self.agents:
                self.agents[node].remove(cid)
            self.scheduler.task_done(cid)
            self.scheduler.wait_queue = [
                t for t in self.scheduler.wait_queue if t.tid != cid]
            self.migration.forget(cid)
            st.state = TaskState.DONE
            dep = self.deployments[cid]
            dep.status = "removed"
            dep.end_time = time.time()
            self._log("scale_in", cid=cid, node=node)
        if sp is not None:
            sp.annotate(node=node).end()

    # ------------------------------------------------------------------
    # Workload-scaling service: autoscaler reconcile loop (paper §3.5)
    # ------------------------------------------------------------------
    def attach_autoscaler(self, autoscaler: Autoscaler,
                          target: ReplicaTarget, *, service: str = "svc",
                          signal_fn: Optional[
                              Callable[[], ScalingSignals]] = None,
                          interval_s: float = 0.25):
        """Register a reconcile loop for one service; starts with start().

        ``signal_fn`` defaults to reading the canonical service metrics from
        this orchestrator's registry — whoever terminates requests for the
        service (live serving loop or load generator) publishes them there.
        """
        if signal_fn is None:
            def signal_fn():
                s = signals_from_registry(self.metrics, service)
                s.replicas = target.current_replicas()
                return s
        entry = (autoscaler, target, signal_fn, interval_s)
        self._autoscalers.append(entry)
        if self._started:
            self._spawn_autoscale_loop(entry)

    def _spawn_autoscale_loop(self, entry):
        autoscaler, target, signal_fn, interval_s = entry

        def reconcile_loop():
            while not self._stop.wait(interval_s):
                try:
                    signals = signal_fn()
                    desired = autoscaler.reconcile(signals,
                                                   self.metrics.clock())
                    if desired is not None:
                        target.scale_to(desired)
                        self._log("autoscale", desired=desired,
                                  replicas=signals.replicas)
                except NodeFailed:
                    continue          # next pass sees the updated cluster
                except Exception as e:  # noqa: BLE001 - e.g. replicate race
                    # keep reconciling, but leave a trace: a permanently
                    # broken signal path must not look like a quiet cluster
                    self.metrics.counter("autoscaler_errors_total").inc()
                    self._log("autoscale_error", error=repr(e))
                    continue

        t = threading.Thread(target=reconcile_loop, daemon=True,
                             name="funky-autoscaler")
        t.start()
        self._threads.append(t)

    # ------------------------------------------------------------------
    # ClusterView for the scheduler
    # ------------------------------------------------------------------
    def nodes(self) -> List[str]:
        return [n for n, a in self.agents.items() if not a.failed]

    def free_slices(self, node: str) -> int:
        """Logical occupancy (scheduler's own accounting) — the physical
        allocator lags asynchronous task setup, so consulting it directly
        would double-book slots."""
        agent = self.agents.get(node)
        if agent is None or agent.failed:
            return 0
        return agent.num_slices() - len(self.running_tasks(node))

    def running_tasks(self, node: str) -> List[SchedTask]:
        return [t for t in self.scheduler.run_queue if t.node_id == node]

    # -- enriched view (placement layer) --------------------------------
    def failure_domain(self, node: str) -> str:
        agent = self.agents.get(node)
        return agent.failure_domain if agent is not None else node

    def warm_programs(self, node: str) -> tuple:
        agent = self.agents.get(node)
        if agent is None or agent.failed:
            return ()
        try:
            return agent.warm_programs()
        except NodeFailed:
            return ()

    # ------------------------------------------------------------------
    # Scheduling loop
    # ------------------------------------------------------------------
    def tick(self) -> List[Action]:
        """Reap finished tasks, run one scheduling pass, execute actions."""
        t0 = time.perf_counter()
        with self._lock:
            self._reap()
            self._learn_programs()
            actions = self.scheduler.schedule_once(self)
            for a in actions:
                self._execute(a)
            self._publish_cluster_metrics()
            self.metrics.histogram("sched_tick_seconds").observe(
                time.perf_counter() - t0)
            return actions

    def _learn_programs(self):
        """Cache each running image's program ids (once known) so placement
        can match them against node program caches for warm affinity."""
        for st in self.scheduler.run_queue:
            if "programs" in st.meta:
                continue
            dep = self.deployments.get(st.tid)
            agent = self.agents.get(st.node_id)
            if dep is None or agent is None or agent.failed:
                continue
            known = self._image_programs.get(dep.image_ref)
            if known:
                st.meta["programs"] = known
                continue
            try:
                progs = agent.task_programs(st.tid)
            except NodeFailed:
                continue
            if progs is None:
                continue               # guest still booting: retry next tick
            # cache even an empty result so probing terminates per task
            st.meta["programs"] = tuple(progs)
            if progs:
                self._image_programs[dep.image_ref] = tuple(progs)

    def _publish_cluster_metrics(self):
        """Cluster-level gauges (same names the simulator emits)."""
        self.metrics.gauge("wait_queue_depth").set(
            len(self.scheduler.wait_queue))
        self.metrics.gauge("running_tasks").set(
            len(self.scheduler.run_queue))
        total = used = 0
        for n, agent in self.agents.items():
            if agent.failed:
                continue
            slices = agent.num_slices()
            free = self.free_slices(n)
            self.metrics.gauge("free_slices", node=n).set(free)
            if slices:
                self.metrics.gauge(M_NODE_UTILIZATION, node=n).set(
                    (slices - free) / slices)
            total += slices
            used += slices - free
        if total:
            self.metrics.gauge("cluster_utilization").set(used / total)

    def _reap(self):
        for cid, st in list(self._sched_tasks.items()):
            if st.state is not TaskState.RUNNING:
                continue
            agent = self.agents.get(st.node_id)
            if agent is None or agent.failed:
                continue
            status = agent.task_status(cid)
            dep = self.deployments[cid]
            if status is TaskStatus.DONE:
                st.state = TaskState.DONE
                self.scheduler.task_done(cid)
                self.migration.forget(cid)
                dep.status = "done"
                dep.end_time = time.time()
                self._log("done", cid=cid)
            elif status is TaskStatus.FAILED:
                from repro.core.monitor import NoSliceAvailable

                rec_err = agent.engine.runtime.tasks[cid].error
                if isinstance(rec_err, NoSliceAvailable):
                    # slot race during async setup: requeue, don't kill
                    agent.engine.runtime.delete(cid)
                    st.state = TaskState.WAITING
                    st.node_id = None
                    self.scheduler.task_done(cid)
                    self.scheduler.submit(st)
                    dep.status = "pending"
                    self._log("requeued_no_slice", cid=cid)
                    continue
                st.state = TaskState.DONE
                self.scheduler.task_done(cid)
                self.migration.forget(cid)
                dep.status = "failed"
                dep.end_time = time.time()
                self._log("task_failed", cid=cid)

    def _execute(self, a: Action):
        dep = self.deployments.get(a.tid)
        st = self._sched_tasks[a.tid]
        sp = self._span(f"orch.{a.kind}", cid=a.tid, node=a.node)

        def dispatch():
            if a.kind == "deploy":
                self.agents[a.node].deploy(
                    a.tid, dep.image_ref, priority=dep.priority,
                    preemptible=dep.preemptible)
                dep.status = "running"
                dep.start_time = dep.start_time or time.time()
            elif a.kind == "evict":
                self.agents[a.node].evict(a.tid)
                self.deployments[a.tid].status = "evicted"
            elif a.kind == "resume":
                self.agents[a.node].resume(a.tid)
                dep.status = "running"
            elif a.kind == "migrate":
                self.agents[a.node].migrate_in(
                    a.tid, dep.image_ref, a.src_node)
                dep.status = "running"

        try:
            retry_call(dispatch, self.retry,
                       on_retry=lambda n, b, e: self._on_action_retry(
                           a, sp, n, b, e))
            self._log(a.kind, cid=a.tid, node=a.node)
            if a.kind in ("migrate", "resume"):
                # the straggler landed again: close the migration loop
                # with a span link from its post-trace back to the
                # pre-eviction trace (relation="migrates")
                pre = self._pending_migrate_links.pop(a.tid, None)
                if pre is not None and self.tracer is not None:
                    post = self.tracer.event_span(
                        "orch.migrate_in", cid=a.tid, node=a.node,
                        src_node=getattr(a, "src_node", None))
                    post.link(pre, relation="migrates")
                    post.finish()
        except TransientFault as e:
            # attempts exhausted: structured failure + requeue — the
            # scheduling loop must survive an unlucky streak
            if a.kind in ("resume", "migrate"):
                st.state = TaskState.EVICTED      # context survives
            else:
                st.state = TaskState.WAITING
                st.node_id = None
            self.scheduler.task_done(a.tid)
            self.scheduler.submit(st)
            self._log("action_failed", action=a.kind, cid=a.tid,
                      error=repr(e))
            if sp is not None:
                sp.annotate(outcome="action_failed", error=repr(e))
        except NodeFailed:
            # node died under us: requeue the task
            st.state = TaskState.WAITING
            st.node_id = None
            self.scheduler.task_done(a.tid)
            self.scheduler.submit(st)
            self._log("node_failed_during", action=a.kind, cid=a.tid)
            if sp is not None:
                sp.annotate(outcome="node_failed")
        except Exception as e:  # noqa: BLE001 - e.g. NoSliceAvailable race
            from repro.core.monitor import NoSliceAvailable

            if not isinstance(e, NoSliceAvailable):
                if sp is not None:
                    sp.annotate(outcome="error", error=repr(e)).end()
                raise
            if a.kind in ("resume", "migrate"):
                st.state = TaskState.EVICTED      # context survives
            else:
                st.state = TaskState.WAITING
                st.node_id = None
            self.scheduler.task_done(a.tid)
            self.scheduler.submit(st)
            self._log("no_slice_retry", action=a.kind, cid=a.tid)
            if sp is not None:
                sp.annotate(outcome="no_slice_retry")
        finally:
            if sp is not None:
                sp.end()

    def _on_action_retry(self, a: Action, sp, attempt: int,
                         backoff_s: float, exc: BaseException):
        self.metrics.counter("orchestrator_action_retries_total",
                             action=a.kind).inc()
        self._log("action_retry", action=a.kind, cid=a.tid,
                  attempt=attempt, backoff_s=backoff_s, error=repr(exc))
        if sp is not None:
            sp.child("orch.retry", attempt=attempt, backoff_s=backoff_s,
                     error=repr(exc)).end()

    # ------------------------------------------------------------------
    # Background services
    # ------------------------------------------------------------------
    def start(self, tick_interval: float = 0.02):
        self._started = True
        for entry in self._autoscalers:
            self._spawn_autoscale_loop(entry)

        def sched_loop():
            while not self._stop.is_set():
                self.tick()
                time.sleep(tick_interval)

        t = threading.Thread(target=sched_loop, daemon=True,
                             name="funky-scheduler")
        t.start()
        self._threads.append(t)

        if self.checkpoint_interval:
            def ckpt_loop():
                while not self._stop.wait(self.checkpoint_interval):
                    with self._lock:
                        running = [t.tid for t in self.scheduler.run_queue]
                    for cid in running:
                        try:
                            self.checkpoint(cid)
                        except Exception as e:  # noqa: BLE001
                            # a task may legitimately finish/evict under us,
                            # but a permanently broken snapshot path must
                            # not look like a healthy checkpoint service
                            self._log("ckpt_error", cid=cid, error=repr(e))

            t2 = threading.Thread(target=ckpt_loop, daemon=True,
                                  name="funky-ckpt")
            t2.start()
            self._threads.append(t2)

        if self.straggler_interval:
            def straggler_loop():
                while not self._stop.wait(self.straggler_interval):
                    try:
                        self.check_stragglers()
                    except Exception as e:  # noqa: BLE001
                        self._log("straggler_probe_error", error=repr(e))

            t3 = threading.Thread(target=straggler_loop, daemon=True,
                                  name="funky-straggler")
            t3.start()
            self._threads.append(t3)

    def stop(self):
        self._stop.set()
        for t in self._threads:
            t.join(timeout=10)

    # ------------------------------------------------------------------
    # Straggler mitigation
    # ------------------------------------------------------------------
    def check_stragglers(self, *, min_relative_rate: float = 0.5,
                         min_window_s: float = 1.0) -> List[str]:
        """Metrics-driven migration: node agents publish each task's guest
        step counter into the shared registry (``task_progress_steps``
        series + per-node ``node_progress_rate`` gauges), and the
        ``MigrationController`` flags tasks progressing below
        ``min_relative_rate`` x the peer median (>= 3 measurable peers
        required).  Flagged tasks are evicted so the scheduler's placement
        migrates their context to a healthier node.  Returns the cids
        acted on."""
        running: Dict[str, Optional[str]] = {}
        with self._lock:
            for st in list(self.scheduler.run_queue):
                agent = self.agents.get(st.node_id)
                if agent is None or agent.failed:
                    continue
                try:
                    step = agent.task_progress(st.tid)
                except NodeFailed:
                    continue
                if step is None:
                    continue
                self.migration.observe(st.tid, step)
                running[st.tid] = st.node_id
        decisions = self.migration.decide(
            running, min_relative_rate=min_relative_rate,
            min_window_s=min_window_s)
        acted = []
        for d in decisions:
            st = self._sched_tasks[d.cid]
            # only worth migrating if somewhere else has room
            if not any(self.free_slices(n) > 0 for n in self.nodes()
                       if n != st.node_id):
                continue
            ssp = self._span("orch.straggler_migrate", cid=d.cid,
                             node=st.node_id)
            try:
                self.agents[st.node_id].evict(d.cid)
            except Exception as e:  # noqa: BLE001 - task may just finish
                self._log("straggler_evict_error", cid=d.cid,
                          error=repr(e))
                if ssp is not None:
                    ssp.annotate(outcome="evict_error").end()
                continue
            with self._lock:
                self.scheduler.task_done(d.cid)
                st.state = TaskState.EVICTED
                # the freed slice would otherwise resume the straggler
                # straight back onto the degraded node — flag it so
                # placement scores the *other* candidates first
                st.meta["migrate_from"] = st.node_id
                self.scheduler.submit(st)
                self.migration.reset(d.cid)
            self._log("straggler_evicted", cid=d.cid, rate=d.rate,
                      median=d.median)
            if ssp is not None:
                ssp.annotate(outcome="evicted", rate=d.rate).end()
            if self.tracer is not None:
                pre = self.tracer.event_span(
                    "orch.migrate_out", cid=d.cid, node=st.node_id,
                    rate=d.rate, median=d.median)
                pre.finish()
                self._pending_migrate_links[d.cid] = pre
            acted.append(d.cid)
        return acted

    # ------------------------------------------------------------------
    # Fault tolerance
    # ------------------------------------------------------------------
    def handle_node_failure(self, node_id: str):
        """Restore tasks of a failed node from their latest snapshots.

        Per victim: (1) the dead node's task is hard-crashed — driver
        stopped with *no* graceful hooks, so its un-checkpointed work is
        genuinely lost; (2) a serve replica's leased in-flight requests
        are replayed back into the router queue (no request lost, none
        double-completed); (3) restore walks the snapshot candidates
        newest-first with bounded retries, falling back past corrupt
        checkpoints (``restore_fallback`` events) before resubmitting
        from scratch as the last resort."""
        from repro.core.runtime import TaskStatus as TS

        fsp = self._span("orch.node_failure", node=node_id)
        agent = self.agents[node_id]
        agent.fail()
        rt = agent.engine.runtime
        with self._lock:
            victims = [t for t in list(self.scheduler.run_queue)
                       if t.node_id == node_id]
            for st in victims:
                self.scheduler.task_done(st.tid)
                # pre-failure progress history measured the dead node
                self.migration.reset(st.tid)
                dep = self.deployments[st.tid]
                rec = rt.tasks.get(st.tid)
                if rec is not None and rec.status in (TS.CREATED,
                                                      TS.RUNNING,
                                                      TS.EVICTED):
                    rt.crash(st.tid)
                if (rec is not None
                        and getattr(rec.image, "kind", "") ==
                        "engine-serve"):
                    self._replay_serve_requests(rec.image.name, st.tid)
                # restore target chosen by the same placement engine (the
                # failed node's domain peers are penalized automatically)
                probe = SchedTask(tid=f"{st.tid}::restore",
                                  priority=st.priority, group=st.group,
                                  meta=dict(st.meta))
                target = self.placement.select_node(
                    probe, self, {}, running=self.scheduler.run_queue)
                rsp = (fsp.child("orch.restore", cid=st.tid)
                       if fsp is not None else None)
                snap = None
                if target is not None:
                    snap = self._restore_from_candidates(st, dep, target,
                                                         rsp)
                if snap is not None:
                    st.state = TaskState.RUNNING
                    st.node_id = target
                    self.scheduler.run_queue.append(st)
                    self._log("restored", cid=st.tid, node=target,
                              snap=snap)
                    if rsp is not None:
                        rsp.annotate(node=target, outcome="restored",
                                     snap=snap).end()
                else:
                    # no (usable) snapshot: restart from scratch
                    st.state = TaskState.WAITING
                    st.node_id = None
                    self.scheduler.submit(st)
                    self._log("resubmitted", cid=st.tid)
                    if rsp is not None:
                        rsp.annotate(outcome="resubmitted").end()
        if fsp is not None:
            fsp.end()

    def _replay_serve_requests(self, service: str, engine_id: str):
        """Re-enqueue a crashed serve replica's leased in-flight requests
        (router-level replay) so another replica picks them up."""
        from repro.scaling.serving import get_router

        try:
            n = get_router(service,
                           registry=self.metrics).fail_engine(engine_id)
        except Exception as e:  # noqa: BLE001 - recovery must not die here
            self._log("router_replay_error", cid=engine_id, error=repr(e))
            return
        if n:
            self._log("router_replay", cid=engine_id, service=service,
                      replayed=n)

    def _restore_from_candidates(self, st: SchedTask, dep: Deployment,
                                 target: str, rsp) -> Optional[str]:
        """Try snapshot candidates newest-first; each restore attempt gets
        bounded retries for transient faults and falls back to the next
        older snapshot on corruption.  Returns the path restored from."""
        from repro.ckpt.checkpoint import CheckpointCorruptError

        for snap in self._snapshot_candidates(st.tid):
            try:
                retry_call(
                    lambda: self.agents[target].restore(st.tid, snap,
                                                        dep.image_ref),
                    self.retry,
                    on_retry=lambda n, b, e: self._on_restore_retry(
                        st.tid, snap, rsp, n, b, e))
                return snap
            except (CheckpointCorruptError, TransientFault) as e:
                self.metrics.record_event(
                    "restore_fallback", task=st.tid, snap=snap,
                    error=repr(e))
                self._log("restore_fallback", cid=st.tid, snap=snap,
                          error=repr(e))
                if rsp is not None:
                    rsp.child("orch.restore_fallback", snap=snap,
                              error=repr(e)).end()
            except NodeFailed:
                return None           # restore target died too
        return None

    def _on_restore_retry(self, cid: str, snap: str, rsp, attempt: int,
                          backoff_s: float, exc: BaseException):
        self.metrics.counter("orchestrator_action_retries_total",
                             action="restore").inc()
        self._log("action_retry", action="restore", cid=cid,
                  attempt=attempt, backoff_s=backoff_s, error=repr(exc))
        if rsp is not None:
            rsp.child("orch.retry", attempt=attempt, backoff_s=backoff_s,
                      error=repr(exc)).end()

    def _snapshot_candidates(self, cid: str) -> List[str]:
        """All published snapshots for ``cid`` across every node's
        checkpoint root, newest step first (numeric step order)."""
        from repro.ckpt.checkpoint import snapshot_candidates

        roots = [agent.engine.runtime.ckpt_root
                 for agent in self.agents.values()]
        return snapshot_candidates(roots, cid)

    def _latest_snapshot_any(self, cid: str) -> Optional[str]:
        hits = self._snapshot_candidates(cid)
        return hits[0] if hits else None

    # ------------------------------------------------------------------
    def wait_all(self, timeout: float = 600.0) -> bool:
        deadline = time.time() + timeout
        while time.time() < deadline:
            with self._lock:
                pend = [d for d in self.deployments.values()
                        if d.status not in ("done", "failed", "removed")]
            if not pend:
                return True
            time.sleep(0.02)
        return False

    def _log(self, event: str, **kw):
        self.events.append((time.time(), event, kw))
        self.metrics.counter("orchestrator_events_total", event=event).inc()

    def _span(self, name: str, **labels):
        """Open a span on the cluster trace (None when tracing is off)."""
        if self._cluster_trace is None:
            return None
        return self._cluster_trace.span(name, **labels)
