"""Discrete-event cluster simulator (paper §5.6, Figs 11–13).

Replays (synthetic) Borg-like traces against a simulated vSlice cluster.
The *same* ``FunkyScheduler`` policy engine used by the live runtime drives
placement decisions; Funky-specific overheads (boot, reconfiguration, sync
wait, evict/resume/migrate/checkpoint byte costs) are inserted per event,
parameterized by the micro-benchmarks measured on the live runtime —
exactly the paper's methodology.

Modeling notes (matching §5.6):
* every job occupies one vSlice while running; an ``acceleration_rate`` r
  shortens its work to ``dur * (1 - r + r/speedup)`` with speedup = 1.6;
* worst case for Funky: the job's full memory footprint is dirty and must be
  saved/restored on every evict/checkpoint (capped at 8 GiB device memory);
* failures: a job fails once at ``fail_frac`` of its work; with periodic
  checkpointing it resumes from the latest snapshot, else restarts.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.scheduler import (Action, FunkyScheduler, Policy, SchedTask,
                                  TaskState)
from repro.core.traces import TraceJob


@dataclass
class SimParams:
    host_bw: float = 10e9           # device<->host, bytes/s (PCIe-ish)
    net_bw: float = 12.5e9          # node<->node, bytes/s (100 Gb/s)
    disk_bw: float = 0.5e9          # SSD write, bytes/s
    boot_s: float = 0.05            # sandbox boot (measured: unikernel-like)
    reconfig_s: float = 0.5         # program load/compile on deploy
    sync_wait_s: float = 0.1        # request-boundary wait (chunked)
    accel_speedup: float = 1.6      # measured FPGA-vs-CPU factor (paper)
    checkpoint_interval_s: Optional[float] = None
    acceleration_rate: float = 1.0  # fraction of work accelerable (Fig 11)


@dataclass
class SimJobState:
    job: TraceJob
    work: float                     # effective seconds of work required
    progress: float = 0.0           # completed work, seconds
    ckpt_progress: float = 0.0      # progress at last snapshot
    run_start: Optional[float] = None
    epoch: int = 0                  # invalidates stale finish/fail events
    failed_once: bool = False
    submit_t: float = 0.0
    first_start_t: Optional[float] = None
    finish_t: Optional[float] = None
    evictions: int = 0
    migrations: int = 0
    busy_until: float = 0.0         # overhead window before compute starts


class SimulatedCluster:
    """ClusterView over simulated nodes."""

    def __init__(self, num_nodes: int, slices_per_node: int):
        self.capacity = {f"node{i}": slices_per_node
                         for i in range(num_nodes)}
        self.used: Dict[str, int] = {n: 0 for n in self.capacity}
        self.placement: Dict[str, str] = {}

    def nodes(self) -> List[str]:
        return list(self.capacity)

    def free_slices(self, node: str) -> int:
        return self.capacity[node] - self.used[node]

    def running_tasks(self, node: str):  # unused by scheduler internals
        return []

    def occupy(self, node: str, tid: str):
        self.used[node] += 1
        self.placement[tid] = node

    def release(self, tid: str):
        node = self.placement.pop(tid, None)
        if node is not None:
            self.used[node] -= 1


class Simulator:
    def __init__(self, jobs: List[TraceJob], num_nodes: int,
                 slices_per_node: int = 1, policy: Policy = Policy.PRE_MG,
                 params: Optional[SimParams] = None):
        self.jobs = jobs
        self.params = params or SimParams()
        self.cluster = SimulatedCluster(num_nodes, slices_per_node)
        self.sched = FunkyScheduler(policy)
        self.states: Dict[str, SimJobState] = {}
        self.tasks: Dict[str, SchedTask] = {}
        self._heap: list = []
        self._seq = itertools.count()
        self.now = 0.0
        self.events_processed = 0

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: str, payload=None):
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def _effective_work(self, job: TraceJob) -> float:
        r = self.params.acceleration_rate
        return job.duration * (1 - r + r / self.params.accel_speedup)

    # -- overhead helpers ------------------------------------------------------
    def _evict_cost(self, st: SimJobState) -> float:
        return (self.params.sync_wait_s
                + st.job.memory_bytes / self.params.host_bw)

    def _resume_cost(self, st: SimJobState) -> float:
        return st.job.memory_bytes / self.params.host_bw

    def _migrate_cost(self, st: SimJobState) -> float:
        return st.job.memory_bytes / self.params.net_bw

    def _ckpt_cost(self, st: SimJobState) -> float:
        return (self.params.sync_wait_s
                + st.job.memory_bytes / self.params.disk_bw)

    # ------------------------------------------------------------------
    def run(self) -> dict:
        for job in self.jobs:
            self._push(job.submit_time, "submit", job)
        if self.params.checkpoint_interval_s:
            self._push(self.params.checkpoint_interval_s, "ckpt_tick")

        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            self.now = max(self.now, t)
            self.events_processed += 1
            getattr(self, f"_on_{kind}")(payload)
            self._schedule()
        return self._report()

    # -- event handlers ---------------------------------------------------------
    def _on_submit(self, job: TraceJob):
        st = SimJobState(job=job, work=self._effective_work(job),
                         submit_t=self.now)
        self.states[job.jid] = st
        task = SchedTask(tid=job.jid, priority=job.priority,
                         submit_time=self.now)
        self.tasks[job.jid] = task
        self.sched.submit(task)

    def _start_running(self, st: SimJobState, overhead: float):
        st.run_start = self.now + overhead
        st.busy_until = st.run_start
        if st.first_start_t is None:
            st.first_start_t = st.run_start
        st.epoch += 1
        remaining = st.work - st.progress
        fail_at = None
        if (st.job.fail_frac is not None and not st.failed_once):
            fail_point = st.job.fail_frac * st.work
            if fail_point > st.progress:
                fail_at = st.run_start + (fail_point - st.progress)
        finish_at = st.run_start + remaining
        if fail_at is not None and fail_at < finish_at:
            self._push(fail_at, "fail", (st.job.jid, st.epoch))
        else:
            self._push(finish_at, "finish", (st.job.jid, st.epoch))

    def _pause(self, st: SimJobState):
        """Accumulate progress and stop the clock for this job."""
        if st.run_start is not None:
            st.progress += max(0.0, self.now - st.run_start)
            st.progress = min(st.progress, st.work)
            st.run_start = None
        st.epoch += 1            # cancels in-flight finish/fail events

    def _on_finish(self, payload):
        jid, epoch = payload
        st = self.states[jid]
        if epoch != st.epoch or st.run_start is None:
            return               # stale event (task was evicted/failed)
        st.progress = st.work
        st.finish_t = self.now
        self.cluster.release(jid)
        self.sched.task_done(jid)
        self.tasks[jid].state = TaskState.DONE

    def _on_fail(self, payload):
        jid, epoch = payload
        st = self.states[jid]
        if epoch != st.epoch or st.run_start is None:
            return
        st.failed_once = True
        self._pause(st)
        # lose progress back to the last snapshot (or zero)
        st.progress = st.ckpt_progress
        self.cluster.release(jid)
        self.sched.task_done(jid)
        task = self.tasks[jid]
        task.state = TaskState.WAITING
        task.node_id = None
        self.sched.submit(task)   # restore/restart via normal scheduling

    def _on_ckpt_tick(self, _):
        p = self.params
        for jid, st in self.states.items():
            if st.run_start is not None and st.finish_t is None \
                    and self.now >= st.busy_until:
                # pause for the snapshot, then continue
                self._pause(st)
                st.ckpt_progress = st.progress
                self._start_running(st, self._ckpt_cost(st))
        # keep ticking while jobs remain unsubmitted or unfinished
        pending = (len(self.states) < len(self.jobs)
                   or any(s.finish_t is None for s in self.states.values()))
        if pending:
            self._push(self.now + p.checkpoint_interval_s, "ckpt_tick")

    # -- scheduling ----------------------------------------------------------
    def _schedule(self):
        actions = self.sched.schedule_once(self.cluster)
        for a in actions:
            st = self.states[a.tid]
            if a.kind == "deploy":
                self.cluster.occupy(a.node, a.tid)
                self._start_running(
                    st, self.params.boot_s + self.params.reconfig_s)
            elif a.kind == "evict":
                self._pause(st)
                st.evictions += 1
                self.cluster.release(a.tid)
                # eviction overhead occupies the *evicted* task's timeline
                st.busy_until = self.now + self._evict_cost(st)
            elif a.kind == "resume":
                self.cluster.occupy(a.node, a.tid)
                self._start_running(st, self._resume_cost(st))
            elif a.kind == "migrate":
                st.migrations += 1
                self.cluster.occupy(a.node, a.tid)
                self._start_running(
                    st, self._migrate_cost(st) + self._resume_cost(st))

    # -- reporting ---------------------------------------------------------------
    def _report(self) -> dict:
        done = [s for s in self.states.values() if s.finish_t is not None]
        if not done:
            return {"completed": 0}
        makespan = max(s.finish_t for s in done) - min(
            s.submit_t for s in self.states.values())
        lat = [s.finish_t - s.submit_t for s in done]
        exec_t = [s.finish_t - s.first_start_t for s in done
                  if s.first_start_t is not None]
        by_prio: Dict[int, list] = {}
        for s in done:
            by_prio.setdefault(s.job.priority, []).append(
                s.finish_t - s.submit_t)
        return {
            "completed": len(done),
            "makespan_s": makespan,
            "throughput_per_min": len(done) / (makespan / 60.0),
            "mean_latency_s": sum(lat) / len(lat),
            "mean_exec_s": sum(exec_t) / max(len(exec_t), 1),
            "latency_by_priority": {
                p: sum(v) / len(v) for p, v in sorted(by_prio.items())},
            "evictions": sum(s.evictions for s in self.states.values()),
            "migrations": sum(s.migrations for s in self.states.values()),
            "events": self.events_processed,
        }
