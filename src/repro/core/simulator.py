"""Discrete-event cluster simulator (paper §5.6, Figs 11–13).

Replays (synthetic) Borg-like traces against a simulated vSlice cluster.
The *same* ``FunkyScheduler`` + ``PlacementPolicy`` engine used by the live
runtime drives placement decisions — ``SimulatedCluster`` exposes the same
enriched view (synthetic failure domains, a warm program-cache model that
skips reconfiguration on warm deploys, per-node utilization gauges in the
virtual-clock registry); Funky-specific overheads (boot, reconfiguration, sync
wait, evict/resume/migrate/checkpoint byte costs) are inserted per event,
parameterized by the micro-benchmarks measured on the live runtime —
exactly the paper's methodology.

Modeling notes (matching §5.6):
* every job occupies one vSlice while running; an ``acceleration_rate`` r
  shortens its work to ``dur * (1 - r + r/speedup)`` with speedup = 1.6;
* worst case for Funky: the job's full memory footprint is dirty and must be
  saved/restored on every evict/checkpoint (capped at 8 GiB device memory);
* failures: a job fails once at ``fail_frac`` of its work; with periodic
  checkpointing it resumes from the latest snapshot, else restarts.
"""

from __future__ import annotations

import heapq
import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.placement import M_NODE_UTILIZATION
from repro.core.scheduler import (Action, FunkyScheduler, Policy, SchedTask,
                                  TaskState)
from repro.core.traces import TraceJob
from repro.scaling.autoscaler import (M_COMPLETIONS, M_KV_PAGES, M_LATENCY,
                                      M_PREEMPTIONS, M_PREFIX_HIT_RATE,
                                      M_QUEUE_DEPTH, M_REPLICAS,
                                      M_REPLICAS_SERIES, M_REQUESTS,
                                      M_SLO_VIOLATIONS, M_SPEC_ACCEPT_RATE,
                                      M_UTILIZATION, Autoscaler,
                                      signals_from_registry)
from repro.scaling.loadgen import ClosedLoopGen, Request
from repro.scaling.metrics import MetricsRegistry


@dataclass
class SimParams:
    host_bw: float = 10e9           # device<->host, bytes/s (PCIe-ish)
    net_bw: float = 12.5e9          # node<->node, bytes/s (100 Gb/s)
    disk_bw: float = 0.5e9          # SSD write, bytes/s
    boot_s: float = 0.05            # sandbox boot (measured: unikernel-like)
    reconfig_s: float = 0.5         # program load/compile on deploy
    sync_wait_s: float = 0.1        # request-boundary wait (chunked)
    accel_speedup: float = 1.6      # measured FPGA-vs-CPU factor (paper)
    checkpoint_interval_s: Optional[float] = None
    acceleration_rate: float = 1.0  # fraction of work accelerable (Fig 11)


@dataclass
class SimJobState:
    job: TraceJob
    work: float                     # effective seconds of work required
    progress: float = 0.0           # completed work, seconds
    ckpt_progress: float = 0.0      # progress at last snapshot
    run_start: Optional[float] = None
    epoch: int = 0                  # invalidates stale finish/fail events
    failed_once: bool = False
    submit_t: float = 0.0
    first_start_t: Optional[float] = None
    finish_t: Optional[float] = None
    evictions: int = 0
    migrations: int = 0
    busy_until: float = 0.0         # overhead window before compute starts


class SimulatedCluster:
    """Enriched ClusterView over simulated nodes: synthetic failure
    domains (round-robin across ``failure_domains`` when given, else every
    node its own domain) and a warm program-cache model (a node that ever
    compiled a job's programs stays warm — compile caches persist) — so
    the simulator's ``PlacementPolicy`` sees the same signal shapes as the
    live orchestrator's view."""

    def __init__(self, num_nodes: int, slices_per_node: int,
                 failure_domains: Optional[int] = None):
        self.capacity = {f"node{i}": slices_per_node
                         for i in range(num_nodes)}
        self.used: Dict[str, int] = {n: 0 for n in self.capacity}
        self.placement: Dict[str, str] = {}
        self.domains = {
            n: (f"dom{i % failure_domains}" if failure_domains else n)
            for i, n in enumerate(self.capacity)}
        self.warm: Dict[str, set] = {n: set() for n in self.capacity}

    def nodes(self) -> List[str]:
        return list(self.capacity)

    def free_slices(self, node: str) -> int:
        return self.capacity[node] - self.used[node]

    def running_tasks(self, node: str):  # unused by scheduler internals
        return []

    # -- enriched view (placement layer) --------------------------------
    def failure_domain(self, node: str) -> str:
        return self.domains[node]

    def warm_programs(self, node: str) -> set:
        return self.warm[node]

    def is_warm(self, node: str, programs) -> bool:
        return bool(programs) and set(programs) <= self.warm[node]

    def occupy(self, node: str, tid: str, programs=()):
        self.used[node] += 1
        self.placement[tid] = node
        self.warm[node].update(programs)

    def release(self, tid: str):
        node = self.placement.pop(tid, None)
        if node is not None:
            self.used[node] -= 1


class Simulator:
    def __init__(self, jobs: List[TraceJob], num_nodes: int,
                 slices_per_node: int = 1, policy: Policy = Policy.PRE_MG,
                 params: Optional[SimParams] = None,
                 placement=None, failure_domains: Optional[int] = None):
        self.jobs = jobs
        self.params = params or SimParams()
        self.cluster = SimulatedCluster(num_nodes, slices_per_node,
                                        failure_domains=failure_domains)
        self.states: Dict[str, SimJobState] = {}
        self.tasks: Dict[str, SchedTask] = {}
        self._heap: list = []
        self._seq = itertools.count()
        self.now = 0.0
        self.events_processed = 0
        # same telemetry schema as the live plane, virtual-clock timestamps
        self.metrics = MetricsRegistry(clock=lambda: self.now)
        # the *same* placement engine as the live plane, reading the
        # enriched SimulatedCluster view + this simulator's registry
        if placement is None:
            from repro.core.placement import PlacementPolicy
            placement = PlacementPolicy(registry=self.metrics)
        self.sched = FunkyScheduler(policy, placement=placement)

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: str, payload=None):
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def _effective_work(self, job: TraceJob) -> float:
        r = self.params.acceleration_rate
        return job.duration * (1 - r + r / self.params.accel_speedup)

    # -- overhead helpers ------------------------------------------------------
    def _evict_cost(self, st: SimJobState) -> float:
        return (self.params.sync_wait_s
                + st.job.memory_bytes / self.params.host_bw)

    def _resume_cost(self, st: SimJobState) -> float:
        return st.job.memory_bytes / self.params.host_bw

    def _migrate_cost(self, st: SimJobState) -> float:
        return st.job.memory_bytes / self.params.net_bw

    def _ckpt_cost(self, st: SimJobState) -> float:
        return (self.params.sync_wait_s
                + st.job.memory_bytes / self.params.disk_bw)

    # ------------------------------------------------------------------
    def run(self) -> dict:
        for job in self.jobs:
            self._push(job.submit_time, "submit", job)
        if self.params.checkpoint_interval_s:
            self._push(self.params.checkpoint_interval_s, "ckpt_tick")

        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            self.now = max(self.now, t)
            self.events_processed += 1
            getattr(self, f"_on_{kind}")(payload)
            self._schedule()
        return self._report()

    # -- event handlers ---------------------------------------------------------
    def _on_submit(self, job: TraceJob):
        st = SimJobState(job=job, work=self._effective_work(job),
                         submit_t=self.now)
        self.states[job.jid] = st
        task = SchedTask(tid=job.jid, priority=job.priority,
                         submit_time=self.now,
                         group=getattr(job, "group", None))
        progs = getattr(job, "programs", ())
        if progs:
            task.meta["programs"] = tuple(progs)
        self.tasks[job.jid] = task
        self.sched.submit(task)
        self.metrics.counter("sim_jobs_submitted_total").inc()

    def _start_running(self, st: SimJobState, overhead: float):
        st.run_start = self.now + overhead
        st.busy_until = st.run_start
        if st.first_start_t is None:
            st.first_start_t = st.run_start
        st.epoch += 1
        remaining = st.work - st.progress
        fail_at = None
        if (st.job.fail_frac is not None and not st.failed_once):
            fail_point = st.job.fail_frac * st.work
            if fail_point > st.progress:
                fail_at = st.run_start + (fail_point - st.progress)
        finish_at = st.run_start + remaining
        if fail_at is not None and fail_at < finish_at:
            self._push(fail_at, "fail", (st.job.jid, st.epoch))
        else:
            self._push(finish_at, "finish", (st.job.jid, st.epoch))

    def _pause(self, st: SimJobState):
        """Accumulate progress and stop the clock for this job."""
        if st.run_start is not None:
            st.progress += max(0.0, self.now - st.run_start)
            st.progress = min(st.progress, st.work)
            st.run_start = None
        st.epoch += 1            # cancels in-flight finish/fail events

    def _on_finish(self, payload):
        jid, epoch = payload
        st = self.states[jid]
        if epoch != st.epoch or st.run_start is None:
            return               # stale event (task was evicted/failed)
        st.progress = st.work
        st.finish_t = self.now
        self.cluster.release(jid)
        self.sched.task_done(jid)
        self.tasks[jid].state = TaskState.DONE
        self.metrics.counter("sim_jobs_completed_total").inc()
        self.metrics.histogram("job_latency_seconds",
                               window_s=float("inf")).observe(
            self.now - st.submit_t)

    def _on_fail(self, payload):
        jid, epoch = payload
        st = self.states[jid]
        if epoch != st.epoch or st.run_start is None:
            return
        st.failed_once = True
        self._pause(st)
        # lose progress back to the last snapshot (or zero)
        st.progress = st.ckpt_progress
        self.cluster.release(jid)
        self.sched.task_done(jid)
        task = self.tasks[jid]
        task.state = TaskState.WAITING
        task.node_id = None
        self.sched.submit(task)   # restore/restart via normal scheduling

    def _on_ckpt_tick(self, _):
        p = self.params
        for jid, st in self.states.items():
            if st.run_start is not None and st.finish_t is None \
                    and self.now >= st.busy_until:
                # pause for the snapshot, then continue
                self._pause(st)
                st.ckpt_progress = st.progress
                self._start_running(st, self._ckpt_cost(st))
        # keep ticking while jobs remain unsubmitted or unfinished
        pending = (len(self.states) < len(self.jobs)
                   or any(s.finish_t is None for s in self.states.values()))
        if pending:
            self._push(self.now + p.checkpoint_interval_s, "ckpt_tick")

    # -- scheduling ----------------------------------------------------------
    def _schedule(self):
        actions = self.sched.schedule_once(self.cluster)
        for a in actions:
            st = self.states[a.tid]
            if a.kind == "deploy":
                progs = getattr(st.job, "programs", ())
                # warm program cache: the node already compiled this job's
                # bitstreams, so deploy skips reconfiguration (the paper's
                # warmed-up-FPGA behavior the placement layer optimizes for)
                warm = self.cluster.is_warm(a.node, progs)
                self.cluster.occupy(a.node, a.tid, programs=progs)
                self._start_running(
                    st, self.params.boot_s
                    + (0.0 if warm else self.params.reconfig_s))
            elif a.kind == "evict":
                self._pause(st)
                st.evictions += 1
                self.cluster.release(a.tid)
                # eviction overhead occupies the *evicted* task's timeline
                st.busy_until = self.now + self._evict_cost(st)
            elif a.kind == "resume":
                self.cluster.occupy(a.node, a.tid)
                self._start_running(st, self._resume_cost(st))
            elif a.kind == "migrate":
                st.migrations += 1
                self.cluster.occupy(
                    a.node, a.tid, programs=getattr(st.job, "programs", ()))
                self._start_running(
                    st, self._migrate_cost(st) + self._resume_cost(st))
            self.metrics.counter("sim_actions_total", kind=a.kind).inc()
        self.metrics.gauge("wait_queue_depth").set(
            len(self.sched.wait_queue))
        cap = sum(self.cluster.capacity.values())
        if cap:
            self.metrics.gauge("cluster_utilization").set(
                sum(self.cluster.used.values()) / cap)
            for n, c in self.cluster.capacity.items():
                self.metrics.gauge(M_NODE_UTILIZATION, node=n).set(
                    self.cluster.used[n] / c)

    # -- reporting ---------------------------------------------------------------
    def _report(self) -> dict:
        done = [s for s in self.states.values() if s.finish_t is not None]
        if not done:
            return {"completed": 0}
        makespan = max(s.finish_t for s in done) - min(
            s.submit_t for s in self.states.values())
        lat = [s.finish_t - s.submit_t for s in done]
        exec_t = [s.finish_t - s.first_start_t for s in done
                  if s.first_start_t is not None]
        by_prio: Dict[int, list] = {}
        for s in done:
            by_prio.setdefault(s.job.priority, []).append(
                s.finish_t - s.submit_t)
        return {
            "completed": len(done),
            "makespan_s": makespan,
            "throughput_per_min": len(done) / (makespan / 60.0),
            "mean_latency_s": sum(lat) / len(lat),
            "mean_exec_s": sum(exec_t) / max(len(exec_t), 1),
            "latency_by_priority": {
                p: sum(v) / len(v) for p, v in sorted(by_prio.items())},
            "evictions": sum(s.evictions for s in self.states.values()),
            "migrations": sum(s.migrations for s in self.states.values()),
            "events": self.events_processed,
        }


# ---------------------------------------------------------------------------
# Elastic-serving simulation: autoscaler in the loop (Fig 14)
# ---------------------------------------------------------------------------
@dataclass
class ServingParams:
    provision_delay_s: float = 0.55     # sandbox boot + reconfiguration
    control_interval_s: float = 1.0     # autoscaler reconcile period
    slo_latency_s: float = 0.5          # per-request latency SLO
    hist_window_s: float = 10.0         # signal window for tail latency


@dataclass
class KVModelParams:
    """Cache-memory occupancy model for the serving simulator, mirroring
    the live engine's paged KV pool: a request holds its prompt pages for
    its whole service time and grows by one page per ``page_tokens``
    generated tokens.  When the (service-wide ``active * pool_pages``)
    pool exhausts, the growing request is OOM-preempted back to the queue
    head — the same recomputation rule as the live engine — so memory
    pressure shows up both as the ``kv_pages_in_use_ratio`` signal and as
    preemption-inflated latency."""
    pool_pages: int = 64                # per replica
    page_tokens: int = 8
    prompt_tokens: int = 16
    default_tokens: int = 8             # requests without n_tokens

    def prompt_pages(self) -> int:
        return max(1, -(-self.prompt_tokens // self.page_tokens))

    def total_pages(self, req: Request) -> int:
        n = (req.n_tokens if getattr(req, "n_tokens", None)
             else self.default_tokens)
        return max(1, -(-(self.prompt_tokens + n) // self.page_tokens))


def spec_tokens_per_iteration(spec_k: int, accept_rate: float) -> float:
    """Expected tokens committed per speculative iteration under a
    per-token acceptance probability ``accept_rate``: the accepted prefix
    is geometric, so E = sum_{i=0..k} a^i (1 at a=0 — plain decode — and
    k+1 at a=1, the forced-accept ceiling)."""
    a = min(max(accept_rate, 0.0), 1.0)
    return sum(a ** i for i in range(spec_k + 1))


def engine_service_model(ttft_s: float, tbt_s: float,
                         default_tokens: int = 8, *, spec_k: int = 0,
                         spec_accept_rate: float = 0.0,
                         prefix_hit_rate: float = 0.0):
    """Service-time function from engine-reported latencies.

    ``ttft_s``/``tbt_s`` come from the live engine's ``request_ttft_seconds``
    / ``request_tbt_seconds`` histograms, so the simulator's SLO attainment
    is grounded in on-device measurements (the paper's §5.6 methodology:
    overheads measured live, replayed at trace scale) instead of an assumed
    exponential service time.  Requests carrying ``n_tokens`` get
    ``ttft + (n-1) * tbt``; others fall back to ``default_tokens``.

    ``spec_k``/``spec_accept_rate`` model a *hypothetical* speculative
    deployment from plain-engine calibration: one iteration commits
    ``spec_tokens_per_iteration`` tokens on average, so the per-token time
    shrinks by that factor.  (Calibrating ``tbt_s`` from a live speculative
    engine already folds the speedup in — leave them 0 then.)

    ``prefix_hit_rate`` models a prefix cache: that fraction of prompt
    tokens is served from cached KV pages instead of prefill compute, so
    the time-to-first-token shrinks proportionally (TTFT is prefill-bound
    for the short-generation serving mixes fig 14/15 replay).  Calibrate
    it from the live drive loop's folded ``prefix_hit_rate`` gauge.
    """
    speedup = (spec_tokens_per_iteration(spec_k, spec_accept_rate)
               if spec_k > 0 else 1.0)
    hit = min(max(prefix_hit_rate, 0.0), 1.0)

    def service_time(req: Request) -> float:
        n = req.n_tokens if getattr(req, "n_tokens", None) else default_tokens
        return ttft_s * (1.0 - hit) + max(0, n - 1) * tbt_s / speedup
    return service_time


def disaggregated_service_model(ttft_s: float, tbt_s: float,
                                default_tokens: int = 8, *,
                                transfer_s: float = 0.0,
                                fallback_rate: float = 0.0):
    """Role-aware service-time function for a disaggregated deployment.

    Models the decode pool's occupancy per request: prefill runs on a
    separate replica class, so a decode server holds a lane only for its
    ``(n-1) * tbt`` generation tail plus the KV handoff install
    (``transfer_s``, the TransferQueue's EWMA install cost).  The
    TTFT-aware admission path refuses ``fallback_rate`` of handoffs —
    those lanes decode their first tokens on the prefill side, which
    shows up here as the fallback fraction of prefill time landing back
    on the pool (the aggregated-fallback guarantee: at ``fallback_rate
    = 1`` this degrades exactly to ``engine_service_model``, never
    worse).  Calibrate all four inputs from the live disaggregated
    arm's histograms and ``TransferQueue.stats()``.
    """
    fb = min(max(fallback_rate, 0.0), 1.0)

    def service_time(req: Request) -> float:
        n = req.n_tokens if getattr(req, "n_tokens", None) else default_tokens
        return (max(0, n - 1) * tbt_s
                + (1.0 - fb) * transfer_s + fb * ttft_s)
    return service_time


class ServingSimulator:
    """Discrete-event M/G/n serving loop with the autoscaler in the loop.

    Requests (from ``repro.scaling.loadgen``) queue FIFO for ``replicas``
    identical servers.  Every ``control_interval_s`` the ``Autoscaler``
    reads the canonical service signals from this simulator's virtual-clock
    ``MetricsRegistry`` — exactly the signals the live orchestrator's
    reconcile loop reads — and retargets the replica count.  Scale-out pays
    ``provision_delay_s`` (boot + reconfigure, as measured on the live
    runtime); scale-in removes idle replicas immediately and drains busy
    ones at their next request boundary, the paper's request-boundary rule.
    """

    def __init__(self, requests: List[Request], *,
                 autoscaler: Optional[Autoscaler] = None,
                 initial_replicas: int = 1, service: str = "svc",
                 params: Optional[ServingParams] = None,
                 closed_gen: Optional[ClosedLoopGen] = None,
                 service_time_fn=None,
                 kv_model: Optional[KVModelParams] = None,
                 spec_accept_rate: Optional[float] = None,
                 prefix_hit_rate: Optional[float] = None,
                 trace: bool = False):
        self.params = params or ServingParams()
        self.autoscaler = autoscaler
        self.service = service
        self.closed_gen = closed_gen
        # speculation acceptance assumed by the service model (published
        # as the canonical gauge so policies see the same signal shape the
        # live drive loop folds from per-engine gauges)
        self.spec_accept_rate = spec_accept_rate
        # prefix-cache hit rate assumed by the service model (published as
        # the canonical gauge, mirroring the live loop's service-mean fold)
        self.prefix_hit_rate = prefix_hit_rate
        # default: the trace's pre-drawn exponential demand; engine-served
        # figures pass engine_service_model(...) instead
        self._service_time = service_time_fn or (lambda r: r.service_s)
        self.now = 0.0
        self.metrics = MetricsRegistry(clock=lambda: self.now)
        # virtual-clock tracing: the same span abstraction the live plane
        # uses, timestamped in simulated seconds (deterministic)
        self.tracer = None
        self._req_trace: Dict[str, tuple] = {}   # rid -> (trace, open span)
        if trace:
            from repro.obs import Tracer
            self.tracer = Tracer(clock=lambda: self.now, capacity=4096,
                                 sample_rate=1.0)
        self.active = initial_replicas          # provisioned servers
        self.provisioning = 0                   # servers booting
        self._provision_cancel = 0
        self.draining = 0                       # busy servers to retire
        self.busy = 0
        self.queue: deque = deque()
        self._heap: list = []
        self._seq = itertools.count()
        self._pending_arrivals = 0
        self._latencies: List[float] = []
        self.violations = 0
        self.events_processed = 0
        # paged KV occupancy model (optional): pages held per in-service
        # request, epochs invalidate depart/grow events after a preemption
        self.kv = kv_model
        self._kv_used = 0
        self._kv_held: Dict[str, int] = {}
        self._kv_epoch: Dict[str, int] = {}
        self.kv_preemptions = 0
        self.kv_peak_occupancy = 0.0
        for r in requests:
            self._push(r.arrival_t, "arrive", r)
        self._record_replicas()

    # -- plumbing ----------------------------------------------------------
    def _push(self, t: float, kind: str, payload=None):
        if kind == "arrive":
            self._pending_arrivals += 1
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def _work_remains(self) -> bool:
        return bool(self._pending_arrivals or self.busy or self.queue)

    def _committed(self) -> int:
        """Replica count once all in-flight transitions settle: booting
        servers land (minus cancelled boots), draining servers retire."""
        return (self.active + self.provisioning - self._provision_cancel
                - self.draining)

    def _record_replicas(self):
        self.metrics.gauge(M_REPLICAS, service=self.service).set(
            self._committed())
        self.metrics.series(M_REPLICAS_SERIES, service=self.service,
                            capacity=65536).record(self.active)

    def _kv_capacity(self) -> int:
        return max(self.active, 1) * self.kv.pool_pages

    def _kv_occupancy(self) -> float:
        return self._kv_used / max(self._kv_capacity(), 1)

    def _publish_signals(self):
        self.metrics.gauge(M_QUEUE_DEPTH, service=self.service).set(
            len(self.queue))
        self.metrics.gauge(M_UTILIZATION, service=self.service).set(
            self.busy / max(self.active, 1))
        if self.kv is not None:
            self.metrics.gauge(M_KV_PAGES, service=self.service).set(
                self._kv_occupancy())
        if self.spec_accept_rate is not None:
            self.metrics.gauge(M_SPEC_ACCEPT_RATE,
                               service=self.service).set(
                self.spec_accept_rate)
        if self.prefix_hit_rate is not None:
            self.metrics.gauge(M_PREFIX_HIT_RATE,
                               service=self.service).set(
                self.prefix_hit_rate)
        self._record_replicas()

    # -- event handlers ----------------------------------------------------
    def _dispatch(self):
        while self.queue and self.busy < self.active:
            if self.kv is not None:
                # memory-based admission: an idle server alone is not
                # enough, the prompt's pages must fit in the pool
                need = self.kv.prompt_pages()
                if self._kv_used + need > self._kv_capacity():
                    break
            req = self.queue.popleft()
            self.busy += 1
            if req.rid in self._req_trace:
                tr, sp = self._req_trace[req.rid]
                if sp is not None:
                    sp.end()
                self._req_trace[req.rid] = (tr, tr.span("sim.service"))
            dur = self._service_time(req)
            epoch = self._kv_epoch.get(req.rid, 0)
            if self.kv is not None:
                need = self.kv.prompt_pages()
                self._kv_used += need
                self._kv_held[req.rid] = need
                self.kv_peak_occupancy = max(self.kv_peak_occupancy,
                                             self._kv_occupancy())
                extra = self.kv.total_pages(req) - need
                for i in range(extra):
                    # decode crosses one page boundary per page_tokens
                    # tokens; spread the growth across the service time
                    self._push(self.now + dur * (i + 1) / (extra + 1),
                               "kv_grow", (req, epoch))
            self._push(self.now + dur, "depart", (req, epoch))

    def _on_arrive(self, req: Request):
        self._pending_arrivals -= 1
        self.metrics.counter(M_REQUESTS, service=self.service).inc()
        if self.tracer is not None:
            tr = self.tracer.start_trace("request", trace_id=req.rid,
                                         service=self.service)
            self._req_trace[req.rid] = (tr, tr.span("router.queue"))
        self.queue.append(req)
        self._dispatch()

    def _on_kv_grow(self, payload):
        req, epoch = payload
        if (req.rid not in self._kv_held
                or epoch != self._kv_epoch.get(req.rid, 0)):
            return                       # departed or already preempted
        if self._kv_used < self._kv_capacity():
            self._kv_used += 1
            self._kv_held[req.rid] += 1
            self.kv_peak_occupancy = max(self.kv_peak_occupancy,
                                         self._kv_occupancy())
            return
        # pool exhausted: OOM-preempt this request back to the queue head
        # (deterministic recomputation, like the live engine) — its pages
        # free up, its depart event is invalidated by the epoch bump
        self._kv_used -= self._kv_held.pop(req.rid)
        self._kv_epoch[req.rid] = epoch + 1
        self.busy -= 1
        self.queue.appendleft(req)
        self.kv_preemptions += 1
        self.metrics.counter(M_PREEMPTIONS, service=self.service).inc()
        if req.rid in self._req_trace:
            tr, sp = self._req_trace[req.rid]
            if sp is not None:
                sp.annotate(preempted=True).end()
            self._req_trace[req.rid] = (tr, tr.span("router.queue",
                                                    requeued=True))
        self._dispatch()

    def _on_depart(self, payload):
        req, epoch = payload
        if epoch != self._kv_epoch.get(req.rid, 0):
            return                       # stale: request was OOM-preempted
        if self.kv is not None:
            self._kv_used -= self._kv_held.pop(req.rid, 0)
        self.busy -= 1
        latency = self.now - req.arrival_t
        if req.rid in self._req_trace:
            tr, sp = self._req_trace.pop(req.rid)
            if sp is not None:
                sp.end()
            tr.finish(latency_s=latency)
        self._latencies.append(latency)
        self.metrics.counter(M_COMPLETIONS, service=self.service).inc()
        self.metrics.histogram(M_LATENCY, service=self.service,
                               window_s=self.params.hist_window_s,
                               ).observe(latency)
        if latency > self.params.slo_latency_s:
            self.violations += 1
            self.metrics.counter(M_SLO_VIOLATIONS,
                                 service=self.service).inc()
        if self.closed_gen is not None:
            nxt = self.closed_gen.on_complete(req, self.now)
            if nxt is not None:
                self._push(nxt.arrival_t, "arrive", nxt)
        if self.draining > 0:
            # request-boundary decommission of a surplus replica
            self.draining -= 1
            self.active -= 1
            self._record_replicas()
        else:
            self._dispatch()

    def _on_provision(self, _):
        if self._provision_cancel > 0:       # retargeted down mid-boot
            self._provision_cancel -= 1
            self.provisioning -= 1
            return
        self.provisioning -= 1
        self.active += 1
        self._record_replicas()
        self._dispatch()

    def _scale_towards(self, desired: int):
        committed = self._committed()
        if desired > committed:
            grow = desired - committed
            # un-drain busy servers first: cheapest capacity there is
            undrain = min(grow, self.draining)
            self.draining -= undrain
            grow -= undrain
            for _ in range(grow):
                if self._provision_cancel > 0:
                    self._provision_cancel -= 1   # revive a cancelled boot
                else:
                    self.provisioning += 1
                    self._push(self.now + self.params.provision_delay_s,
                               "provision")
        elif desired < committed:
            shrink = committed - desired
            cancel = min(shrink,
                         self.provisioning - self._provision_cancel)
            self._provision_cancel += cancel
            shrink -= cancel
            idle = max(0, self.active - self.busy)
            immediate = min(shrink, idle)
            self.active -= immediate
            # the rest retire at their next request boundary; committed
            # already counts existing drains, so this never re-applies an
            # earlier shrink
            self.draining += shrink - immediate
        self._record_replicas()

    def _on_control(self, _):
        self._publish_signals()
        if self.autoscaler is not None:
            signals = signals_from_registry(self.metrics, self.service)
            desired = self.autoscaler.reconcile(signals, self.now)
            if desired is not None:
                self._scale_towards(desired)
        if self._work_remains():
            self._push(self.now + self.params.control_interval_s, "control")

    # -- driver ------------------------------------------------------------
    def run(self) -> dict:
        self._push(0.0, "control")
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            self.now = max(self.now, t)
            self.events_processed += 1
            getattr(self, f"_on_{kind}")(payload)
        return self.report()

    def report(self) -> dict:
        lat = sorted(self._latencies)

        def q(p):
            if not lat:
                return float("nan")
            return lat[min(len(lat) - 1, int(p * len(lat)))]

        replicas_ts = self.metrics.series(M_REPLICAS_SERIES,
                                          service=self.service,
                                          capacity=65536)
        n = len(lat)
        out = {
            "completed": n,
            "slo_attainment": (n - self.violations) / n if n else
            float("nan"),
            "mean_latency_s": sum(lat) / n if n else float("nan"),
            "p50_latency_s": q(0.50),
            "p95_latency_s": q(0.95),
            "p99_latency_s": q(0.99),
            "mean_replicas": replicas_ts.time_weighted_mean(),
            "max_replicas": max((v for _, v in replicas_ts.points()),
                                default=self.active),
            "events": self.events_processed,
            "horizon_s": self.now,
        }
        if self.kv is not None:
            out["kv_preemptions"] = self.kv_preemptions
            out["kv_peak_occupancy"] = self.kv_peak_occupancy
        return out
