"""Funky runtime: the OCI-compliant low-level task runtime (paper §3.5).

Beyond the standard OCI lifecycle (create/start/kill/delete) it implements
the five Funky commands of Table 3:

    evict <cid>                  save device context to host RAM, free slot
    resume <cid[, node_id]>      resume locally or migrate from node_id
    checkpoint <cid>             snapshot VM+device state to disk
    replicate <cid, node_id>     clone a (possibly running) task onto a node
    update <cid, vfpga_num>      vertical scaling

One runtime daemon runs per worker node; each task gets a driver thread (the
guest vCPU) that calls ``task.step()`` through a run-gate, so orchestration
commands always land on request boundaries.
"""

from __future__ import annotations

import enum
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from repro.core.guest import FunkyCL
from repro.core.monitor import Monitor, MonitorState, NoSliceAvailable
from repro.core.state import GuestState, TaskSnapshot
from repro.core.tasks import GuestTask, TaskImage
from repro.core.vslice import SliceAllocator


class TaskStatus(enum.Enum):
    CREATED = "created"
    RUNNING = "running"
    EVICTED = "evicted"
    DONE = "done"
    FAILED = "failed"
    REMOVED = "removed"


@dataclass
class TaskRecord:
    cid: str
    image: TaskImage
    task: GuestTask
    monitor: Monitor
    guest_state: GuestState
    status: TaskStatus = TaskStatus.CREATED
    priority: int = 0
    preemptible: bool = True
    vfpga_num: int = 1
    annotations: dict = field(default_factory=dict)
    driver: Optional[threading.Thread] = None
    run_gate: threading.Event = field(default_factory=threading.Event)
    stop_flag: bool = False
    step_lock: threading.Lock = field(default_factory=threading.Lock)
    error: Optional[BaseException] = None
    latest_snapshot: Optional[str] = None
    boot_seconds: float = 0.0
    timeline: list = field(default_factory=list)

    def log(self, event: str, **kw):
        self.timeline.append((time.time(), event, kw))


class FunkyRuntime:
    def __init__(self, node_id: str, allocator: SliceAllocator,
                 ckpt_root: str = "/tmp/funky-ckpt", telemetry=None,
                 chaos=None):
        self.node_id = node_id
        self.allocator = allocator
        self.ckpt_root = ckpt_root
        # fault-injection plan (repro.chaos.FaultPlan); threaded into every
        # Monitor this runtime builds and into the checkpoint writer
        self.chaos = chaos
        self.tasks: Dict[str, TaskRecord] = {}
        self._lock = threading.Lock()
        self.alive = True
        # node-level program ("bitstream") cache: tasks sharing an image hit
        # warm compiled executables — the paper's warmed-up-FPGA behavior
        from repro.core.programs import ProgramCache
        from repro.scaling.metrics import MetricsRegistry

        self.programs = ProgramCache()
        self.telemetry = (telemetry if telemetry is not None
                          else MetricsRegistry())
        os.makedirs(ckpt_root, exist_ok=True)

    # ------------------------------------------------------------------
    # OCI lifecycle
    # ------------------------------------------------------------------
    def create(self, cid: str, image: TaskImage,
               annotations: Optional[dict] = None) -> TaskRecord:
        t0 = time.perf_counter()
        annotations = dict(annotations or {})
        rec = TaskRecord(
            cid=cid, image=image, task=image.instantiate(),
            monitor=Monitor(cid, self.allocator, programs=self.programs,
                            telemetry=self.telemetry, chaos=self.chaos),
            guest_state=GuestState(seed=image.seed),
            priority=int(annotations.get("priority", 0)),
            preemptible=annotations.get("preemptible", "true") == "true",
            annotations=annotations,
        )
        rec.boot_seconds = time.perf_counter() - t0
        rec.log("create", node=self.node_id)
        with self._lock:
            self.tasks[cid] = rec
        return rec

    def start(self, cid: str):
        rec = self.tasks[cid]
        if rec.status is TaskStatus.EVICTED:
            return self.resume(cid)
        rec.log("start", node=self.node_id)
        self._spawn_driver(rec, restore=False)

    def _spawn_driver(self, rec: TaskRecord, restore: bool):
        rec.run_gate.set()
        rec.stop_flag = False

        def drive():
            cl = FunkyCL(rec.monitor)
            try:
                rec.task.setup(cl, rec.guest_state, restore=restore)
                rec.status = TaskStatus.RUNNING
                done = False
                while not done:
                    rec.run_gate.wait()
                    if rec.stop_flag:
                        return
                    with rec.step_lock:
                        # re-check under the lock: we may have been parked
                        # (evict/checkpoint) while waiting to acquire it
                        if not rec.run_gate.is_set():
                            continue
                        done = rec.task.step(cl, rec.guest_state)
                rec.task.teardown(cl, rec.guest_state)
                rec.status = TaskStatus.DONE
                rec.log("done", step=rec.guest_state.step)
            except NoSliceAvailable as e:
                rec.status = TaskStatus.FAILED
                rec.error = e
                rec.log("failed", error="NoSliceAvailable")
            except BaseException as e:  # noqa: BLE001
                rec.status = TaskStatus.FAILED
                rec.error = e
                rec.log("failed", error=repr(e))

        rec.driver = threading.Thread(
            target=drive, name=f"driver-{rec.cid}", daemon=True)
        rec.driver.start()

    def _park_driver(self, rec: TaskRecord):
        """Block the driver between steps (cooperative pause)."""
        rec.run_gate.clear()
        # wait until the in-flight step (if any) finishes its enqueues
        with rec.step_lock:
            pass

    def drain(self, cid: str, timeout_s: float = 30.0) -> dict:
        """Graceful decommission: flip the task into its draining state
        (no new admissions) and wait until the work it already holds has
        finished — request-boundary scale-in without requeueing.  Tasks
        with no drain hook return immediately; a wedged drain times out
        and the caller falls back to the hard kill."""
        rec = self.tasks[cid]
        if rec.status is not TaskStatus.RUNNING:
            return {"drained": True, "waited_s": 0.0}
        if type(rec.task).drain is GuestTask.drain:
            # no draining notion (train tasks etc.): don't stall the
            # scale-in for the full timeout waiting on a no-op hook
            return {"drained": True, "waited_s": 0.0}
        t0 = time.perf_counter()
        rec.task.drain()
        # the driver notices the drained state on its next step and runs
        # teardown, flipping the status off RUNNING — wait (bounded) for
        # that so the follow-up kill finds a finished task
        deadline = t0 + timeout_s
        while (time.perf_counter() < deadline
               and rec.status is TaskStatus.RUNNING):
            time.sleep(0.005)
        waited = time.perf_counter() - t0
        stats = {"drained": rec.status is not TaskStatus.RUNNING
                 or rec.task.drained, "waited_s": waited}
        rec.log("drain", **stats)
        return stats

    def kill(self, cid: str):
        rec = self.tasks[cid]
        rec.stop_flag = True
        rec.run_gate.set()
        if rec.driver is not None:
            rec.driver.join(timeout=30)
        if rec.monitor.state in (MonitorState.RUNNING,):
            rec.monitor.vfpga_exit()
        try:
            rec.task.on_kill()
        except Exception:  # noqa: BLE001 - best-effort cleanup hook
            pass
        rec.status = TaskStatus.REMOVED
        rec.log("kill")

    def crash(self, cid: str):
        """Simulated hard crash of one task: the driver is stopped and the
        slice freed, but — unlike ``kill`` — the graceful ``on_kill`` hook
        never runs, so nothing is evacuated or requeued from inside the
        task.  Whatever recovery happens must come from outside (router
        lease replay + snapshot restore)."""
        rec = self.tasks[cid]
        rec.stop_flag = True
        rec.run_gate.set()
        if rec.driver is not None:
            rec.driver.join(timeout=30)
        if rec.monitor.state in (MonitorState.RUNNING,):
            rec.monitor.vfpga_exit()
        rec.status = TaskStatus.FAILED
        rec.log("crash")

    def delete(self, cid: str):
        with self._lock:
            self.tasks.pop(cid, None)

    # ------------------------------------------------------------------
    # Funky commands (Table 3)
    # ------------------------------------------------------------------
    def evict(self, cid: str, setup_timeout: float = 300.0) -> dict:
        rec = self.tasks[cid]
        # A task may still be booting (program compilation); eviction waits
        # for the context to exist, like the paper's sync-before-evict.
        deadline = time.time() + setup_timeout
        while rec.status is TaskStatus.CREATED and time.time() < deadline:
            time.sleep(0.005)
        if rec.status is not TaskStatus.RUNNING:
            raise RuntimeError(f"evict: {cid} is {rec.status}")
        t0 = time.perf_counter()
        self._park_driver(rec)
        stats = rec.monitor.evict()
        rec.status = TaskStatus.EVICTED
        stats["total_seconds"] = time.perf_counter() - t0
        rec.log("evict", **{k: v for k, v in stats.items()})
        return stats

    def resume(self, cid: str, source: Optional["FunkyRuntime"] = None) -> dict:
        """Resume an evicted task; if ``source`` is a remote runtime, pull the
        task context from it first (migration, Table 3)."""
        t0 = time.perf_counter()
        if source is not None and source is not self:
            rec = source.migrate_out(cid)
            rec.monitor.allocator = self.allocator
            with self._lock:
                self.tasks[cid] = rec
        rec = self.tasks[cid]
        stats = rec.monitor.resume(self.allocator)
        rec.status = TaskStatus.RUNNING
        if rec.driver is None or not rec.driver.is_alive():
            self._spawn_driver(rec, restore=True)
        else:
            rec.run_gate.set()
        stats["total_seconds"] = time.perf_counter() - t0
        rec.log("resume", node=self.node_id, **stats)
        return stats

    def migrate_out(self, cid: str) -> TaskRecord:
        """Hand the full evicted context to a peer runtime."""
        rec = self.tasks[cid]
        if rec.status is TaskStatus.RUNNING:
            self.evict(cid)
        rec.stop_flag = True
        rec.run_gate.set()
        if rec.driver is not None:
            rec.driver.join(timeout=30)
        rec.driver = None
        rec.run_gate = threading.Event()
        rec.stop_flag = False
        with self._lock:
            self.tasks.pop(cid, None)
        rec.log("migrate_out", node=self.node_id)
        return rec

    def _await_setup(self, rec: TaskRecord, timeout: float = 300.0):
        """Snapshots are only meaningful once the guest finished setup()."""
        deadline = time.time() + timeout
        while rec.status is TaskStatus.CREATED and time.time() < deadline:
            time.sleep(0.005)
        if rec.status is TaskStatus.CREATED:
            raise RuntimeError(f"{rec.cid}: setup did not finish in time")

    def checkpoint(self, cid: str, keep_running: bool = True) -> str:
        from repro.ckpt.checkpoint import save_snapshot

        rec = self.tasks[cid]
        self._await_setup(rec)
        if rec.status in (TaskStatus.DONE, TaskStatus.FAILED,
                          TaskStatus.REMOVED):
            raise RuntimeError(
                f"checkpoint: {cid} already {rec.status.value} "
                "(device context released)")
        self._park_driver(rec)
        try:
            snap = rec.monitor.checkpoint(rec.guest_state,
                                          keep_running=keep_running)
            snap.program_ids = tuple(rec.monitor.programs.program_ids())
            path = os.path.join(self.ckpt_root, f"{cid}-step{snap.step}")
            stats = save_snapshot(path, snap, image=rec.image,
                                  prev_path=rec.latest_snapshot,
                                  chaos=self.chaos)
            rec.latest_snapshot = path
            rec.log("checkpoint", path=path, bytes=snap.nbytes(),
                    reused_buffers=stats["reused_buffers"])
            return path
        finally:
            if keep_running:
                rec.run_gate.set()
            else:
                rec.status = TaskStatus.EVICTED

    def restore(self, cid: str, snapshot_path: str) -> TaskRecord:
        """Re-create a task from a disk snapshot and resume it here.

        Verifies digests; a corrupt snapshot falls back along its
        incremental ``prev_path`` chain to the last-good ancestor (each
        skip recorded as a ``restore_fallback`` event).  Raises
        ``CheckpointCorruptError`` only when no ancestor verifies."""
        from repro.ckpt.checkpoint import load_latest_good

        if self.chaos is not None:
            self.chaos.raise_if("ckpt.restore",
                                key=f"{self.node_id}:{cid}")
        snap, image, used_path, skipped = load_latest_good(snapshot_path)
        for bad_path, reason in skipped:
            self.telemetry.record_event(
                "restore_fallback", task=cid, node=self.node_id,
                skipped=bad_path, reason=reason, used=used_path)
        snapshot_path = used_path
        rec = TaskRecord(
            cid=cid, image=image, task=image.instantiate(),
            monitor=Monitor(cid, self.allocator, programs=self.programs,
                            telemetry=self.telemetry, chaos=self.chaos),
            guest_state=snap.guest_state.clone(),
        )
        rec.monitor.load_snapshot(snap)
        with self._lock:
            self.tasks[cid] = rec
        rec.status = TaskStatus.EVICTED
        rec.latest_snapshot = snapshot_path
        rec.log("restore", path=snapshot_path, fallbacks=len(skipped))
        self.resume(cid)
        return rec

    def replicate(self, cid: str, target: "FunkyRuntime",
                  new_cid: Optional[str] = None) -> str:
        """Horizontal scaling: clone a running task onto another node."""
        rec = self.tasks[cid]
        new_cid = new_cid or f"{cid}-rep{int(time.time() * 1000) % 100000}"
        self._await_setup(rec)
        self._park_driver(rec)
        try:
            snap = rec.monitor.checkpoint(rec.guest_state, keep_running=True)
        finally:
            rec.run_gate.set()
        clone = TaskRecord(
            cid=new_cid, image=rec.image, task=rec.image.instantiate(),
            monitor=Monitor(new_cid, target.allocator,
                            programs=target.programs,
                            telemetry=target.telemetry,
                            chaos=target.chaos),
            guest_state=snap.guest_state.clone(),
            priority=rec.priority, preemptible=rec.preemptible,
        )
        clone.monitor.load_snapshot(snap)
        with target._lock:
            target.tasks[new_cid] = clone
        clone.log("replicate_from", source=cid, node=target.node_id)
        target.resume(new_cid)
        return new_cid

    def update(self, cid: str, vfpga_num: int):
        """Vertical scaling: adjust the task's vSlice allowance."""
        rec = self.tasks[cid]
        rec.vfpga_num = vfpga_num
        rec.task.on_update(vfpga_num)
        rec.log("update", vfpga_num=vfpga_num)

    # ------------------------------------------------------------------
    def status(self, cid: str) -> TaskStatus:
        return self.tasks[cid].status

    def wait(self, cid: str, timeout: float = 300.0) -> TaskStatus:
        rec = self.tasks[cid]
        deadline = time.time() + timeout
        while time.time() < deadline:
            if rec.status in (TaskStatus.DONE, TaskStatus.FAILED,
                              TaskStatus.REMOVED):
                return rec.status
            time.sleep(0.005)
        return rec.status
