"""Funky requests (paper Table 2) — the four primitive device operations.

Every device interaction of a guest task is one of:

    MEMORY(buff_id, spec, size)          allocate/register a device buffer
    TRANSFER(queue, buff_id, src, size)  host<->device data movement
    EXECUTE(queue, program, args)        launch a compiled program
    SYNC(queue, req_id)                  await completion

Requests travel on a shared queue between the guest and the monitor's worker
thread (the paper's lock-free exitless-I/O rings; here a ``queue.Queue``
crossing a real thread boundary).  Each request carries a ``Completion``
future the guest can wait on — EXECUTE/TRANSFER are *asynchronous* unless the
guest SYNCs, mirroring the OpenCL command-queue model.
"""

from __future__ import annotations

import enum
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Optional


class RequestKind(enum.Enum):
    MEMORY = "MEMORY"
    TRANSFER = "TRANSFER"
    EXECUTE = "EXECUTE"
    SYNC = "SYNC"
    SHUTDOWN = "SHUTDOWN"      # internal: stop the worker thread


class Direction(enum.Enum):
    H2D = "h2d"
    D2H = "d2h"


class Completion:
    """Future for one request."""

    __slots__ = ("_event", "value", "error", "submitted_at", "completed_at",
                 "phases", "error_seen")

    def __init__(self):
        self._event = threading.Event()
        self.value: Any = None
        self.error: Optional[BaseException] = None
        # exactly-once failure surfacing: a completion may be awaited at
        # its issue site (sync reads) or at a later step boundary (async
        # EXECUTEs); whoever raises the error first sets this so the other
        # path doesn't re-raise or double-count it
        self.error_seen = False
        self.submitted_at = time.perf_counter()
        self.completed_at: Optional[float] = None
        # per-phase wall-time attribution filled in by the monitor worker
        # before set(): queue_wait_s always; EXECUTE adds prep_s (signature
        # lookup + compile), device_s and sig_hit; TRANSFER adds bytes and
        # direction; SYNC adds synced buffer count.  Populated whether or
        # not tracing is enabled, so the engine can compute its
        # host/device split without a tracer.
        self.phases: Optional[dict] = None

    def set(self, value: Any = None, error: Optional[BaseException] = None):
        self.value = value
        self.error = error
        self.completed_at = time.perf_counter()
        self._event.set()

    def wait(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError("request did not complete")
        if self.error is not None:
            raise self.error
        return self.value

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def latency(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at


_req_counter = itertools.count(1)


@dataclass
class FunkyRequest:
    kind: RequestKind
    req_id: int = field(default_factory=lambda: next(_req_counter))
    completion: Completion = field(default_factory=Completion)

    # MEMORY
    buff_id: Optional[str] = None
    spec: Any = None                    # abstract pytree (ShapeDtypeStructs)
    paged: bool = False                 # page-granular dirtiness (axis 0)

    # TRANSFER
    direction: Optional[Direction] = None
    host_value: Any = None              # h2d payload (host pytree)

    # EXECUTE
    program_id: Optional[str] = None
    in_buffs: tuple = ()
    out_buffs: tuple = ()
    const_args: tuple = ()              # small scalars passed by value
    # opt-in: donate inputs that are also outputs, so in-place updates
    # (KV caches, decode state) don't copy the buffer every step.  The
    # program must have been registered with matching donate_argnums or
    # the first EXECUTE pays a recompile.
    donate: bool = False
    # {out_buff_id: page ids written} for paged out buffers; a paged out
    # buffer absent from the dict is treated as fully dirtied
    dirty_pages: Optional[dict] = None

    # SYNC
    upto_req_id: Optional[int] = None   # None = all outstanding

    # tracing (optional): parent span in the submitter's trace; the
    # monitor worker hangs queue-wait/execute/transfer child spans off it.
    span: Any = None
    enqueue_t: Optional[float] = None   # trace-clock time at submit
    mon_span: Any = None                # set by the worker loop for handlers

    def __repr__(self) -> str:  # compact for logs
        return f"<{self.kind.value} #{self.req_id} buff={self.buff_id} prog={self.program_id}>"
