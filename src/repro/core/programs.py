"""Program ("bitstream") registry and compile cache.

An FPGA bitstream maps to an AOT-compiled XLA executable.  ``vfpga_init``'s
bitstream transfer + reconfiguration (≈3.5 s on the Vitis XDMA shell) maps to
``jit(fn).lower(specs).compile()`` — slow the first time, free on a cache hit
(a *warm* vSlice, the paper's "keep it warmed up" motivation §1).

Keyed by (program name, abstract arg tree structure); stats feed Fig 6.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax


@dataclass
class Program:
    program_id: str
    fn: Callable
    static_argnums: tuple = ()
    # how EXECUTE maps buffers: fn(*in_buffs_values, *const_args) -> outputs
    # matched positionally with out_buffs.


@dataclass
class CompiledEntry:
    compiled: Any
    compile_seconds: float
    arg_fingerprint: str


def _fingerprint(tree: Any) -> str:
    leaves = jax.tree.leaves(tree)
    parts = [f"{getattr(l, 'shape', ())}:{getattr(l, 'dtype', type(l).__name__)}"
             for l in leaves]
    return "|".join(parts)


class ProgramCache:
    def __init__(self):
        self._programs: Dict[str, Program] = {}
        self._compiled: Dict[tuple, CompiledEntry] = {}
        self._lock = threading.Lock()
        self.stats = {"hits": 0, "misses": 0, "compile_seconds": 0.0}

    def register(self, program: Program):
        with self._lock:
            self._programs[program.program_id] = program

    def __contains__(self, program_id: str) -> bool:
        return program_id in self._programs

    def get_program(self, program_id: str) -> Program:
        return self._programs[program_id]

    def get_or_compile(self, program_id: str, abstract_args: tuple,
                       donate_argnums: tuple = ()) -> CompiledEntry:
        """AOT-compile fn for the given abstract args (cache on fingerprint)."""
        prog = self._programs[program_id]
        fp = _fingerprint(abstract_args)
        key = (program_id, fp, donate_argnums)
        with self._lock:
            hit = self._compiled.get(key)
            if hit is not None:
                self.stats["hits"] += 1
                return hit
        t0 = time.perf_counter()
        jitted = jax.jit(prog.fn, donate_argnums=donate_argnums)
        compiled = jitted.lower(*abstract_args).compile()
        dt = time.perf_counter() - t0
        entry = CompiledEntry(compiled=compiled, compile_seconds=dt,
                              arg_fingerprint=fp)
        with self._lock:
            self._compiled[key] = entry
            self.stats["misses"] += 1
            self.stats["compile_seconds"] += dt
        return entry

    def program_ids(self):
        return tuple(self._programs)
