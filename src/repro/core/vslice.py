"""vSlice: the virtualized accelerator slot (paper's vFPGA).

A vSlice is a lease on a logical sub-mesh of a node's devices with a memory
budget (the Alveo U50's 8 GiB HBM maps to ``mem_cap_bytes``).  The node-local
``SliceAllocator`` implements the two hypercalls:

    vfpga_init(task)  -> acquire a free slot (+ program "reconfiguration")
    vfpga_free(slot)  -> release it (device memory zeroed by the monitor)
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Optional

from repro.launch.mesh import compat_make_mesh


@dataclass
class VSlice:
    node_id: str
    slice_id: int
    mesh: object                        # jax Mesh (1-device mesh on CPU hosts)
    mem_cap_bytes: int
    owner: Optional[str] = None         # task id
    configured_program: Optional[str] = None   # "bitstream" currently loaded

    @property
    def name(self) -> str:
        return f"{self.node_id}/vslice{self.slice_id}"


class SliceAllocator:
    """Per-node vSlice pool."""

    def __init__(self, node_id: str, num_slices: int,
                 mem_cap_bytes: int = 8 << 30, mesh=None):
        if mesh is None:
            mesh = compat_make_mesh((1, 1), ("data", "model"))
        self._lock = threading.Lock()
        self.node_id = node_id
        self.slices = [
            VSlice(node_id=node_id, slice_id=i, mesh=mesh,
                   mem_cap_bytes=mem_cap_bytes)
            for i in range(num_slices)
        ]

    def vfpga_init(self, task_id: str, program_id: Optional[str] = None
                   ) -> Optional[VSlice]:
        """Acquire a free slot for ``task_id``; None if all busy."""
        with self._lock:
            for s in self.slices:
                if s.owner is None:
                    s.owner = task_id
                    s.configured_program = program_id
                    return s
        return None

    def vfpga_free(self, vslice: VSlice):
        with self._lock:
            vslice.owner = None
            vslice.configured_program = None

    def free_count(self) -> int:
        with self._lock:
            return sum(1 for s in self.slices if s.owner is None)

    def owned_by(self, task_id: str):
        with self._lock:
            return [s for s in self.slices if s.owner == task_id]
