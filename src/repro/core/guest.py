"""FunkyCL: the OpenCL-compatible guest library (paper §3.3, Table 1).

The guest task sees the standard OpenCL host-API surface; each call is
converted to a hypercall or a Funky request exactly as in Table 1:

    clCreateProgramWithBinary  -> vfpga_init (slot acquire + reconfigure)
    clReleaseProgram           -> vfpga_exit (when refcount drops to zero)
    clCreateBuffer             -> MEMORY(buff_id, spec)
    clEnqueueMigrateMemObjects -> TRANSFER(queue, buff_id, ...)
    clEnqueueKernel            -> EXECUTE(queue, kernel, args)   [async]
    clFinish                   -> SYNC(queue)

Zero-copy note (§3.3): on real Funky the unikernel's single address space
lets the monitor translate guest pointers once; here host pytrees are handed
to the worker by reference through the queue — no serialization happens on
the TRANSFER path either.

Guest code must never touch ``jax.devices()`` directly; everything flows
through the monitor for isolation and state tracking.  Snake_case aliases are
provided for non-OpenCL-steeped callers.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence

from repro.core.monitor import Monitor
from repro.core.programs import Program
from repro.core.requests import (Completion, Direction, FunkyRequest,
                                 RequestKind)


class FunkyCL:
    def __init__(self, monitor: Monitor):
        self._monitor = monitor
        self._program_refs: dict[str, int] = {}
        self._pending: list[Completion] = []

    # ------------------------------------------------------------------
    # Program objects
    # ------------------------------------------------------------------
    def clCreateProgramWithBinary(self, program: Program,
                                  abstract_args: tuple,
                                  donate_argnums: tuple = ()) -> str:
        """Acquire a vFPGA and configure user logic (Table 1)."""
        if self._monitor.vslice is None:
            self._monitor.vfpga_init(program, abstract_args, donate_argnums)
        else:
            self._monitor.register_program(program, abstract_args,
                                           donate_argnums)
        pid = program.program_id
        self._program_refs[pid] = self._program_refs.get(pid, 0) + 1
        return pid

    def clReleaseProgram(self, program_id: str):
        """Decrement refcount; release the vFPGA when it reaches zero."""
        self._program_refs[program_id] -= 1
        if all(v <= 0 for v in self._program_refs.values()):
            self.clFinish()
            self._monitor.vfpga_exit()

    # ------------------------------------------------------------------
    # Buffers & transfers
    # ------------------------------------------------------------------
    def clCreateBuffer(self, buff_id: str, spec: Any,
                       paged: bool = False) -> str:
        """``paged=True`` registers a page-pool buffer (every leaf's axis 0
        is the page axis): subsequent EXECUTEs can report ``dirty_pages`` so
        evict/checkpoint serialize only the pages actually written."""
        req = FunkyRequest(kind=RequestKind.MEMORY, buff_id=buff_id,
                           spec=spec, paged=paged)
        self._track(self._monitor.submit(req))
        return buff_id

    def clEnqueueMigrateMemObjects(self, buff_id: str,
                                   host_value: Any = None,
                                   to_device: bool = True,
                                   span: Any = None) -> Completion:
        req = FunkyRequest(
            kind=RequestKind.TRANSFER, buff_id=buff_id,
            direction=Direction.H2D if to_device else Direction.D2H,
            host_value=host_value, span=span)
        return self._track(self._monitor.submit(req))

    # ------------------------------------------------------------------
    # Kernels
    # ------------------------------------------------------------------
    def clEnqueueKernel(self, program_id: str, in_buffs: Sequence[str],
                        out_buffs: Sequence[str],
                        const_args: tuple = (),
                        donate: bool = False,
                        dirty_pages: Optional[dict] = None,
                        span: Any = None) -> Completion:
        """Async kernel launch; kernel args travel with the EXECUTE request
        (clSetKernelArg coalescing, paper §4).  ``donate=True`` donates
        inputs that are also outputs (in-place update, no device copy) —
        register the program with matching donate_argnums to avoid a
        recompile on first use.  ``dirty_pages`` maps a paged out buffer to
        the page ids this launch writes, keeping evict/checkpoint costs
        proportional to pages touched rather than pool size."""
        req = FunkyRequest(
            kind=RequestKind.EXECUTE, program_id=program_id,
            in_buffs=tuple(in_buffs), out_buffs=tuple(out_buffs),
            const_args=tuple(const_args), donate=donate,
            dirty_pages=dirty_pages, span=span)
        return self._track(self._monitor.submit(req))

    def clFinish(self) -> None:
        req = FunkyRequest(kind=RequestKind.SYNC)
        self._monitor.submit(req).wait()
        for c in self._pending:
            c.wait()
        self._pending.clear()

    # ------------------------------------------------------------------
    # Convenience (non-OpenCL helpers used by our example tasks)
    # ------------------------------------------------------------------
    create_program = clCreateProgramWithBinary
    release_program = clReleaseProgram
    create_buffer = clCreateBuffer
    enqueue_kernel = clEnqueueKernel
    finish = clFinish

    def write_buffer(self, buff_id: str, host_value: Any,
                     span: Any = None) -> Completion:
        return self.clEnqueueMigrateMemObjects(buff_id, host_value,
                                               to_device=True, span=span)

    def read_buffer(self, buff_id: str, span: Any = None) -> Any:
        return self.clEnqueueMigrateMemObjects(
            buff_id, to_device=False, span=span).wait()

    def _track(self, c: Completion) -> Completion:
        self._pending.append(c)
        if len(self._pending) > 1024:
            self._pending = [p for p in self._pending if not p.done]
        return c
