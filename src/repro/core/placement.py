"""Unified placement layer: one scoring engine for every placement decision.

Before this module, placement logic lived in four call sites — the
scheduler's ``_select_node``/``_find_victim``, the autoscaler's scale-out
path (``Orchestrator._pick_free_node``), the straggler probe's migration
choice, and the trace simulator's ``_schedule`` — so scale-out ignored warm
program caches and failure domains, and migration ran off a private probe
nobody else could observe.  Now all four delegate to a single
``PlacementPolicy`` over an *enriched* cluster view:

* **free vSlices** (capacity-first, like the old max-free rule);
* **failure domains** — ``view.failure_domain(node)``; replicas of one
  ``ServiceGroup`` are spread across domains (anti-affinity is
  lexicographically dominant: a node whose domain already hosts a group
  member is only chosen when no conflict-free node has a free slice);
* **warm program caches** — ``view.warm_programs(node)`` (the node-level
  ``ProgramCache.program_ids()``); a node already holding the service's
  compiled programs skips bitstream reconfiguration, so at equal capacity
  the warm node wins;
* **per-node utilization / progress-rate gauges** read from the shared
  ``repro.scaling.metrics`` registry (the same schema on both planes).

``MigrationController`` replaces ``check_stragglers``'s private probe: node
agents publish per-task progress into the registry
(``task_progress_steps`` series, ``node_utilization`` /
``node_progress_rate`` gauges) and the controller decides evict+migrate
purely from those metrics — live plane and simulator see the same signal
shapes under their respective clocks.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.scheduler import SchedTask, TaskState
from repro.scaling.metrics import metric_key

# Canonical per-node / per-task metric names (shared with the simulator).
M_NODE_UTILIZATION = "node_utilization"           # used / total slices, 0..1
M_NODE_PROGRESS_RATE = "node_progress_rate"       # mean guest steps/s
M_TASK_PROGRESS = "task_progress_steps"           # TimeSeries of step counts
M_NODE_KV_FREE = "node_kv_free_pages"             # free KV pool pages


def _median(values: List[float]) -> float:
    """Proper median: mean of the two middle elements for even counts (the
    old straggler probe took the upper element, biasing the threshold)."""
    vals = sorted(values)
    n = len(vals)
    if n == 0:
        return math.nan
    mid = n // 2
    if n % 2:
        return vals[mid]
    return (vals[mid - 1] + vals[mid]) / 2.0


# ---------------------------------------------------------------------------
# Service groups
# ---------------------------------------------------------------------------
@dataclass
class ServiceGroup:
    """Replicas of one service, as the scheduler sees them.

    Tasks carry their group id in ``SchedTask.group`` (the orchestrator
    assigns the base task's cid to every replica it clones; traces may tag
    jobs explicitly).  The group is what anti-affinity spreads across
    failure domains and what group-aware victim selection protects."""

    gid: str
    members: List[SchedTask] = field(default_factory=list)

    def domains(self, domain_fn) -> Dict[str, int]:
        """Failure-domain occupancy of the group's placed members."""
        out: Dict[str, int] = {}
        for t in self.members:
            if t.node_id is not None:
                d = domain_fn(t.node_id)
                out[d] = out.get(d, 0) + 1
        return out

    @staticmethod
    def gather(tasks: Iterable[SchedTask]) -> Dict[str, "ServiceGroup"]:
        groups: Dict[str, ServiceGroup] = {}
        for t in tasks:
            if t.group is None:
                continue
            groups.setdefault(t.group, ServiceGroup(t.group)) \
                  .members.append(t)
        return groups


# ---------------------------------------------------------------------------
# Placement policy
# ---------------------------------------------------------------------------
@dataclass
class PlacementWeights:
    """Soft scoring knobs.  Defaults keep capacity first (one free slice
    outweighs any warmth/utilization signal), warmth as the tie-breaker.
    Group anti-affinity is *not* a weight — it orders lexicographically
    above the score, so replicas spread whenever capacity allows."""

    free_slices: float = 1.0        # per free slice
    warm_cache: float = 0.5         # x (wanted ∩ cached)/wanted
    utilization: float = 0.25       # x node_utilization gauge (penalty)
    progress_rate: float = 0.25     # x normalized node_progress_rate (bonus)
    # role-aware scoring (disaggregated serving): prefill replicas want
    # free compute (extra weight on free slices), decode replicas want
    # free KV pages (normalized node_kv_free_pages gauge)
    role_compute: float = 0.5       # x free slices, prefill tasks only
    role_memory: float = 0.5        # x normalized kv-free, decode tasks only


class PlacementPolicy:
    """Scores candidate nodes from an enriched ``ClusterView``.

    The view must provide the scheduler's ``nodes``/``free_slices``; it
    *may* additionally provide ``failure_domain(node)`` and
    ``warm_programs(node)`` (every node defaults to its own domain and a
    cold cache).  A ``repro.scaling.metrics`` registry, when attached,
    contributes per-node utilization and progress-rate signals.  With none
    of the enrichments present the policy reduces exactly to the old
    most-free-slices rule, so existing trace results are unchanged.
    """

    def __init__(self, weights: Optional[PlacementWeights] = None,
                 registry=None):
        self.weights = weights or PlacementWeights()
        self.registry = registry

    # -- view accessors (degrade gracefully on plain ClusterViews) -------
    @staticmethod
    def domain_of(view, node: str) -> str:
        fn = getattr(view, "failure_domain", None)
        return fn(node) if fn is not None else node

    @staticmethod
    def warm_programs(view, node: str) -> Tuple[str, ...]:
        fn = getattr(view, "warm_programs", None)
        if fn is None:
            return ()
        try:
            return tuple(fn(node))
        except Exception:  # noqa: BLE001 - node may have just failed
            return ()

    # -- scoring ----------------------------------------------------------
    def _progress_rates(self) -> Dict[str, float]:
        """One registry scan per placement decision (not per candidate)."""
        if self.registry is None:
            return {}
        return {k: v for k, v in
                self.registry.gauge_values(M_NODE_PROGRESS_RATE).items()
                if v > 0}

    def score(self, task: SchedTask, node: str, view, free: int,
              rates: Optional[Dict[str, float]] = None) -> float:
        w = self.weights
        s = w.free_slices * free
        wanted = task.meta.get("programs") if task.meta else None
        if wanted:
            warm = self.warm_programs(view, node)
            if warm:
                wanted_set = set(wanted)
                s += w.warm_cache * (len(wanted_set & set(warm))
                                     / len(wanted_set))
        role = task.meta.get("role") if task.meta else None
        if role == "prefill":
            # prefill replicas are compute-bound (the long fused prompt
            # EXECUTE): bias further toward nodes with spare slices
            s += w.role_compute * free
        elif role == "decode" and self.registry is not None:
            # decode replicas are memory-bound (resident KV pages): bias
            # toward nodes advertising free pool pages
            kv = self.registry.gauge_values(M_NODE_KV_FREE)
            mx = max(kv.values(), default=0.0)
            if mx > 0:
                key = metric_key(M_NODE_KV_FREE, {"node": node})
                s += w.role_memory * (kv.get(key, 0.0) / mx)
        if self.registry is not None:
            s -= w.utilization * self.registry.gauge(
                M_NODE_UTILIZATION, node=node).value
            if rates is None:
                rates = self._progress_rates()
            if rates:
                key = metric_key(M_NODE_PROGRESS_RATE, {"node": node})
                s += w.progress_rate * (rates.get(key, 0.0)
                                        / max(rates.values()))
        return s

    def _group_conflicts(self, task: SchedTask, view,
                         running: Iterable[SchedTask]) -> Dict[str, int]:
        """Failure-domain occupancy of the task's group peers."""
        if task.group is None:
            return {}
        group = ServiceGroup.gather(
            t for t in running if t.tid != task.tid).get(task.group)
        if group is None:
            return {}
        return group.domains(lambda n: self.domain_of(view, n))

    # -- the four former call sites --------------------------------------
    def select_node(self, task: SchedTask, view, reserved: Dict[str, int],
                    *, running: Iterable[SchedTask] = (),
                    allow_migrate: bool = True) -> Optional[str]:
        """Most suitable node with a free slice (Alg 1 L4, enriched).

        Evicted tasks prefer (or, when the policy cannot migrate contexts,
        are pinned to) the node holding their context — unchanged from the
        scheduler's old ``_select_node``.  Exception: a task evicted *for
        migration* (``meta["migrate_from"]`` names its old node, set by the
        straggler path) must not take that fast path — its own freed slice
        would resume it straight back onto the degraded node — so it is
        scored over the other candidates, falling back to the flagged node
        only when nothing else has room.
        """
        def free(n: str) -> int:
            return view.free_slices(n) - reserved.get(n, 0)

        avoid = task.meta.get("migrate_from") if task.meta else None
        if task.state is TaskState.EVICTED and task.node_id is not None:
            if not (allow_migrate and avoid == task.node_id):
                if free(task.node_id) > 0:
                    return task.node_id
                if not allow_migrate:
                    return None        # PRE_EV cannot migrate contexts
        free_by_node = {n: free(n) for n in view.nodes()}
        candidates = [n for n in free_by_node if free_by_node[n] > 0]
        if allow_migrate and avoid is not None:
            others = [n for n in candidates if n != avoid]
            if others:
                candidates = others
        if not candidates:
            return None
        conflicts = self._group_conflicts(task, view, running)
        rates = self._progress_rates()
        return max(candidates,
                   key=lambda n: (-conflicts.get(self.domain_of(view, n), 0),
                                  self.score(task, n, view,
                                             free_by_node[n], rates), n))

    def find_victim(self, task: SchedTask, run_queue: List[SchedTask],
                    evicting: set) -> Optional[SchedTask]:
        """Lowest-priority preemptible running task strictly below ``task``
        — group-aware: a group's *last* running replica is only victimized
        when every other candidate is also some group's last replica, so
        preemption never takes a whole service down while an alternative
        exists."""
        groups = ServiceGroup.gather(run_queue)
        best = None
        best_key = None
        for i, t in enumerate(run_queue):
            if t.tid in evicting or not t.preemptible:
                continue
            if t.priority >= task.priority:
                continue
            last_of_group = (t.group is not None
                             and len(groups[t.group].members) <= 1)
            key = (last_of_group, t.priority, i)
            if best_key is None or key < best_key:
                best, best_key = t, key
        return best


# ---------------------------------------------------------------------------
# Metrics-driven migration
# ---------------------------------------------------------------------------
@dataclass
class MigrationDecision:
    cid: str
    node: Optional[str]
    rate: float
    median: float
    reason: str = "straggler"


@dataclass
class MigrationConfig:
    min_relative_rate: float = 0.5      # straggler if rate < x * median
    min_window_s: float = 1.0           # rate window
    min_peers: int = 3                  # need >= this many measurable rates


class MigrationController:
    """Evict+migrate decisions from the shared metrics registry.

    Producers (node agents on the live plane, the simulator under its
    virtual clock) publish each task's guest step counter through
    ``observe``; the controller derives progress *rates* from the
    registry's ``task_progress_steps`` series, folds them into per-node
    ``node_progress_rate`` gauges, and flags tasks progressing below
    ``min_relative_rate`` x the peer median.  The caller (orchestrator)
    executes the evictions; the scheduler's placement then migrates the
    contexts — the same engine as every other placement decision.
    """

    def __init__(self, registry, config: Optional[MigrationConfig] = None):
        self.registry = registry
        self.config = config or MigrationConfig()
        # points recorded before a task's last migration measure the old
        # node; ignore them so a freshly migrated task is not re-flagged
        self._reset_t: Dict[str, float] = {}
        # nodes whose progress-rate gauge we own: zeroed once they go idle
        # so a drained node never keeps a stale placement bonus
        self._known_nodes: set = set()

    # -- producer side ----------------------------------------------------
    def observe(self, cid: str, step: Optional[int]):
        """Publish one progress sample; node attribution happens at
        ``decide`` time from the caller's running map."""
        if step is None:
            return
        self.registry.series(M_TASK_PROGRESS, cid=cid).record(float(step))

    def reset(self, cid: str):
        """Ignore a task's prior history (it was just migrated/evicted)."""
        self._reset_t[cid] = self.registry.clock()

    def forget(self, cid: str):
        """Drop a finished task's series from the registry — progress
        history must not grow unboundedly with every task ever probed."""
        self.registry.drop_series(M_TASK_PROGRESS, cid=cid)
        self._reset_t.pop(cid, None)

    # -- decision side -----------------------------------------------------
    def _rate(self, cid: str, min_window_s: float) -> Optional[float]:
        pts = self.registry.series(M_TASK_PROGRESS, cid=cid).points()
        cutoff = self._reset_t.get(cid)
        if cutoff is not None:
            pts = [(t, v) for t, v in pts if t >= cutoff]
        if len(pts) < 2:
            return None
        t1, s1 = pts[-1]
        # a task that has never taken a guest step is still booting
        # (deploy/compile), not straggling — it has no measurable rate,
        # and a zero-rate sample here would mis-flag it for eviction
        if s1 <= 0:
            return None
        for t0, s0 in reversed(pts[:-1]):
            if t1 - t0 >= min_window_s:
                return (s1 - s0) / (t1 - t0)
        return None

    def decide(self, running: Dict[str, Optional[str]], *,
               min_relative_rate: Optional[float] = None,
               min_window_s: Optional[float] = None,
               ) -> List[MigrationDecision]:
        """``running`` maps cid -> node for tasks eligible to migrate."""
        cfg = self.config
        rel = (cfg.min_relative_rate if min_relative_rate is None
               else min_relative_rate)
        win = cfg.min_window_s if min_window_s is None else min_window_s
        rates: Dict[str, float] = {}
        for cid in running:
            r = self._rate(cid, win)
            if r is not None:
                rates[cid] = r
        # fold per-task rates into the per-node latency gauge the placement
        # scorer (and operators) read; nodes with no measurable tasks are
        # zeroed so an idle node never coasts on a stale bonus
        by_node: Dict[str, List[float]] = {}
        for cid, r in rates.items():
            node = running.get(cid)
            if node is not None:
                by_node.setdefault(node, []).append(r)
        nodes_now = {n for n in running.values() if n is not None}
        for node in nodes_now | self._known_nodes:
            rs = by_node.get(node)
            self.registry.gauge(M_NODE_PROGRESS_RATE, node=node).set(
                sum(rs) / len(rs) if rs else 0.0)
        self._known_nodes |= nodes_now
        if len(rates) < cfg.min_peers:
            return []
        med = _median(list(rates.values()))
        if not med or med <= 0 or math.isnan(med):
            return []
        out = []
        for cid, r in rates.items():
            if r < rel * med:
                out.append(MigrationDecision(cid=cid, node=running.get(cid),
                                             rate=r, median=med))
        return out
