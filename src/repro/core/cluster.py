"""Cluster assembly: leader + worker nodes, wired per the paper's Figure 1.

``make_cluster`` builds N worker nodes — each with a vSlice allocator, a
Funky runtime daemon, a container engine and a node agent — plus the leader's
orchestrator.  On this CPU host every vSlice maps to the same physical
device (as multiple vFPGAs map onto one card's slots); isolation and
accounting are enforced by the monitors.
"""

from __future__ import annotations

import os
import tempfile
from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.cri import ContainerEngine
from repro.core.node_agent import NodeAgent
from repro.core.orchestrator import Orchestrator
from repro.core.runtime import FunkyRuntime
from repro.core.scheduler import Policy
from repro.core.tasks import TaskImage
from repro.core.vslice import SliceAllocator
from repro.scaling.metrics import MetricsRegistry


@dataclass
class Node:
    node_id: str
    allocator: SliceAllocator
    runtime: FunkyRuntime
    engine: ContainerEngine
    agent: NodeAgent


@dataclass
class Cluster:
    nodes: Dict[str, Node]
    orchestrator: Orchestrator
    images: Dict[str, TaskImage]
    ckpt_root: str

    @property
    def metrics(self) -> MetricsRegistry:
        """Cluster-wide telemetry (monitors, agents, orchestrator)."""
        return self.orchestrator.metrics

    def agent(self, node_id: str) -> NodeAgent:
        return self.nodes[node_id].agent

    def stop(self):
        self.orchestrator.stop()


def make_cluster(num_nodes: int = 3, slices_per_node: int = 1,
                 images: Optional[Dict[str, TaskImage]] = None,
                 policy: Policy = Policy.PRE_MG,
                 mem_cap_bytes: int = 8 << 30,
                 checkpoint_interval: Optional[float] = None,
                 ckpt_root: Optional[str] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 failure_domains: Optional[int] = None,
                 straggler_interval: Optional[float] = None,
                 tracer=None, chaos=None) -> Cluster:
    """``failure_domains=k`` spreads the nodes round-robin over ``k``
    synthetic failure domains (rack/PDU model) for replica anti-affinity;
    the default gives every node its own domain.  ``chaos`` (a
    ``repro.chaos.FaultPlan``) is threaded into every runtime, monitor
    and node agent for deterministic fault injection."""
    images = images or {}
    ckpt_root = ckpt_root or tempfile.mkdtemp(prefix="funky-ckpt-")
    metrics = metrics if metrics is not None else MetricsRegistry()
    engines: Dict[str, ContainerEngine] = {}
    nodes: Dict[str, Node] = {}
    for i in range(num_nodes):
        nid = f"node{i}"
        alloc = SliceAllocator(nid, slices_per_node,
                               mem_cap_bytes=mem_cap_bytes)
        rt = FunkyRuntime(nid, alloc,
                          ckpt_root=os.path.join(ckpt_root, nid),
                          telemetry=metrics, chaos=chaos)
        eng = ContainerEngine(rt, images, peers=engines)
        engines[nid] = eng
        domain = (f"dom{i % failure_domains}" if failure_domains else None)
        agent = NodeAgent(nid, eng, metrics=metrics, failure_domain=domain,
                          chaos=chaos)
        nodes[nid] = Node(nid, alloc, rt, eng, agent)
    orch = Orchestrator({n: nd.agent for n, nd in nodes.items()},
                        policy=policy,
                        checkpoint_interval=checkpoint_interval,
                        metrics=metrics,
                        straggler_interval=straggler_interval,
                        tracer=tracer)
    return Cluster(nodes=nodes, orchestrator=orch, images=images,
                   ckpt_root=ckpt_root)
