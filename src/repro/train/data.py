"""Deterministic synthetic data pipeline.

Batches are a pure function of ``(seed, step)`` via Philox counters, so a
resumed/migrated task regenerates exactly the batch stream it would have seen
— checkpoint/restore equivalence tests rely on this.  A background prefetch
thread overlaps host batch generation with device compute.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class DataConfig:
    seed: int = 0
    process_index: int = 0
    process_count: int = 1


def _rng(seed: int, step: int, salt: int = 0) -> np.random.Generator:
    key = (np.uint64(seed) << np.uint64(32)) ^ np.uint64(step * 2 + 1)
    return np.random.Generator(np.random.Philox(key=[key, np.uint64(salt)]))


def make_batch(cfg: ModelConfig, shape: ShapeConfig, step: int,
               data_cfg: DataConfig | None = None,
               batch_override: Optional[int] = None,
               seq_override: Optional[int] = None) -> dict:
    """Training batch for (arch, shape) at a given step (host numpy)."""
    dc = data_cfg or DataConfig()
    B = batch_override or shape.global_batch
    S = seq_override or shape.seq_len
    B_local = B // dc.process_count
    rng = _rng(dc.seed, step, dc.process_index)

    def toks(*s):
        return rng.integers(0, cfg.vocab_size, size=s, dtype=np.int32)

    if cfg.family == "encdec":
        T = max(int(S * cfg.tgt_ratio), 8)
        tgt = toks(B_local, T + 1)
        return {
            "src_emb": rng.standard_normal(
                (B_local, S, cfg.d_model), dtype=np.float32) * 0.02,
            "tgt_tokens": tgt[:, :-1],
            "tgt_targets": tgt[:, 1:],
        }
    if cfg.family == "vlm":
        Stext = max(S - cfg.num_image_tokens, 8)
        t = toks(B_local, Stext + 1)
        return {
            "tokens": t[:, :-1],
            "targets": t[:, 1:],
            "img_emb": rng.standard_normal(
                (B_local, cfg.num_image_tokens, cfg.d_model),
                dtype=np.float32) * 0.02,
        }
    t = toks(B_local, S + 1)
    return {"tokens": t[:, :-1], "targets": t[:, 1:]}


class PrefetchingLoader:
    """Iterator with a background producer thread (depth-bounded queue)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 data_cfg: DataConfig | None = None, start_step: int = 0,
                 depth: int = 2, batch_override: Optional[int] = None,
                 seq_override: Optional[int] = None):
        self.cfg, self.shape = cfg, shape
        self.data_cfg = data_cfg or DataConfig()
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._overrides = (batch_override, seq_override)
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        step = self.step
        bo, so = self._overrides
        while not self._stop.is_set():
            b = make_batch(self.cfg, self.shape, step, self.data_cfg, bo, so)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, b = self._q.get()
        self.step = step + 1
        return b

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
