from repro.train.data import DataConfig, PrefetchingLoader, make_batch
from repro.train.optimizer import (OptConfig, apply_updates, init_opt_state,
                                   lr_at)
from repro.train.train_step import (make_chunked_train_fns, make_train_state,
                                    make_train_step)

__all__ = [
    "DataConfig", "OptConfig", "PrefetchingLoader", "apply_updates",
    "init_opt_state", "lr_at", "make_batch", "make_chunked_train_fns",
    "make_train_state", "make_train_step",
]
