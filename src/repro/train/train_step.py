"""Train-step builders.

Two execution modes, mirroring the paper's request-splitting design (§3.4):

* ``make_train_step``        — one fused XLA program: grad-accumulate over K
  microbatches with an internal ``lax.scan`` then apply AdamW.  Maximum
  throughput; preemption granularity = the whole step.
* ``make_chunked_train_fns`` — (grad_step, apply_step) as *separate* programs
  dispatched per microbatch by the runtime.  This is Funky's "split a 1 GiB
  request into chunks" optimization mapped to training: the monitor can
  synchronize and preempt between chunks (Fig 9 reproduction in
  ``benchmarks/fig09_sync_split.py``).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model_zoo import ModelBundle
from repro.train.optimizer import OptConfig, apply_updates, init_opt_state


def _split_microbatches(batch: dict, k: int, mesh=None,
                        dp_axes: tuple = ()) -> dict:
    def r(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        out = x.reshape(k, b // k, *x.shape[1:])
        if mesh is not None and dp_axes:
            # Re-pin the per-microbatch batch dim: without this, GSPMD tends
            # to replicate microbatches across data shards after the reshape.
            from repro.models.layers import _axsize
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P

            if (b // k) % _axsize(mesh, dp_axes) == 0:
                spec = P(None, dp_axes, *([None] * (x.ndim - 1)))
                out = jax.lax.with_sharding_constraint(
                    out, NamedSharding(mesh, spec))
        return out

    return jax.tree.map(r, batch)


def make_train_step(bundle: ModelBundle, opt_cfg: OptConfig,
                    num_microbatches: int = 1,
                    accum_dtype: str = "float32", mesh=None,
                    dp_axes: tuple = ()) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_of(params, mb):
        loss, metrics = bundle.loss_fn(params, mb)
        return loss, metrics

    def step(params, opt_state, batch):
        if num_microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        else:
            mbs = _split_microbatches(batch, num_microbatches, mesh, dp_axes)
            adt = jnp.dtype(accum_dtype)

            def body(carry, mb):
                acc, loss_acc = carry
                (loss, _), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
                acc = jax.tree.map(lambda a, gi: a + gi.astype(adt), acc, g)
                return (acc, loss_acc + loss), None

            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            (grads, loss_sum), _ = jax.lax.scan(
                body, (acc0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = loss_sum / num_microbatches
            metrics = {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32)}
        params, opt_state, stats = apply_updates(opt_cfg, params, grads, opt_state)
        metrics = dict(metrics)
        metrics.update(stats)
        return params, opt_state, metrics

    return step


def make_chunked_train_fns(bundle: ModelBundle, opt_cfg: OptConfig,
                           accum_dtype: str = "float32"):
    """Chunk-granular training (the paper's sync-splitting, §3.4 / Fig 9).

    grad_step(params, grad_acc, microbatch) -> (grad_acc', loss)
        one microbatch forward+backward, accumulated into grad_acc;
    apply_step(params, opt_state, grad_acc, k) -> (params', opt_state', stats)
        AdamW with the averaged accumulated gradient.

    The runtime dispatches these as individual EXECUTE requests, so eviction/
    checkpoint requests wait at most one microbatch.
    """
    adt = jnp.dtype(accum_dtype)

    def grad_init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)

    def grad_step(params, grad_acc, microbatch):
        def loss_of(p):
            return bundle.loss_fn(p, microbatch)[0]

        loss, g = jax.value_and_grad(loss_of)(params)
        grad_acc = jax.tree.map(lambda a, gi: a + gi.astype(adt), grad_acc, g)
        return grad_acc, loss

    def apply_step(params, opt_state, grad_acc, k):
        grads = jax.tree.map(lambda g: g / k, grad_acc)
        return apply_updates(opt_cfg, params, grads, opt_state)

    return grad_init, grad_step, apply_step


def make_train_state(bundle: ModelBundle, opt_cfg: OptConfig, rng):
    params = bundle.init(rng)
    return params, init_opt_state(opt_cfg, params)
