"""AdamW + schedules, implemented in pure JAX (no optax in this environment).

Moments support three storage formats (``moment_dtype``):

* ``float32``  — exact Adam;
* ``bfloat16`` — halves moment memory; update math still f32;
* ``int8``     — 8-bit Adam (Dettmers-style block quantization, one f32
  scale per last-dim row).  671e9 params x (2 + 1 + 1 + scales) bytes /
  256 chips ≈ 10.6 GB: the format that fits deepseek-v3-671b training on a
  single v5e pod (EXPERIMENTS.md §Perf C).

Large stacked leaves (scan-over-layers parameter stacks) are updated with
``lax.map`` over the leading axis so optimizer f32 temporaries stay
per-layer-slice instead of per-stack (§Perf C.3).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

# leaves with leading dim >= this and rank >= 3 get lax.map'd updates
_SCAN_UPDATE_MIN_LEAD = 8


def _q8(x32: jax.Array):
    """Symmetric int8 quantization with per-last-dim-row f32 scales (m)."""
    amax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


_V_LOG_FLOOR = -46.0    # log(1e-20): "zero" second moment


def _q8_log(v32: jax.Array):
    """Log-space int8 quantization for the (non-negative) second moment.

    Linear int8 collapses small-but-critical v entries to zero (the Adam
    denominator), exploding updates; log bins give uniform *relative*
    precision ~ (vmax/vmin)^(1/254) per row.  Scale carries (log_lo, range).
    """
    vc = jnp.maximum(v32, jnp.exp(_V_LOG_FLOOR))
    lo = jnp.log(jnp.min(vc, axis=-1, keepdims=True))
    hi = jnp.log(jnp.max(vc, axis=-1, keepdims=True))
    rng = jnp.maximum(hi - lo, 1e-9)
    q = jnp.clip(jnp.round((jnp.log(vc) - lo) / rng * 254.0) - 127.0,
                 -127, 127).astype(jnp.int8)
    scale = jnp.concatenate([lo, rng], axis=-1).astype(jnp.float32)
    return q, scale


def _dq8_log(q: jax.Array, scale: jax.Array) -> jax.Array:
    lo = scale[..., :1]
    rng = scale[..., 1:2]
    v = jnp.exp(lo + (q.astype(jnp.float32) + 127.0) / 254.0 * rng)
    return jnp.where(v <= jnp.exp(_V_LOG_FLOOR) * 1.001, 0.0, v)


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    # dtype gradients are reduced across data shards in.  GSPMD defers the
    # grad all-reduce to first use; touching grads in f32 first would double
    # the reduction bytes (measured: §Perf B.3), so we pin bf16 here.
    grad_reduce_dtype: str = "bfloat16"


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(cfg: OptConfig, params: Any) -> dict:
    if cfg.moment_dtype == "int8":
        z8 = lambda p: jnp.zeros(p.shape, jnp.int8)
        zm = lambda p: jnp.zeros(p.shape[:-1] + (1,), jnp.float32)
        zv = lambda p: jnp.zeros(p.shape[:-1] + (2,), jnp.float32).at[
            ..., 0].set(_V_LOG_FLOOR)
        return {
            "m": jax.tree.map(z8, params),
            "v": jax.tree.map(z8, params),
            "m_scale": jax.tree.map(zm, params),
            "v_scale": jax.tree.map(zv, params),
            "count": jnp.zeros((), jnp.int32),
        }
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: OptConfig, params: Any, grads: Any, state: dict):
    """One AdamW step. Returns (new_params, new_state, stats)."""
    if cfg.grad_reduce_dtype:
        # Grad leaves are typically already bf16 but *unreduced* (GSPMD defers
        # the cross-shard reduction to first use).  The barrier pins the
        # reduction here — before the optimizer's f32 upcast — so the wire
        # format is bf16, not f32 (§Perf B.3: halves all-reduce bytes).
        rdt = jnp.dtype(cfg.grad_reduce_dtype)
        grads = jax.tree.map(
            lambda g: g.astype(rdt) if g.dtype == jnp.float32 else g, grads)
        grads = jax.lax.optimization_barrier(grads)
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else jnp.float32(1.0)
    lr = lr_at(cfg, count)
    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bc1 = 1 - b1 ** c
    bc2 = 1 - b2 ** c
    int8 = cfg.moment_dtype == "int8"
    mdt = jnp.dtype(cfg.moment_dtype if not int8 else "float32")

    def upd(p, g, m, v, ms=None, vs=None):
        g = g.astype(jnp.float32) * scale
        m32 = _dq8(m, ms) if int8 else m.astype(jnp.float32)
        v32 = _dq8_log(v, vs) if int8 else v.astype(jnp.float32)
        m32 = b1 * m32 + (1 - b1) * g
        v32 = b2 * v32 + (1 - b2) * jnp.square(g)
        v32 = jnp.maximum(v32, 0.0)
        mhat = m32 / bc1
        vhat = v32 / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:       # no decay on norms/bias
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        if int8:
            mq, msc = _q8(m32)
            vq, vsc = _q8_log(v32)
            return new_p, mq, vq, msc, vsc
        return new_p, m32.astype(mdt), v32.astype(mdt), None, None

    def upd_leaf(p, g, m, v, ms, vs):
        # lax.map over the layer-stack axis keeps f32 temporaries O(1 layer)
        if p.ndim >= 3 and p.shape[0] >= _SCAN_UPDATE_MIN_LEAD:
            if int8:
                return jax.lax.map(lambda xs: upd(*xs), (p, g, m, v, ms, vs))
            out = jax.lax.map(lambda xs: upd(*xs[:4]), (p, g, m, v))
            return out
        return upd(p, g, m, v, ms, vs)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_ms = jax.tree.leaves(state["m_scale"]) if int8 else [None] * len(flat_p)
    flat_vs = jax.tree.leaves(state["v_scale"]) if int8 else [None] * len(flat_p)
    out = [upd_leaf(p, g, m, v, ms, vs)
           for p, g, m, v, ms, vs in
           zip(flat_p, flat_g, flat_m, flat_v, flat_ms, flat_vs)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "count": count,
    }
    if int8:
        new_state["m_scale"] = jax.tree.unflatten(treedef, [o[3] for o in out])
        new_state["v_scale"] = jax.tree.unflatten(treedef, [o[4] for o in out])
    stats = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, stats
