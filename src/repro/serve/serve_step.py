"""Serving steps: prefill, decode, and a simple generate loop.

``generate`` drives batched greedy/temperature decoding; the Funky runtime
wraps ``decode_step`` dispatches as EXECUTE requests, so serving tasks are
preemptible between tokens (minimal-granularity — the paper's best case for
synchronization latency).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.model_zoo import ModelBundle
from repro.serve.kvcache import init_caches_from_specs


def make_prefill_step(bundle: ModelBundle) -> Callable:
    def prefill(params, batch):
        return bundle.prefill_fn(params, batch)

    return prefill


def make_decode_step(bundle: ModelBundle) -> Callable:
    def decode(params, token, pos, caches):
        return bundle.decode_fn(params, token, pos, caches)

    return decode


def sample_token(logits: jax.Array, rng: Optional[jax.Array],
                 temperature: float = 0.0) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, logits / temperature).astype(jnp.int32)


def generate(bundle: ModelBundle, params, prompt_batch: dict, num_tokens: int,
             *, temperature: float = 0.0, rng=None,
             jit: bool = True):
    """Prefill + decode ``num_tokens`` tokens. Returns (B, num_tokens) ids.

    This is the *sequential* baseline the continuous-batching engine
    (``repro.serve.engine``) is benchmarked against in fig15: one request
    at a time, tokens delivered only when the loop finishes.  RNG keys are
    pre-split once (one host-side ``jax.random.split`` total, not one per
    token); greedy decoding skips key handling entirely.
    """
    if jit:
        # cache the jitted steps on the bundle so back-to-back generate
        # calls (the sequential serving baseline) hit warm executables
        # instead of re-tracing fresh closures per request
        steps = getattr(bundle, "_jit_steps", None)
        if steps is None:
            steps = (jax.jit(make_prefill_step(bundle)),
                     jax.jit(make_decode_step(bundle)))
            bundle._jit_steps = steps
        prefill, decode = steps
    else:
        prefill = make_prefill_step(bundle)
        decode = make_decode_step(bundle)
    logits, caches = prefill(params, prompt_batch)
    key = prompt_batch.get("tgt_tokens", prompt_batch.get("tokens"))
    pos = key.shape[1]
    if bundle.cfg.family == "vlm":
        pos += bundle.cfg.num_image_tokens
    toks = []
    keys = None
    if temperature > 0.0:
        rng = rng if rng is not None else jax.random.key(0)
        keys = jax.random.split(rng, num_tokens)
    for i in range(num_tokens):
        tok = sample_token(logits, None if keys is None else keys[i],
                           temperature)
        toks.append(tok)
        logits, caches = decode(params, tok, jnp.int32(pos + i), caches)
    return jnp.stack(toks, axis=1)
