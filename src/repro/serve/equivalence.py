"""Reusable engine-vs-baseline bit-exactness harness.

The serving engine's core correctness contract is that *scheduling never
changes tokens*: continuous batching, paged memory, OOM preemption,
compaction, evict/resume and speculative decode are all pure throughput
mechanisms — the committed token stream of every request must be
bit-identical to what a plain greedy decode of that request alone would
produce.  This module packages that contract as a parameterized check so
every new engine feature (and `benchmarks/fig15_serving.py --smoke`) can
assert it instead of re-growing ad-hoc comparison loops:

* ``run_transcript`` — drive one freshly built engine over a workload to
  completion and return ``{rid: [token, ...]}``; an optional ``step_hook``
  fires between iterations to inject perturbations (evict/resume,
  compaction, anything legal at a token boundary).
* ``assert_transcripts_equal`` — diff two transcripts with a first-
  divergence error message.
* ``check_equivalence`` — run candidate and baseline factories over the
  same workload (each gets fresh request objects) and assert equality.
* ``evict_resume_every`` — the canonical perturbation: monitor-level
  evict + resume every ``n`` iterations while lanes are in flight.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

Transcript = Dict[str, List[int]]


def run_transcript(engine_factory: Callable, requests_factory: Callable,
                   *, step_hook: Optional[Callable] = None,
                   max_iterations: int = 100000) -> Tuple[Transcript, object]:
    """Run a workload to completion on a fresh engine.

    ``engine_factory() -> (monitor, engine)`` must return an engine with
    ``setup()`` already run; ``requests_factory()`` returns fresh
    ``ServeRequest`` objects (engines mutate ``arrival_t``).
    ``step_hook(engine, monitor, iteration)`` runs after every iteration.
    Returns ``(transcript, engine)`` — the engine is already torn down
    (``vfpga_exit``) but keeps its counters/stats readable.
    """
    mon, eng = engine_factory()
    try:
        for req in requests_factory():
            eng.submit(req)
        i = 0
        while not eng.idle:
            eng.step()
            i += 1
            if step_hook is not None:
                step_hook(eng, mon, i)
            if i >= max_iterations:
                raise RuntimeError(
                    f"engine did not drain in {max_iterations} iterations")
        return ({rid: list(rec.tokens)
                 for rid, rec in eng.completed.items()}, eng)
    finally:
        mon.vfpga_exit()


def assert_transcripts_equal(got: Transcript, ref: Transcript,
                             context: str = "") -> None:
    """Bit-exact comparison with a first-divergence diagnostic."""
    tag = f" [{context}]" if context else ""
    if set(got) != set(ref):
        raise AssertionError(
            f"request sets differ{tag}: only-got={sorted(set(got) - set(ref))}"
            f" only-ref={sorted(set(ref) - set(got))}")
    for rid in sorted(ref):
        a, b = got[rid], ref[rid]
        if a == b:
            continue
        n = min(len(a), len(b))
        div = next((i for i in range(n) if a[i] != b[i]), n)
        raise AssertionError(
            f"transcript diverges{tag}: rid={rid} at token {div}: "
            f"got={a[max(0, div - 2):div + 3]} (len {len(a)}) "
            f"ref={b[max(0, div - 2):div + 3]} (len {len(b)})")


def check_equivalence(engine_factory: Callable, baseline_factory: Callable,
                      requests_factory: Callable, *,
                      step_hook: Optional[Callable] = None,
                      baseline_hook: Optional[Callable] = None,
                      context: str = "") -> Tuple[object, object]:
    """Assert the candidate engine's transcript equals the baseline's.

    Returns the two (torn-down) engines so callers can additionally assert
    on mechanism counters (preemptions, spec stats, compactions, ...).
    """
    got, eng = run_transcript(engine_factory, requests_factory,
                              step_hook=step_hook)
    ref, base = run_transcript(baseline_factory, requests_factory,
                               step_hook=baseline_hook)
    assert_transcripts_equal(got, ref, context=context)
    return eng, base


def evict_resume_every(n: int, *, only_while_active: bool = True) -> Callable:
    """Step hook: monitor-level evict + immediate resume every ``n``
    iterations — the harness's standard preemption perturbation."""
    def hook(eng, mon, i):
        if i % n:
            return
        if only_while_active and eng.active_count == 0:
            return
        mon.evict()
        mon.resume()
    return hook
