"""Continuous-batching serving engine (vLLM/Orca-style iteration-level
scheduling on top of the Funky monitor).

The engine owns ``slots`` fixed decode lanes.  Each lane is an independent
sequence with its own position counter and its own KV-cache stripe; one
*iteration* advances every occupied lane by one token through a single
vmapped EXECUTE request.  Between iterations the engine retires finished
sequences and backfills freed lanes with prefills of waiting requests —
admission happens at iteration granularity, so a long-running batch never
stalls behind a straggler and newly arrived requests never wait for the
whole batch to drain (the continuous-batching property).

Every device interaction is a Funky request through ``Monitor.submit``:

    prefill_one   EXECUTE (params, pf_prompt)        -> (pf_tok, pf_cache)
    admit_slot    EXECUTE scatter into lane ``slot`` (donated, in-place)
    decode_step   EXECUTE vmapped one-token step     (donated, in-place)
    token d2h     TRANSFER — the per-iteration token delivery/sync point

so serving stays preemptible at token boundaries (the paper's
minimal-granularity best case, §3.3/Fig 9-10): ``Monitor.evict`` between
iterations snapshots the lanes like any other DIRTY buffers, and ``resume``
continues every in-flight sequence bit-exactly.  Buffer donation on the
decode/admit path means the KV cache is updated in place instead of being
copied every token, and the monitor's execute-signature cache keeps the
per-request dispatch cost flat.

Per-request latencies (TTFT, time-between-tokens, end-to-end) land in the
shared ``repro.scaling.metrics`` registry under the canonical service
schema, so fig14/fig15 SLO attainment is computed from engine-reported
numbers rather than load-generator models.

Greedy decoding only (deterministic across preemption); prompts are padded
or truncated to the engine's fixed ``prompt_len`` — raggedness lives in
arrival times and generation lengths.
"""

from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.guest import FunkyCL
from repro.core.programs import Program
from repro.scaling.autoscaler import (M_COMPLETIONS, M_QUEUE_DEPTH,
                                      M_SLO_VIOLATIONS, M_UTILIZATION)
from repro.scaling.metrics import MetricsRegistry
from repro.serve.kvcache import init_caches_from_specs

# Canonical per-request serving metrics (one schema across planes).
M_TTFT = "request_ttft_seconds"
M_TBT = "request_tbt_seconds"
M_E2E = "request_latency_seconds"
M_TOKENS = "engine_tokens_total"
M_ITERS = "engine_iterations_total"


@dataclass
class ServeRequest:
    """One generation request admitted into a decode slot."""
    rid: str
    prompt: np.ndarray                  # (P,) int32 token ids
    max_new_tokens: int = 8
    arrival_t: Optional[float] = None   # registry-clock timestamp
    slo_s: Optional[float] = None       # end-to-end SLO (None = untracked)


@dataclass
class CompletedRequest:
    rid: str
    tokens: List[int]
    arrival_t: float
    admit_t: float
    first_token_t: float
    finish_t: float
    tbts: List[float] = field(default_factory=list)

    @property
    def ttft_s(self) -> float:
        return self.first_token_t - self.arrival_t

    @property
    def e2e_s(self) -> float:
        return self.finish_t - self.arrival_t


@dataclass
class _SlotState:
    req: ServeRequest
    slot: int
    tokens: List[int]
    admit_t: float
    first_token_t: float
    last_token_t: float
    tbts: List[float] = field(default_factory=list)


class ContinuousBatchingEngine:
    def __init__(self, arch: str, cl: FunkyCL, *, slots: int = 4,
                 prompt_len: int = 16, max_new_tokens: int = 16,
                 service: str = "svc", engine_id: str = "engine0",
                 seed: int = 0, registry: Optional[MetricsRegistry] = None,
                 publish_gauges: bool = True):
        from repro.configs import get_arch
        from repro.models import build_model

        self.cl = cl
        self.slots = slots
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens   # per-request cap (cache size)
        self.service = service
        self.engine_id = engine_id
        self.seed = seed
        self.cfg = get_arch(arch)
        # cache capacity = prompt_len + max_new_tokens: prefill reserves the
        # decode headroom so admission is a pure scatter, never a regrow
        self.bundle = build_model(self.cfg, cache_margin=max_new_tokens)
        self.registry = (registry if registry is not None
                         else cl._monitor.telemetry)
        self._clock = self.registry.clock
        self._publish_gauges = publish_gauges
        # handles resolved once — the per-iteration loop never takes the
        # registry lock (same rule as the monitor's dispatch loop)
        self._h_ttft = self.registry.histogram(M_TTFT, service=service)
        self._h_tbt = self.registry.histogram(M_TBT, service=service)
        self._h_e2e = self.registry.histogram(M_E2E, service=service)
        self._c_tokens = self.registry.counter(M_TOKENS, service=service)
        self._c_iters = self.registry.counter(M_ITERS, service=service)
        self._c_completions = self.registry.counter(M_COMPLETIONS,
                                                    service=service)
        self._c_violations = self.registry.counter(M_SLO_VIOLATIONS,
                                                   service=service)
        if publish_gauges:
            self._g_queue = self.registry.gauge(
                M_QUEUE_DEPTH, service=service, engine=engine_id)
            self._g_util = self.registry.gauge(
                M_UTILIZATION, service=service, engine=engine_id)

        self.pending: deque = deque()
        self._free: List[int] = list(range(slots))
        heapq.heapify(self._free)
        self._active: Dict[int, _SlotState] = {}
        self.completed: Dict[str, CompletedRequest] = {}
        self._unreported: deque = deque()   # completions not yet drained
        self.iterations = 0
        self._setup_done = False

    # ------------------------------------------------------------------
    # Program/buffer setup (Funky guest-style, via FunkyCL only)
    # ------------------------------------------------------------------
    def setup(self, restore: bool = False) -> None:
        bundle, B, P = self.bundle, self.slots, self.prompt_len

        def init_params(seed):
            return bundle.init(jax.random.PRNGKey(seed))

        def prefill_one(params, tokens):
            logits, cache = bundle.prefill_fn(params, {"tokens": tokens})
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        def decode_step(params, toks, pos, caches):
            def lane(tok, p, cache):
                logits, new_cache = bundle.decode_fn(params, tok, p, cache)
                return (jnp.argmax(logits, -1).astype(jnp.int32),
                        p + jnp.int32(1), new_cache)
            return jax.vmap(lane)(toks, pos, caches)

        def admit_slot(toks, pos, caches, pf_tok, pf_cache, slot):
            slot = jnp.asarray(slot, jnp.int32)
            toks = jax.lax.dynamic_update_slice(
                toks, pf_tok[:, None], (slot, jnp.int32(0)))
            pos = jax.lax.dynamic_update_slice(
                pos, jnp.full((1,), P, jnp.int32), (slot,))
            caches = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_slice(
                    c, n[None], (slot,) + (jnp.int32(0),) * n.ndim),
                caches, pf_cache)
            return toks, pos, caches

        params_abs = jax.eval_shape(lambda: init_params(0))
        prompt_abs = jax.ShapeDtypeStruct((1, P), jnp.int32)
        pf_tok_abs, pf_cache_abs = jax.eval_shape(
            prefill_one, params_abs, prompt_abs)
        caches_abs = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((B,) + l.shape, l.dtype),
            pf_cache_abs)
        toks_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
        self._caches_abs = caches_abs

        def init_slots():
            return (jnp.zeros((B, 1), jnp.int32), jnp.zeros((B,), jnp.int32),
                    init_caches_from_specs(caches_abs))

        cl = self.cl
        cl.clCreateProgramWithBinary(Program("init_params", init_params),
                                     (0,))
        cl.clCreateProgramWithBinary(Program("init_slots", init_slots), ())
        cl.clCreateProgramWithBinary(Program("prefill_one", prefill_one),
                                     (params_abs, prompt_abs))
        slot_abs = jnp.int32(0)
        cl.clCreateProgramWithBinary(
            Program("admit_slot", admit_slot),
            (toks_abs, pos_abs, caches_abs, pf_tok_abs, pf_cache_abs,
             slot_abs),
            donate_argnums=(0, 1, 2))
        cl.clCreateProgramWithBinary(
            Program("decode_step", decode_step),
            (params_abs, toks_abs, pos_abs, caches_abs),
            donate_argnums=(1, 2, 3))
        if not restore:
            cl.clCreateBuffer("params", params_abs)
            cl.clCreateBuffer("toks", toks_abs)
            cl.clCreateBuffer("pos", pos_abs)
            cl.clCreateBuffer("caches", caches_abs)
            cl.clCreateBuffer("pf_prompt", prompt_abs)
            cl.clCreateBuffer("pf_tok", pf_tok_abs)
            cl.clCreateBuffer("pf_cache", pf_cache_abs)
            cl.clEnqueueKernel("init_params", (), ("params",),
                               const_args=(self.seed,))
            cl.clEnqueueKernel("init_slots", (), ("toks", "pos", "caches"))
            cl.clFinish()
        self._setup_done = True

    # ------------------------------------------------------------------
    # Request intake
    # ------------------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        if req.arrival_t is None:
            req.arrival_t = self._clock()
        self.pending.append(req)

    @property
    def idle(self) -> bool:
        return not self._active and not self.pending

    @property
    def active_count(self) -> int:
        return len(self._active)

    def _pad_prompt(self, prompt: np.ndarray) -> np.ndarray:
        p = np.asarray(prompt, np.int32).reshape(-1)[: self.prompt_len]
        if p.shape[0] < self.prompt_len:
            p = np.pad(p, (0, self.prompt_len - p.shape[0]))
        return p.reshape(1, self.prompt_len)

    # ------------------------------------------------------------------
    # One iteration: admit into free lanes, decode all occupied lanes
    # ------------------------------------------------------------------
    def _admit(self) -> int:
        admitted = 0
        cl = self.cl
        while self._free and self.pending:
            slot = heapq.heappop(self._free)
            req = self.pending.popleft()
            cl.write_buffer("pf_prompt", self._pad_prompt(req.prompt))
            cl.clEnqueueKernel("prefill_one", ("params", "pf_prompt"),
                               ("pf_tok", "pf_cache"))
            cl.clEnqueueKernel(
                "admit_slot",
                ("toks", "pos", "caches", "pf_tok", "pf_cache"),
                ("toks", "pos", "caches"),
                const_args=(np.int32(slot),), donate=True)
            first_tok = int(np.asarray(cl.read_buffer("pf_tok"))[0])
            now = self._clock()
            st = _SlotState(req=req, slot=slot, tokens=[first_tok],
                            admit_t=now, first_token_t=now,
                            last_token_t=now)
            self._h_ttft.observe(now - req.arrival_t)
            self._c_tokens.inc()
            self.registry.record_event("engine_admit", rid=req.rid,
                                       slot=slot, engine=self.engine_id)
            admitted += 1
            if len(st.tokens) >= req.max_new_tokens:
                self._retire(st, now)       # degenerate 1-token request
            else:
                self._active[slot] = st
        return admitted

    def _retire(self, st: _SlotState, now: float) -> None:
        rec = CompletedRequest(
            rid=st.req.rid, tokens=st.tokens, arrival_t=st.req.arrival_t,
            admit_t=st.admit_t, first_token_t=st.first_token_t,
            finish_t=now, tbts=st.tbts)
        self.completed[st.req.rid] = rec
        self._unreported.append(rec)
        self._active.pop(st.slot, None)
        heapq.heappush(self._free, st.slot)
        self._h_e2e.observe(rec.e2e_s)
        self._c_completions.inc()
        if st.req.slo_s is not None and rec.e2e_s > st.req.slo_s:
            self._c_violations.inc()
        self.registry.record_event("engine_retire", rid=st.req.rid,
                                   slot=st.slot, tokens=len(st.tokens),
                                   engine=self.engine_id)

    def step(self) -> dict:
        """One engine iteration; returns counts for the caller's pacing."""
        if not self._setup_done:
            raise RuntimeError("engine.setup() has not run")
        admitted = self._admit()
        decoded = 0
        if self._active:
            self.cl.clEnqueueKernel(
                "decode_step", ("params", "toks", "pos", "caches"),
                ("toks", "pos", "caches"), donate=True)
            # token delivery doubles as the iteration's sync point — the
            # d2h TRANSFER drains the queue and lands on a token boundary
            toks = np.asarray(self.cl.read_buffer("toks"))
            now = self._clock()
            for st in list(self._active.values()):
                st.tokens.append(int(toks[st.slot, 0]))
                st.tbts.append(now - st.last_token_t)
                self._h_tbt.observe(now - st.last_token_t)
                st.last_token_t = now
                decoded += 1
                if len(st.tokens) >= st.req.max_new_tokens:
                    self._retire(st, now)
            self._c_tokens.inc(decoded)
        self.iterations += 1
        self._c_iters.inc()
        if self._publish_gauges:
            self._g_queue.set(len(self.pending))
            self._g_util.set(len(self._active) / self.slots)
        return {"admitted": admitted, "decoded": decoded,
                "active": len(self._active), "pending": len(self.pending)}

    def drain_completions(self) -> List[CompletedRequest]:
        out = list(self._unreported)
        self._unreported.clear()
        return out

    def evacuate(self) -> List[ServeRequest]:
        """Hand back every un-finished request (kill / drain path) and
        reset the lanes.  Finished-but-unreported completions stay
        available via ``drain_completions`` — report those first so the
        caller's in-flight accounting stays exact."""
        reqs = ([st.req for st in self._active.values()]
                + list(self.pending))
        self._active.clear()
        self.pending.clear()
        self._free = list(range(self.slots))
        heapq.heapify(self._free)
        return reqs

    def run_until_drained(self, max_iterations: int = 100000) -> None:
        while not self.idle:
            self.step()
            if self.iterations >= max_iterations:
                raise RuntimeError("engine did not drain "
                                   f"in {max_iterations} iterations")

    # ------------------------------------------------------------------
    # Router integration (live plane): pull admissible work, push results
    # ------------------------------------------------------------------
    def pump(self, router) -> bool:
        """One iteration against a ``RequestRouter``; True if work moved."""
        for req in router.pop(len(self._free)):
            self.submit(req)
        moved = bool(self._active or self.pending)
        if moved:
            self.step()
        for rec in self.drain_completions():
            router.complete(rec)
        return moved
