"""Continuous-batching serving engine (vLLM/Orca-style iteration-level
scheduling on top of the Funky monitor) over **paged** vFPGA device memory.

The engine owns ``slots`` fixed decode lanes.  Each lane is an independent
sequence with its own position counter; one *iteration* advances every
occupied lane by one token through a single vmapped EXECUTE request.
Between iterations the engine retires finished sequences and backfills
freed lanes with prefills of waiting requests — admission happens at
iteration granularity, so a long-running batch never stalls behind a
straggler (the continuous-batching property).

KV memory comes in two modes:

* **paged** (default) — device KV memory is a ``BlockPool`` of fixed-size
  pages shared by every lane.  A per-lane *block table* row maps logical
  page index -> physical page; the vmapped decode step gathers each lane's
  cache through its row and scatters back only the page it wrote.  Lanes
  hold pages at token granularity: prompt pages at admission, one more
  page whenever decode crosses a page boundary, all freed the moment the
  request retires.  Admission is therefore **memory-based** — admit while
  ``free_pages - prompt_pages >= reserve_pages`` — so ``slots`` can exceed
  what worst-case reservations would allow.  If the pool exhausts
  mid-decode the youngest lane is OOM-preempted: its pages are freed and
  its request requeued for deterministic recomputation (greedy decode, so
  the client sees identical tokens).  Freed pages are scrubbed (positions
  invalidated) on reallocation — the §3.4 freed-memory-zeroing rule — so a
  new owner can never attend to a previous lane's tokens.
* **reserved** — the old worst-case layout: every lane owns a
  ``prompt_len + max_new_tokens`` stripe up front.  Kept as the fig15
  baseline the paged mode is measured against.

Paged mode also supports **prompt buckets**: 2-3 prefill lengths compiled
up front, with each admission routed to the smallest bucket that fits
instead of padding everything to one ``prompt_len``.

Paged mode additionally supports **speculative decoding** (``spec=``):
a draft model runs ``k`` lookahead steps per lane in one EXECUTE, then the
target model verifies all ``k+1`` positions in a single vmapped EXECUTE —
sequential in-kernel decode steps over the gathered lane cache, so the
logits at every position are bit-identical to plain greedy decode.  The
host commits the accepted prefix plus the target's own token at the first
mismatch (1..k+1 tokens per iteration), rolls the lane's ``pos`` back past
the rejected tail and frees the orphaned tail pages
(``BlockPool.free_tail``).  Rejected writes left in *kept* pages are
harmless by construction: their ``kv_pos`` exceeds every future query
position until the lane overwrites them in order, and causal masking hides
them until then — which is also why evict/resume mid-lookahead stays
bit-exact (the dirty-page report covers every page the verify wrote,
including partially-accepted ones).  Speculation lives entirely inside one
iteration, so token-boundary preemption, OOM preemption (deterministic
recompute) and drain semantics are unchanged.

The pool auto-defragments: when fragmentation (``1 - used/span``) crosses
``auto_compact_frag`` the engine runs ``compact()`` at the top of the next
iteration — never while pages are referenced by an in-flight EXECUTE.

Every device interaction is a Funky request through ``Monitor.submit``, so
serving stays preemptible at token boundaries: ``Monitor.evict`` between
iterations snapshots the dirty pages plus the (tiny) block table — the
``BufferTable`` tracks the pool at page granularity — and ``resume``
continues every in-flight ragged sequence bit-exactly.

Per-request latencies (TTFT, time-between-tokens, end-to-end) land in the
shared ``repro.scaling.metrics`` registry under the canonical service
schema, together with KV occupancy gauges the autoscaler reads as a memory
pressure signal.
"""

from __future__ import annotations

import heapq
import math
import os
import tempfile
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.guest import FunkyCL
from repro.core.programs import Program
from repro.models.attention import _INVALID_POS
from repro.scaling.autoscaler import (M_COMPLETIONS, M_KV_FREE_PAGES,
                                      M_KV_PAGES, M_PREEMPTIONS,
                                      M_PREFIX_HIT_RATE, M_QUEUE_DEPTH,
                                      M_SLO_VIOLATIONS, M_SPEC_ACCEPT_RATE,
                                      M_UTILIZATION)
from repro.scaling.metrics import MetricsRegistry
from repro.serve.kvcache import (BlockPool, _is_pos_leaf,
                                 apply_block_table_delta, cache_bytes,
                                 compact_pool, extract_pool_pages,
                                 extract_written_page, gather_lane_cache,
                                 init_caches_from_specs, install_pool_pages,
                                 pool_specs_from_lane_cache, scatter_pages,
                                 scatter_prefill, scrub_pages,
                                 token_axes_from_lengths)
from repro.serve.prefix_cache import PrefixCache

# Canonical per-request serving metrics (one schema across planes).
M_TTFT = "request_ttft_seconds"
M_TBT = "request_tbt_seconds"
M_E2E = "request_latency_seconds"
M_TOKENS = "engine_tokens_total"
M_ITERS = "engine_iterations_total"
M_SPEC_K = "spec_k"                 # live speculative lookahead per engine
# Host-overhead attribution (per engine): where a token's wall time went.
# device_us is the monitor-measured accelerator phase (compiled-program
# calls + transfer/sync blocking); host_us is everything else in the
# iteration loop (batching, commit/rollback, page bookkeeping); queue_wait
# is the mean monitor worker-queue wait per request.
M_HOST_US = "host_us_per_token"
M_DEVICE_US = "device_us_per_token"
M_QUEUE_WAIT_US = "queue_wait_us"


@dataclass(frozen=True)
class SpecConfig:
    """Speculative-decode configuration.

    ``draft_arch=None`` self-drafts with the target architecture; combined
    with ``draft_seed=None`` (the engine seed) the draft params equal the
    target params, so every draft token is accepted — the forced-accept
    ceiling.  ``draft_mode="antigreedy"`` makes the draft argmin instead of
    argmax, guaranteeing rejection at every position — the forced-reject
    floor (1 committed token per iteration, like plain decode).  Committed
    token streams are bit-exact vs plain greedy decode for *any* draft.

    ``dynamic_k=True`` adapts the live lookahead between ``k_min`` and
    ``k`` from the engine's acceptance signal (the same accepted/offered
    ratio the ``spec_accept_rate`` gauge publishes): every
    ``adapt_window`` offered drafts the window rate is read — below
    ``shrink_below`` the lookahead shrinks one step (rejected verify work
    stops burning iterations); at/above ``grow_above`` for two consecutive
    windows it regrows one step.  Draft/verify programs are compiled per
    ``k`` value up front, so switching depth never recompiles mid-serve,
    and adaptation only changes throughput — never tokens.
    """
    k: int = 2                          # max lookahead tokens per iteration
    draft_arch: Optional[str] = None    # None -> target arch
    draft_seed: Optional[int] = None    # None -> engine seed
    draft_mode: str = "greedy"          # "greedy" | "antigreedy"
    dynamic_k: bool = False             # adapt live k from acceptance
    k_min: int = 1                      # floor for dynamic shrink
    adapt_window: int = 32              # offered drafts per adaptation step
    shrink_below: float = 0.4           # window accept rate -> shrink
    grow_above: float = 0.8             # sustained window rate -> regrow


@dataclass
class ServeRequest:
    """One generation request admitted into a decode slot."""
    rid: str
    prompt: np.ndarray                  # (P,) int32 token ids
    max_new_tokens: int = 8
    arrival_t: Optional[float] = None   # registry-clock timestamp
    slo_s: Optional[float] = None       # end-to-end SLO (None = untracked)
    # per-request trace (repro.obs.Trace), started by the router (or the
    # engine on direct submit) when a tracer is attached; trace_id == rid
    trace: Any = None
    # committed-token state: aliased to the decode slot's tokens list at
    # admit time, so the router sees exactly what the engine generated if
    # the replica crashes and the request is replayed (no copy per token)
    committed: Optional[List[int]] = None


@dataclass
class CompletedRequest:
    rid: str
    tokens: List[int]
    arrival_t: float
    admit_t: float
    first_token_t: float
    finish_t: float
    tbts: List[float] = field(default_factory=list)

    @property
    def ttft_s(self) -> float:
        return self.first_token_t - self.arrival_t

    @property
    def e2e_s(self) -> float:
        return self.finish_t - self.arrival_t


@dataclass
class _SlotState:
    req: ServeRequest
    slot: int
    tokens: List[int]
    admit_t: float
    first_token_t: float
    last_token_t: float
    tbts: List[float] = field(default_factory=list)
    # effective generation cap: min(request ask, engine cap) — the engine's
    # cache/pages are provisioned for max_new_tokens, so an over-cap ask is
    # clamped instead of walking past the block table / ring capacity
    limit: int = 1
    # paged mode
    bucket: int = 0                     # prompt bucket this lane prefetched
    pos: int = 0                        # absolute position of the next write
    blocks: List[int] = field(default_factory=list)
    span: Any = None                    # engine.decode span (tracing)
    # fused/pipelined decode: tokens whose generation has been *submitted*
    # (committed or riding an in-flight EXECUTE).  Greedy decode with
    # limit-only masking makes token counts deterministic at submit time,
    # so positions and page mapping advance here while token values land
    # at commit.  Kept equal to len(tokens) on the non-pipelined paths.
    submitted: int = 0
    # EXECUTEs in flight that reference this lane's pages — retire (which
    # frees pages) must wait until the count drains back to zero
    inflight: int = 0
    # the lane hit EOS mid-span: the device side froze (or the host rolled
    # it back) and later in-flight spans for this lane are no-ops
    eos_done: bool = False
    # prefix-cache insert deferred until the pipelined first-token read
    # commits: (bucket, flat_prompt, page_ids)
    deferred_insert: Any = None


class ContinuousBatchingEngine:
    def __init__(self, arch: str, cl: FunkyCL, *, slots: int = 4,
                 prompt_len: int = 16, max_new_tokens: int = 16,
                 service: str = "svc", engine_id: str = "engine0",
                 seed: int = 0, registry: Optional[MetricsRegistry] = None,
                 publish_gauges: bool = True, paged: bool = True,
                 page_size: int = 8, pool_pages: Optional[int] = None,
                 reserve_pages: int = 1,
                 prompt_buckets: Optional[Sequence[int]] = None,
                 spec: Optional[SpecConfig] = None,
                 prefix_cache: bool = False,
                 prefix_cache_max_nodes: int = 4096,
                 auto_compact_frag: Optional[float] = 0.5,
                 auto_compact_min_pages: int = 4,
                 fuse_steps: int = 1, async_depth: int = 0,
                 role: str = "mixed", eos_id: Optional[int] = None,
                 tracer: Any = None):
        from repro.configs import get_arch
        from repro.models import build_model

        self.cl = cl
        self.slots = slots
        # disaggregated serving: a `prefill` replica admits prompts and
        # hands freshly prefilled lanes to a `decode` replica through a
        # TransferQueue; `mixed` is the classic aggregated engine.  Roles
        # need paged KV — the handoff moves whole pool pages.
        if role not in ("mixed", "prefill", "decode"):
            raise ValueError(f"unknown role {role!r}")
        if role != "mixed" and not paged:
            raise ValueError("role-disaggregated serving needs paged=True "
                             "(KV handoff moves pool pages)")
        if role != "mixed" and spec is not None:
            raise ValueError("speculative decode is host-authoritative and "
                             "does not survive a lane handoff; use "
                             "role='mixed'")
        self.role = role
        self.transfer = None            # TransferQueue, via attach_transfer
        # on-device stop-token detection: a lane that emits eos_id freezes
        # inside decode_multi (folded into the per-lane lim mask) instead
        # of decoding past EOS until the host window boundary
        if eos_id is not None and spec is not None:
            raise ValueError("eos_id does not compose with spec: verify "
                             "acceptance is host-decided, so EOS commits "
                             "host-side there anyway")
        self.eos_id = eos_id
        self.max_new_tokens = max_new_tokens   # per-request cap
        self.service = service
        self.engine_id = engine_id
        self.seed = seed
        self.cfg = get_arch(arch)
        self.paged = paged
        if spec is not None:
            if not paged:
                raise ValueError("speculative decode needs paged=True (the "
                                 "lookahead rolls back through block tables)")
            if spec.k < 1:
                raise ValueError("spec.k must be >= 1")
            if spec.draft_mode not in ("greedy", "antigreedy"):
                raise ValueError(f"unknown draft_mode {spec.draft_mode!r}")
            if spec.dynamic_k and not 1 <= spec.k_min <= spec.k:
                raise ValueError(
                    f"dynamic k needs 1 <= k_min <= k, got "
                    f"k_min={spec.k_min} k={spec.k}")
        self.spec = spec
        # host-out-of-the-loop decode: fuse_steps > 1 runs k greedy decode
        # steps per EXECUTE in one on-device fori_loop; async_depth > 0
        # lets step() submit iteration N+1's EXECUTE before reading back
        # iteration N's tokens (the monitor's FIFO queue serializes them)
        if fuse_steps < 1:
            raise ValueError("fuse_steps must be >= 1")
        if async_depth < 0:
            raise ValueError("async_depth must be >= 0")
        if (fuse_steps > 1 or async_depth > 0) and not paged:
            raise ValueError("fused/pipelined decode needs paged=True (the "
                             "multi-step program maps its write span "
                             "through block tables)")
        if spec is not None and (fuse_steps > 1 or async_depth > 0):
            raise ValueError(
                "fuse_steps/async_depth do not compose with spec: the "
                "verify program already fuses k+1 positions per EXECUTE "
                "and acceptance is host-decided, so the host cannot be "
                "taken out of that loop")
        self.fuse_steps = fuse_steps
        self.async_depth = async_depth
        # pipelined mode: EXECUTEs (decode spans AND admissions) are
        # committed at a later boundary instead of being waited at the
        # submit site — the host stays off the device hot path
        self._pipelined = fuse_steps > 1 or async_depth > 0
        # spec_k is the provisioning maximum (capacity, scrub width); the
        # *live* lookahead spec_k_now moves in spec_ks under dynamic_k
        self.spec_k = spec.k if spec is not None else 0
        self.spec_k_now = self.spec_k
        if spec is not None and spec.dynamic_k:
            self.spec_ks = tuple(range(spec.k_min, spec.k + 1))
        else:
            self.spec_ks = (self.spec_k,) if spec is not None else ()
        self._adapt_offered = 0
        self._adapt_accepted = 0
        self._grow_streak = 0
        self.auto_compact_frag = auto_compact_frag
        self.auto_compact_min_pages = auto_compact_min_pages
        if prompt_buckets and prompt_len > max(prompt_buckets):
            raise ValueError(
                f"prompt_len {prompt_len} exceeds the largest prompt "
                f"bucket {max(prompt_buckets)}: prompts would be silently "
                "truncated — add prompt_len as the largest bucket")
        if paged:
            self.buckets = tuple(sorted(set(prompt_buckets or (prompt_len,))))
            self.prompt_len = max(self.buckets)
            self.page_size = page_size
            # +headroom: verify (spec) writes up to k positions past the
            # commit horizon, and a fused decode's masked steps write up
            # to fuse_steps-1 positions past a retiring lane's limit —
            # those in-flight slots must never wrap the table
            self.max_ctx = (self.prompt_len + max_new_tokens
                            + max(self.spec_k, fuse_steps - 1))
            self.max_blocks = math.ceil(self.max_ctx / page_size)
            # default pool covers the worst case (no oversubscription);
            # benchmarks/servers pass a smaller pool to oversubscribe
            self.pool_pages = (pool_pages if pool_pages is not None
                               else slots * self.max_blocks)
            if self.pool_pages < self.max_blocks:
                raise ValueError(
                    f"pool of {self.pool_pages} pages cannot hold one "
                    f"worst-case request ({self.max_blocks} pages)")
            max_prompt_pages = math.ceil(self.prompt_len / page_size)
            if self.pool_pages - max_prompt_pages < reserve_pages:
                raise ValueError(
                    f"reserve watermark {reserve_pages} can never clear for "
                    f"a {max_prompt_pages}-page prompt in a "
                    f"{self.pool_pages}-page pool (admission would starve)")
            self.pool = BlockPool(self.pool_pages, page_size,
                                  reserve_pages=reserve_pages)
            # first-touch pages are born scrubbed (init_paged writes
            # INVALID positions pool-wide) — only reused pages need the
            # zeroing EXECUTE; populated at setup, emptied conservatively
            # on restore/evacuate
            self._virgin_pages: set = set()
            # benchmark baselines flip this before setup() to recreate
            # the staged 4-op admission (write + prefill + admit + read)
            # the single-EXECUTE prefill_admit path replaced
            self._legacy_admit = False
            if prefix_cache:
                # page-granular sharing needs every prompt bucket to land
                # on a page boundary: nodes key whole pages, and the
                # chunked prefill writes exactly one page per EXECUTE
                bad = [b for b in self.buckets if b % page_size]
                if bad:
                    raise ValueError(
                        f"prefix_cache needs page-aligned prompt buckets; "
                        f"{bad} not divisible by page_size {page_size}")
                self.prefix = PrefixCache(
                    self.pool, page_size,
                    max_nodes=prefix_cache_max_nodes)
            else:
                self.prefix = None
            self._prefix_max_nodes = prefix_cache_max_nodes
            # paged prefill writes exactly the prompt (margin 0); decode
            # headroom comes from pages appended at token granularity
            self.bundle = build_model(self.cfg, cache_margin=0)
            self._bt_host = np.full((slots, self.max_blocks), -1, np.int32)
            # device-resident block table: _bt_host is a host *mirror*
            # (dirty-page spans, spec rollback math); steady-state updates
            # ship as (slot, logical_page, phys) delta rows applied by the
            # bt_update EXECUTE.  _bt_full forces a full h2d rewrite
            # (setup/compact/evacuate, or delta overflow).
            self._bt_dirty = True
            self._bt_full = True
            self._bt_delta: List[Tuple[int, int, int]] = []
            self._bt_delta_width = max(16, 4 * slots)
            self.bt_delta_execs = 0     # delta-driven device updates
            self.bt_full_writes = 0     # full-table h2d rewrites
            self._first_token: Dict[str, float] = {}
            if spec is not None:
                self.draft_cfg = get_arch(spec.draft_arch or arch)
                # dense per-lane draft cache: capacity must reach the last
                # lookahead write, prompt_len + max_new_tokens + k - 1
                self.draft_bundle = build_model(
                    self.draft_cfg,
                    cache_margin=max_new_tokens + spec.k)
                self.draft_seed = (spec.draft_seed
                                   if spec.draft_seed is not None else seed)
                # host-authoritative lane state: the verify EXECUTE cannot
                # know acceptance, so toks/pos are committed here and
                # rewritten h2d (tiny) before each speculative iteration
                self._toks_host = np.zeros((slots, 1), np.int32)
                self._pos_host = np.zeros((slots,), np.int32)
        else:
            if prompt_buckets:
                raise ValueError("prompt buckets need paged=True (dense "
                                 "lanes are compiled to one prompt_len)")
            if prefix_cache:
                raise ValueError("prefix_cache needs paged=True (sharing "
                                 "maps pool pages through block tables)")
            self.prefix = None
            self.buckets = (prompt_len,)
            self.prompt_len = prompt_len
            # cache capacity = prompt_len + max_new_tokens: prefill reserves
            # the decode headroom so admission is a pure scatter
            self.bundle = build_model(self.cfg, cache_margin=max_new_tokens)
            self.pool = None
        self.registry = (registry if registry is not None
                         else cl._monitor.telemetry)
        self._clock = self.registry.clock
        self._publish_gauges = publish_gauges
        # tracing: explicit tracer wins; else share the monitor's, if any
        self.tracer = (tracer if tracer is not None
                       else getattr(cl._monitor, "tracer", None))
        self._it_root = None            # current iteration's root span
        self._step_completions: List = []
        # pipelined decode: batches of (exec_completion, read_completion,
        # [(slot_state, n_tokens)]) submitted but not yet committed; at
        # most async_depth stay outstanding while new work exists
        self._inflight: deque = deque()
        # set after a failed fused EXECUTE: device toks/pos must be
        # rewritten from the host-authoritative lane state before the next
        # submit (later pipelined EXECUTEs ran against the pre-failure
        # state, leaving the device scalars ahead of the rolled-back host)
        self._resync_lanes = False
        # host/device attribution accumulators (populated from the
        # monitor's per-request phase dicts, tracer or not)
        self._attr_host_s = 0.0
        self._attr_device_s = 0.0
        self._attr_queue_wait_s = 0.0
        self._attr_tokens = 0
        self._attr_execs = 0
        self._attr_reqs = 0
        # handles resolved once — the per-iteration loop never takes the
        # registry lock (same rule as the monitor's dispatch loop)
        self._h_ttft = self.registry.histogram(M_TTFT, service=service)
        self._h_tbt = self.registry.histogram(M_TBT, service=service)
        self._h_e2e = self.registry.histogram(M_E2E, service=service)
        self._c_tokens = self.registry.counter(M_TOKENS, service=service)
        self._c_iters = self.registry.counter(M_ITERS, service=service)
        self._c_completions = self.registry.counter(M_COMPLETIONS,
                                                    service=service)
        self._c_violations = self.registry.counter(M_SLO_VIOLATIONS,
                                                   service=service)
        self._c_preemptions = self.registry.counter(M_PREEMPTIONS,
                                                    service=service)
        if publish_gauges:
            self._g_queue = self.registry.gauge(
                M_QUEUE_DEPTH, service=service, engine=engine_id)
            self._g_util = self.registry.gauge(
                M_UTILIZATION, service=service, engine=engine_id)
            self._g_kv = self.registry.gauge(
                M_KV_PAGES, service=service, engine=engine_id)
            self._g_kv_free = self.registry.gauge(
                M_KV_FREE_PAGES, service=service, engine=engine_id)
            self._g_host_us = self.registry.gauge(
                M_HOST_US, service=service, engine=engine_id)
            self._g_device_us = self.registry.gauge(
                M_DEVICE_US, service=service, engine=engine_id)
            self._g_queue_wait_us = self.registry.gauge(
                M_QUEUE_WAIT_US, service=service, engine=engine_id)
            if spec is not None:
                self._g_spec = self.registry.gauge(
                    M_SPEC_ACCEPT_RATE, service=service, engine=engine_id)
                self._g_spec_k = self.registry.gauge(
                    M_SPEC_K, service=service, engine=engine_id)
                self._g_spec_k.set(self.spec_k_now)
            if self.prefix is not None:
                self._g_prefix = self.registry.gauge(
                    M_PREFIX_HIT_RATE, service=service, engine=engine_id)

        self.pending: deque = deque()
        self._free: List[int] = list(range(slots))
        heapq.heapify(self._free)
        self._active: Dict[int, _SlotState] = {}
        self.completed: Dict[str, CompletedRequest] = {}
        self._unreported: deque = deque()   # completions not yet drained
        self.iterations = 0
        self.peak_active = 0                # max concurrent in-flight lanes
        self.preemptions = 0
        self.auto_compactions = 0
        # prefix-cache accounting (all zero when the cache is off)
        self.prefix_hits = 0                # full-prompt hits (no prefill)
        self.prefix_partial_hits = 0        # suffix-only prefills
        self.prefix_misses = 0
        self.prefix_prompt_tokens = 0       # padded prompt tokens admitted
        self.prefix_cached_tokens = 0       # of those, served from cache
        self.cow_copies = 0                 # shared pages privatized
        # speculative-decode accounting (all zero when spec is off)
        self.spec_iterations = 0            # verify EXECUTEs issued
        self.spec_lane_iterations = 0       # active-lane verify passes
        self.spec_committed = 0             # tokens committed via verify
        self.spec_offered_drafts = 0        # draft tokens that could commit
        self.spec_accepted_drafts = 0
        self._mid_step = False              # pages in flight: no compaction
        self._setup_done = False
        self._program_ids: List[str] = []

    # ------------------------------------------------------------------
    # Program/buffer setup (Funky guest-style, via FunkyCL only)
    # ------------------------------------------------------------------
    def setup(self, restore: bool = False) -> None:
        if self.paged:
            self._setup_paged(restore)
        else:
            self._setup_reserved(restore)
        self._setup_done = True

    def program_ids(self) -> tuple:
        return tuple(self._program_ids)

    def _register(self, cl, name, fn, abstracts, donate_argnums=()):
        cl.clCreateProgramWithBinary(Program(name, fn), abstracts,
                                     donate_argnums=donate_argnums)
        self._program_ids.append(name)

    def _prefill_fn(self):
        bundle = self.bundle

        def prefill_one(params, tokens):
            logits, cache = bundle.prefill_fn(params, {"tokens": tokens})
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        return prefill_one

    # -- paged layout ----------------------------------------------------
    def _setup_paged(self, restore: bool) -> None:
        bundle, B, ps = self.bundle, self.slots, self.page_size
        NP, max_blocks = self.pool_pages, self.max_blocks
        prefill_one = self._prefill_fn()

        def init_params(seed):
            return bundle.init(jax.random.PRNGKey(seed))

        params_abs = jax.eval_shape(lambda: init_params(0))
        pf_abs = {}
        for P in self.buckets:
            prompt_abs = jax.ShapeDtypeStruct((1, P), jnp.int32)
            pf_tok_abs, pf_cache_abs = jax.eval_shape(
                prefill_one, params_abs, prompt_abs)
            pf_abs[P] = (prompt_abs, pf_tok_abs, pf_cache_abs)
        # discover each cache leaf's token axis by diffing two prompt
        # lengths (rejects layouts paging cannot virtualize, e.g.
        # window-bounded rings) — buckets give the second length for free
        if len(self.buckets) > 1:
            alt, alt_cache = self.buckets[0], pf_abs[self.buckets[0]][2]
        else:
            alt = self.prompt_len - 1
            if alt < 1:
                raise ValueError("paged mode needs prompt_len >= 2")
            _, alt_cache = jax.eval_shape(
                prefill_one, params_abs,
                jax.ShapeDtypeStruct((1, alt), jnp.int32))
        token_axes = token_axes_from_lengths(
            alt_cache, pf_abs[self.prompt_len][2], alt, self.prompt_len)
        self._token_axes = token_axes
        pool_abs = pool_specs_from_lane_cache(
            pf_abs[self.prompt_len][2], token_axes, NP, ps)
        self._pool_abs = pool_abs
        self.pool_bytes = cache_bytes(pool_abs)
        self.page_bytes = self.pool_bytes // NP
        toks_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
        bt_abs = jax.ShapeDtypeStruct((B, max_blocks), jnp.int32)

        def init_paged():
            return (jnp.zeros((B, 1), jnp.int32), jnp.zeros((B,), jnp.int32),
                    init_caches_from_specs(pool_abs))

        def decode_step(params, toks, pos, bt, pool):
            def lane(tok, p, bt_row):
                caches = gather_lane_cache(pool, bt_row, token_axes,
                                           page_size=ps)
                logits, new_cache = bundle.decode_fn(params, tok, p, caches)
                new_tok = jnp.argmax(logits, -1).astype(jnp.int32)
                active = bt_row[0] >= 0
                lp = (p % (max_blocks * ps)) // ps
                pages = extract_written_page(new_cache, lp, token_axes,
                                             page_size=ps)
                # the bt_row[lp] >= 0 guard drops writes landing past the
                # lane's mapped span — a pipelined lane awaiting its final
                # commit keeps decoding (garbage, never committed) and may
                # walk onto a page that was never appended
                phys = jnp.where(active & (bt_row[lp] >= 0), bt_row[lp],
                                 jnp.int32(NP))
                new_p = jnp.where(active, p + jnp.int32(1), p)
                return new_tok, new_p, pages, phys

            toks2, pos2, pages, phys = jax.vmap(
                lane, in_axes=(0, 0, 0))(toks, pos, bt)
            return toks2, pos2, scatter_pages(pool, phys, pages)

        # fused multi-step decode: fuse_steps greedy steps per EXECUTE in
        # one on-device fori_loop.  Per-lane ``lim`` (a const arg — the
        # signature cache keys shapes, not values) masks token/pos updates
        # once a lane hits its limit; cache writes past the mask land at
        # positions every future query masks out (the same rejected-tail
        # argument as speculative decode) and unmapped span pages are
        # dropped by the scatter, so no masking of the KV write is needed.
        kf = self.fuse_steps
        eos = self.eos_id

        def decode_multi(params, toks, pos, bt, pool, lims, delta):
            # pending block-table rows ride the fused EXECUTE itself (a
            # const arg, all-sentinel when clean): in the steady state
            # the delta costs zero extra FIFO ops
            bt = apply_block_table_delta(bt, delta)
            n_span = (kf - 1) // ps + 2

            def lane(tok, p, bt_row, lim):
                cache = gather_lane_cache(pool, bt_row, token_axes,
                                          page_size=ps)
                on = bt_row[0] >= 0
                lim = jnp.clip(lim, 0, kf)
                # on-device stop-token detection: EOS folds into the same
                # per-lane mask as the limit, so a lane freezes mid-span —
                # its token stops updating, its position stops advancing,
                # and post-EOS cache writes land at masked-out positions
                # (the rejected-tail argument above).  Entering a span
                # whose input token is already EOS keeps the lane frozen
                # across EXECUTEs.
                done0 = (tok[0] == jnp.int32(eos)) if eos is not None \
                    else jnp.bool_(False)

                def body(i, carry):
                    cur, outs, c, adv, done = carry
                    logits, c2 = bundle.decode_fn(params, cur, p + i, c)
                    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
                    step_on = on & (i < lim) & ~done
                    cur2 = jnp.where(step_on, nxt, cur)
                    if eos is not None:
                        done = done | (step_on & (cur2[0] == jnp.int32(eos)))
                    adv2 = adv + step_on.astype(jnp.int32)
                    return cur2, outs.at[i].set(cur2[0]), c2, adv2, done

                cur, outs, cache, adv, _ = jax.lax.fori_loop(
                    0, kf, body,
                    (tok, jnp.zeros((kf,), jnp.int32), cache,
                     jnp.int32(0), done0))
                lp0 = (p % (max_blocks * ps)) // ps
                pages, phys = [], []
                for j in range(n_span):
                    lp = jnp.minimum(lp0 + jnp.int32(j),
                                     jnp.int32(max_blocks - 1))
                    pages.append(extract_written_page(
                        cache, lp, token_axes, page_size=ps))
                    ok = on & (lp0 + j < max_blocks) & (bt_row[lp] >= 0)
                    phys.append(jnp.where(ok, bt_row[lp], jnp.int32(NP)))
                # adv == lim for active un-frozen lanes; a frozen lane's
                # device position stops at EOS so the host rollback at
                # commit time keeps both sides in lockstep
                new_p = jnp.where(on, p + adv, p)
                return cur, new_p, outs, tuple(pages), jnp.stack(phys)

            toks2, pos2, outs, pages, phys = jax.vmap(
                lane, in_axes=(0, 0, 0, 0))(toks, pos, bt, lims)
            n_span = (kf - 1) // ps + 2
            for j in range(n_span):
                pool = scatter_pages(pool, phys[:, j], pages[j])
            return outs, toks2, pos2, bt, pool

        def bt_update(bt, delta):
            return apply_block_table_delta(bt, delta)

        def scrub(pool, page_ids):
            return scrub_pages(pool, page_ids)

        def compact(pool, src_ids, dst_ids):
            return compact_pool(pool, src_ids, dst_ids)

        cl = self.cl
        self._register(cl, "init_params", init_params, (0,))
        self._register(cl, "init_paged", init_paged, ())
        slot_abs = jnp.int32(0)
        # one lookahead (or one fused k-step span) can append several
        # pages per lane, so the scrub vector is sized for the
        # worst-case per-iteration page growth — and, with the prefix
        # cache, for a whole prompt's fresh suffix pages scrubbed in one
        # EXECUTE before the chunked prefill
        self._scrub_width = B * (max(self.spec_k,
                                     self.fuse_steps - 1) // ps + 2)
        if self.prefix is not None:
            self._scrub_width = max(self._scrub_width, self.prompt_len // ps)
        ids_abs = jax.ShapeDtypeStruct((self._scrub_width,), jnp.int32)
        np_abs = jax.ShapeDtypeStruct((NP,), jnp.int32)
        if self.prefix is None:
            for P, (prompt_abs, pf_tok_abs, pf_cache_abs) in pf_abs.items():
                n_pp = self.pool.pages_for_tokens(P)
                pp_abs = jax.ShapeDtypeStruct((n_pp,), jnp.int32)

                # single-EXECUTE admission: prefill + first-token argmax +
                # lane install + page scatter in one op, the prompt a
                # const arg (shape-keyed signature: one compile per
                # bucket).  Four FIFO ops per admission collapse to one —
                # per-op monitor overhead is the dominant host cost the
                # fused decode path leaves behind.
                def prefill_admit(params, toks, pos, pool, prompt, slot,
                                  page_ids, P=P):
                    pf_tok, pf_cache = prefill_one(params, prompt)
                    slot = jnp.asarray(slot, jnp.int32)
                    toks = jax.lax.dynamic_update_slice(
                        toks, pf_tok[:, None], (slot, jnp.int32(0)))
                    pos = jax.lax.dynamic_update_slice(
                        pos, jnp.full((1,), P, jnp.int32), (slot,))
                    pool = scatter_prefill(pool, page_ids, pf_cache,
                                           token_axes, page_size=ps,
                                           prompt_len=P)
                    return pf_tok, toks, pos, pool

                self._register(
                    cl, f"prefill_admit_{P}", prefill_admit,
                    (params_abs, toks_abs, pos_abs, pool_abs, prompt_abs,
                     slot_abs, pp_abs),
                    donate_argnums=(1, 2, 3))
                if self.spec is None and not self._legacy_admit:
                    continue
                # speculative admission keeps the staged path: the draft
                # prefill reads the same pf_prompt buffer, and the host
                # needs the first token synchronously for its lane mirror
                # (benchmark baselines recreate it via _legacy_admit)
                self._register(cl, f"prefill_{P}", prefill_one,
                               (params_abs, prompt_abs))

                def admit(toks, pos, pool, pf_tok, pf_cache, slot, page_ids,
                          P=P):
                    slot = jnp.asarray(slot, jnp.int32)
                    toks = jax.lax.dynamic_update_slice(
                        toks, pf_tok[:, None], (slot, jnp.int32(0)))
                    pos = jax.lax.dynamic_update_slice(
                        pos, jnp.full((1,), P, jnp.int32), (slot,))
                    pool = scatter_prefill(pool, page_ids, pf_cache,
                                           token_axes, page_size=ps,
                                           prompt_len=P)
                    return toks, pos, pool

                self._register(
                    cl, f"admit_{P}", admit,
                    (toks_abs, pos_abs, pool_abs, pf_tok_abs, pf_cache_abs,
                     slot_abs, pp_abs),
                    donate_argnums=(0, 1, 2))
        else:
            # Prefix-cache mode replaces the fused per-bucket prefill with
            # ONE page-granular chunk program shared by every bucket: each
            # EXECUTE feeds page ``lp``'s tokens sequentially through the
            # decode step over the lane's gathered cache and scatters
            # exactly that page back.  Cold admissions run every chunk; a
            # prefix hit skips the covered ones — and because hit and cold
            # paths run the *same* compiled program over the same inputs,
            # prefix-hit decode is bit-exact vs. a cold run by
            # construction (sequential decode is NOT bitwise identical to
            # fused prefill, so mixing the two paths would break the
            # equivalence gate).
            pf_tok_abs = pf_abs[self.prompt_len][1]
            chunk_abs = jax.ShapeDtypeStruct((ps,), jnp.int32)
            row_abs = jax.ShapeDtypeStruct((max_blocks,), jnp.int32)

            def prefill_chunk(params, pool, chunk_toks, lp, bt_row):
                lp = jnp.asarray(lp, jnp.int32)
                cache = gather_lane_cache(pool, bt_row, token_axes,
                                          page_size=ps)
                pos0 = lp * jnp.int32(ps)
                logits = None
                for i in range(ps):
                    logits, cache = bundle.decode_fn(
                        params, chunk_toks[i][None],
                        pos0 + jnp.int32(i), cache)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                page = extract_written_page(cache, lp, token_axes,
                                            page_size=ps)
                phys = bt_row[lp][None]
                pool = scatter_pages(pool, phys,
                                     jax.tree.map(lambda x: x[None], page))
                return tok, pool

            self._register(cl, "prefill_chunk", prefill_chunk,
                           (params_abs, pool_abs, chunk_abs, slot_abs,
                            row_abs),
                           donate_argnums=(1,))

            def admit_tok(toks, pos, pf_tok, slot, p_end):
                slot = jnp.asarray(slot, jnp.int32)
                toks = jax.lax.dynamic_update_slice(
                    toks, pf_tok[:, None], (slot, jnp.int32(0)))
                pos = jax.lax.dynamic_update_slice(
                    pos, jnp.asarray(p_end, jnp.int32)[None], (slot,))
                return toks, pos

            self._register(cl, "admit_tok", admit_tok,
                           (toks_abs, pos_abs, pf_tok_abs, slot_abs,
                            slot_abs),
                           donate_argnums=(0, 1))
        self._register(cl, "scrub", scrub, (pool_abs, ids_abs),
                       donate_argnums=(0,))
        self._register(cl, "compact_pool", compact,
                       (pool_abs, np_abs, np_abs), donate_argnums=(0,))
        self._register(cl, "decode_step", decode_step,
                       (params_abs, toks_abs, pos_abs, bt_abs, pool_abs),
                       donate_argnums=(1, 2, 4))
        delta_abs = jax.ShapeDtypeStruct((self._bt_delta_width, 3),
                                         jnp.int32)
        self._register(cl, "bt_update", bt_update, (bt_abs, delta_abs),
                       donate_argnums=(0,))
        if kf > 1:
            lims_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
            self._register(cl, "decode_multi", decode_multi,
                           (params_abs, toks_abs, pos_abs, bt_abs, pool_abs,
                            lims_abs, delta_abs),
                           donate_argnums=(1, 2, 3, 4))
        if self.role != "mixed":
            # cross-replica KV handoff: a prefill replica gathers a lane's
            # pages into a fixed-width staging buffer (d2h read follows), a
            # decode replica scatters the staged pages into freshly
            # allocated pages of its own pool and installs the lane
            # scalars.  Out-of-range ids are padding on both sides.
            xfer_ids_abs = jax.ShapeDtypeStruct((max_blocks,), jnp.int32)
            xfer_abs = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct((max_blocks,) + l.shape[1:],
                                               l.dtype), pool_abs)
            self._xfer_abs = xfer_abs

            def xfer_extract(pool, page_ids):
                return extract_pool_pages(pool, page_ids)

            def xfer_install(pool, staged, page_ids):
                return install_pool_pages(pool, staged, page_ids)

            def lane_set(toks, pos, tok, p, slot):
                slot = jnp.asarray(slot, jnp.int32)
                toks = jax.lax.dynamic_update_slice(
                    toks, jnp.asarray(tok, jnp.int32).reshape(1, 1),
                    (slot, jnp.int32(0)))
                pos = jax.lax.dynamic_update_slice(
                    pos, jnp.asarray(p, jnp.int32)[None], (slot,))
                return toks, pos

            self._register(cl, "xfer_extract", xfer_extract,
                           (pool_abs, xfer_ids_abs))
            self._register(cl, "xfer_install", xfer_install,
                           (pool_abs, xfer_abs, xfer_ids_abs),
                           donate_argnums=(0,))
            self._register(cl, "lane_set", lane_set,
                           (toks_abs, pos_abs, slot_abs, slot_abs, slot_abs),
                           donate_argnums=(0, 1))
        if self.spec is not None:
            self._setup_spec(params_abs, toks_abs, pos_abs, bt_abs, pool_abs,
                             token_axes)
        if not restore:
            cl.clCreateBuffer("params", params_abs)
            cl.clCreateBuffer("toks", toks_abs)
            cl.clCreateBuffer("pos", pos_abs)
            cl.clCreateBuffer("block_table", bt_abs)
            cl.clCreateBuffer("kv_pool", pool_abs, paged=True)
            cl.clCreateBuffer("pf_tok", pf_abs[self.prompt_len][1])
            if self.role != "mixed":
                cl.clCreateBuffer("xfer_pages", self._xfer_abs)
            if kf > 1:
                cl.clCreateBuffer(
                    "fused_toks", jax.ShapeDtypeStruct((B, kf), jnp.int32))
            for P, (prompt_abs, _, pf_cache_abs) in pf_abs.items():
                # plain paged admission is a single EXECUTE taking the
                # prompt as a const arg (like the prefix cache's chunked
                # path), so the staging prompt/cache buffers only exist
                # for speculative engines: the draft prefill reads the
                # prompt buffer, and the staged admit hands the prefill
                # cache across ops
                if self.spec is not None or self._legacy_admit:
                    cl.clCreateBuffer(f"pf_prompt_{P}", prompt_abs)
                    if self.prefix is None:
                        cl.clCreateBuffer(f"pf_cache_{P}", pf_cache_abs)
            cl.clEnqueueKernel("init_params", (), ("params",),
                               const_args=(self.seed,))
            cl.clEnqueueKernel("init_paged", (),
                               ("toks", "pos", "kv_pool"))
            # the freshly-initialized pool is all-INVALID: every page is
            # clean until its first allocation (restore keeps the set
            # empty — snapshot pool contents are a previous life's)
            self._virgin_pages = set(range(self.pool_pages))
            cl.write_buffer("block_table", self._bt_host.copy())
            if self.spec is not None:
                cl.clCreateBuffer("draft_params", self._draft_params_abs)
                cl.clCreateBuffer("draft_caches", self._draft_caches_abs)
                for v in self.spec_ks:
                    cl.clCreateBuffer(f"draft_toks_k{v}",
                                      self._draft_toks_abs[v])
                    cl.clCreateBuffer(f"verify_toks_k{v}",
                                      self._verify_toks_abs[v])
                for P, (_, dpf_cache_abs) in self._draft_pf_abs.items():
                    cl.clCreateBuffer(f"pf_draft_cache_{P}", dpf_cache_abs)
                cl.clEnqueueKernel("init_draft_params", (),
                                   ("draft_params",),
                                   const_args=(self.draft_seed,))
                cl.clEnqueueKernel("init_draft", (), ("draft_caches",))
            cl.clFinish()
            self._bt_dirty = False
            self._bt_full = False
            self._bt_delta.clear()

    # -- speculative decode: draft + verify programs ---------------------
    def _setup_spec(self, params_abs, toks_abs, pos_abs, bt_abs, pool_abs,
                    token_axes) -> None:
        spec, bundle, dbundle = self.spec, self.bundle, self.draft_bundle
        B, ps, k = self.slots, self.page_size, self.spec_k
        NP, max_blocks = self.pool_pages, self.max_blocks
        argfn = jnp.argmax if spec.draft_mode == "greedy" else jnp.argmin

        def init_draft_params(seed):
            return dbundle.init(jax.random.PRNGKey(seed))

        def draft_prefill_one(dparams, tokens):
            _, cache = dbundle.prefill_fn(dparams, {"tokens": tokens})
            return cache

        dparams_abs = jax.eval_shape(lambda: init_draft_params(0))
        dpf_abs = {}
        for P in self.buckets:
            prompt_abs = jax.ShapeDtypeStruct((1, P), jnp.int32)
            dpf_abs[P] = (prompt_abs, jax.eval_shape(
                draft_prefill_one, dparams_abs, prompt_abs))
        # draft lane capacity is prompt + constant margin, so the token
        # axis is found by size *delta* (exact=False), not size equality
        if len(self.buckets) > 1:
            alt = self.buckets[0]
            alt_cache = dpf_abs[alt][1]
        else:
            alt = self.prompt_len - 1
            alt_cache = jax.eval_shape(
                draft_prefill_one, dparams_abs,
                jax.ShapeDtypeStruct((1, alt), jnp.int32))
        d_axes = token_axes_from_lengths(
            alt_cache, dpf_abs[self.prompt_len][1], alt, self.prompt_len,
            exact=False)
        lane_abs = dpf_abs[self.prompt_len][1]   # largest bucket = stripe
        dcaches_abs = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((B,) + l.shape, l.dtype),
            lane_abs)
        self._draft_params_abs = dparams_abs
        self._draft_caches_abs = dcaches_abs
        # one draft/verify program pair per allowed lookahead depth: a
        # dynamic-k engine switches between precompiled depths (bitstream
        # library), never recompiling mid-serve
        self._draft_toks_abs = {
            v: jax.ShapeDtypeStruct((B, v), jnp.int32)
            for v in self.spec_ks}
        self._verify_toks_abs = {
            v: jax.ShapeDtypeStruct((B, v + 1), jnp.int32)
            for v in self.spec_ks}
        self._draft_pf_abs = dpf_abs

        def init_draft():
            return init_caches_from_specs(dcaches_abs)

        def make_draft_lookahead(v):
            def draft_lookahead(dparams, toks, pos, dcaches):
                # v+1 steps for v offered drafts: the extra step feeds the
                # last draft token back so its KV lands in the draft cache
                # — under full acceptance the commit advances v+1
                # positions, and without it the draft state would grow one
                # hole per iteration (degrading acceptance, never
                # correctness)
                def lane(tok, p, cache):
                    cur, outs = tok, []
                    for i in range(v + 1):
                        logits, cache = dbundle.decode_fn(
                            dparams, cur, p + jnp.int32(i), cache)
                        cur = argfn(logits, -1).astype(jnp.int32)
                        if i < v:
                            outs.append(cur)
                    return jnp.concatenate(outs), cache

                return jax.vmap(lane)(toks, pos, dcaches)
            return draft_lookahead

        def make_verify_step(v):
            # pages one v+1-token write window can span
            n_span = v // ps + 2

            def verify_step(params, toks, d_toks, pos, bt, pool):
                def lane(tok, drafts, p, bt_row):
                    cache = gather_lane_cache(pool, bt_row, token_axes,
                                              page_size=ps)
                    cur, outs = tok, []
                    for i in range(v + 1):
                        logits, cache = bundle.decode_fn(
                            params, cur, p + jnp.int32(i), cache)
                        outs.append(jnp.argmax(logits, -1).astype(jnp.int32))
                        if i < v:
                            cur = drafts[i][None]
                    active = bt_row[0] >= 0
                    lp0 = (p % (max_blocks * ps)) // ps
                    pages, phys = [], []
                    for j in range(n_span):
                        lp = jnp.minimum(lp0 + j, jnp.int32(max_blocks - 1))
                        pages.append(extract_written_page(
                            cache, lp, token_axes, page_size=ps))
                        ok = active & (lp0 + j < max_blocks) \
                            & (bt_row[lp] >= 0)
                        phys.append(jnp.where(ok, bt_row[lp], jnp.int32(NP)))
                    return jnp.concatenate(outs), tuple(pages), \
                        jnp.stack(phys)

                outs, pages, phys = jax.vmap(lane)(toks, d_toks, pos, bt)
                # per-lane pages are disjoint (inactive/unmapped dropped)
                for j in range(n_span):
                    pool = scatter_pages(pool, phys[:, j], pages[j])
                return outs, pool
            return verify_step

        cl = self.cl
        self._register(cl, "init_draft_params", init_draft_params, (0,))
        self._register(cl, "init_draft", init_draft, ())
        for P, (prompt_abs, dpf_cache_abs) in dpf_abs.items():
            self._register(cl, f"draft_prefill_{P}", draft_prefill_one,
                           (dparams_abs, prompt_abs))

            def admit_draft(dcaches, pf_cache, slot):
                slot = jnp.asarray(slot, jnp.int32)

                def upd(path, lane_all, new, axis):
                    tf = jnp.moveaxis(new, axis, 0)
                    pad = lane_all.shape[axis + 1] - tf.shape[0]
                    if pad:
                        fill = (jnp.full((pad,) + tf.shape[1:],
                                         _INVALID_POS, jnp.int32)
                                if _is_pos_leaf(path)
                                else jnp.zeros((pad,) + tf.shape[1:],
                                               tf.dtype))
                        tf = jnp.concatenate([tf, fill])
                    row = jnp.moveaxis(tf, 0, axis)
                    return jax.lax.dynamic_update_slice(
                        lane_all, row[None],
                        (slot,) + (jnp.int32(0),) * row.ndim)

                return jax.tree_util.tree_map_with_path(
                    upd, dcaches, pf_cache, d_axes)

            self._register(cl, f"admit_draft_{P}", admit_draft,
                           (dcaches_abs, dpf_cache_abs, jnp.int32(0)),
                           donate_argnums=(0,))
        for v in self.spec_ks:
            self._register(cl, f"draft_lookahead_k{v}",
                           make_draft_lookahead(v),
                           (dparams_abs, toks_abs, pos_abs, dcaches_abs),
                           donate_argnums=(3,))
            self._register(cl, f"verify_step_k{v}", make_verify_step(v),
                           (params_abs, toks_abs, self._draft_toks_abs[v],
                            pos_abs, bt_abs, pool_abs),
                           donate_argnums=(5,))

    # -- reserved (worst-case stripe) layout -----------------------------
    def _setup_reserved(self, restore: bool) -> None:
        bundle, B, P = self.bundle, self.slots, self.prompt_len
        prefill_one = self._prefill_fn()

        def init_params(seed):
            return bundle.init(jax.random.PRNGKey(seed))

        def decode_step(params, toks, pos, caches):
            def lane(tok, p, cache):
                logits, new_cache = bundle.decode_fn(params, tok, p, cache)
                return (jnp.argmax(logits, -1).astype(jnp.int32),
                        p + jnp.int32(1), new_cache)
            return jax.vmap(lane)(toks, pos, caches)

        def admit_slot(toks, pos, caches, pf_tok, pf_cache, slot):
            slot = jnp.asarray(slot, jnp.int32)
            toks = jax.lax.dynamic_update_slice(
                toks, pf_tok[:, None], (slot, jnp.int32(0)))
            pos = jax.lax.dynamic_update_slice(
                pos, jnp.full((1,), P, jnp.int32), (slot,))
            caches = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_slice(
                    c, n[None], (slot,) + (jnp.int32(0),) * n.ndim),
                caches, pf_cache)
            return toks, pos, caches

        params_abs = jax.eval_shape(lambda: init_params(0))
        prompt_abs = jax.ShapeDtypeStruct((1, P), jnp.int32)
        pf_tok_abs, pf_cache_abs = jax.eval_shape(
            prefill_one, params_abs, prompt_abs)
        caches_abs = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((B,) + l.shape, l.dtype),
            pf_cache_abs)
        toks_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
        self._caches_abs = caches_abs
        self.pool_bytes = cache_bytes(caches_abs)

        def init_slots():
            return (jnp.zeros((B, 1), jnp.int32), jnp.zeros((B,), jnp.int32),
                    init_caches_from_specs(caches_abs))

        cl = self.cl
        self._register(cl, "init_params", init_params, (0,))
        self._register(cl, "init_slots", init_slots, ())
        self._register(cl, f"prefill_{P}", prefill_one,
                       (params_abs, prompt_abs))
        slot_abs = jnp.int32(0)
        self._register(
            cl, "admit_slot", admit_slot,
            (toks_abs, pos_abs, caches_abs, pf_tok_abs, pf_cache_abs,
             slot_abs),
            donate_argnums=(0, 1, 2))
        self._register(
            cl, "decode_step", decode_step,
            (params_abs, toks_abs, pos_abs, caches_abs),
            donate_argnums=(1, 2, 3))
        if not restore:
            cl.clCreateBuffer("params", params_abs)
            cl.clCreateBuffer("toks", toks_abs)
            cl.clCreateBuffer("pos", pos_abs)
            cl.clCreateBuffer("caches", caches_abs)
            cl.clCreateBuffer(f"pf_prompt_{P}", prompt_abs)
            cl.clCreateBuffer("pf_tok", pf_tok_abs)
            cl.clCreateBuffer(f"pf_cache_{P}", pf_cache_abs)
            cl.clEnqueueKernel("init_params", (), ("params",),
                               const_args=(self.seed,))
            cl.clEnqueueKernel("init_slots", (), ("toks", "pos", "caches"))
            cl.clFinish()

    # ------------------------------------------------------------------
    # Request intake
    # ------------------------------------------------------------------
    # -- tracked device-op helpers ---------------------------------------
    # Every device op in the serving loop goes through these so the step
    # can fold the monitor's per-request phase dicts (queue wait, device
    # run, transfer bytes) into the engine's host/device attribution.
    def _exec(self, *args, span=None, **kw):
        c = self.cl.clEnqueueKernel(*args, span=span, **kw)
        self._step_completions.append(c)
        return c

    def _write(self, buff_id, host_value, span=None):
        c = self.cl.write_buffer(buff_id, host_value, span=span)
        self._step_completions.append(c)
        return c

    def _read(self, buff_id, span=None):
        c = self.cl.clEnqueueMigrateMemObjects(buff_id, to_device=False,
                                               span=span)
        self._step_completions.append(c)
        try:
            return c.wait()
        except BaseException:
            # the completion stays in _step_completions for phase folding;
            # mark the error surfaced so the step-boundary sweep doesn't
            # raise it a second time
            c.error_seen = True
            raise

    def _read_async(self, buff_id, span=None):
        """d2h read whose wait is deferred to the commit site (pipelined
        decode) — tracked like every other completion."""
        c = self.cl.clEnqueueMigrateMemObjects(buff_id, to_device=False,
                                               span=span)
        self._step_completions.append(c)
        return c

    def submit(self, req: ServeRequest) -> None:
        if req.arrival_t is None:
            req.arrival_t = self._clock()
        if self.tracer is not None and req.trace is None:
            req.trace = self.tracer.start_trace("request", trace_id=req.rid,
                                                service=self.service)
        if req.trace is not None:
            req._eng_queue_span = req.trace.span("engine.queue",
                                                 engine=self.engine_id)
        self.pending.append(req)

    @property
    def idle(self) -> bool:
        return not self._active and not self.pending

    @property
    def active_count(self) -> int:
        return len(self._active)

    def _pick_bucket(self, prompt_len: int) -> int:
        for b in self.buckets:
            if prompt_len <= b:
                return b
        return self.buckets[-1]         # over-long prompts truncate

    def _pad_prompt(self, prompt: np.ndarray, bucket: int) -> np.ndarray:
        p = np.asarray(prompt, np.int32).reshape(-1)[:bucket]
        if p.shape[0] < bucket:
            p = np.pad(p, (0, bucket - p.shape[0]))
        return p.reshape(1, bucket)

    def kv_stats(self) -> dict:
        """Cache-memory occupancy in the shared byte accounting."""
        if not self.paged:
            return {"paged": False, "pool_bytes": self.pool_bytes,
                    "bytes_in_use": self.pool_bytes, "occupancy": 1.0}
        used = self.pool.used_count()
        return {"paged": True, "pool_bytes": self.pool_bytes,
                "page_bytes": self.page_bytes,
                "pages_used": used, "pages_free": self.pool.free_count(),
                "bytes_in_use": used * self.page_bytes,
                "occupancy": self.pool.occupancy(),
                "used_span": self.pool.used_span()}

    # ------------------------------------------------------------------
    # One iteration: admit into free lanes, decode all occupied lanes
    # ------------------------------------------------------------------
    def _admit(self) -> int:
        admitted = 0
        while self._free and self.pending:
            req = self.pending[0]
            bucket = self._pick_bucket(
                np.asarray(req.prompt).reshape(-1).shape[0])
            page_ids = None
            padded = None
            match = None
            if self.paged and self.prefix is not None:
                padded = self._pad_prompt(req.prompt, bucket)
                n_pp = self.pool.pages_for_tokens(bucket)
                match = self.prefix.match(bucket, padded.reshape(-1))
                if len(match.pages) == n_pp and match.next_token is None:
                    # every page matched but the continuation after the
                    # prompt is unknown (pages donated at retire without a
                    # following token) — recompute the last chunk so its
                    # argmax yields the first token
                    match.pages.pop()
                    match.tokens -= self.page_size
                need = n_pp - len(match.pages)
                if not self.pool.can_admit(need):
                    # admission pressure: reclaim cold cache (LRU
                    # subtrees) before refusing — the match just bumped
                    # its own pages' recency, so they are evicted last
                    short = (need + self.pool.reserve_pages
                             - self.pool.free_count())
                    if short > 0:
                        self.prefix.evict_pages(short)
                    if not self.pool.can_admit(need):
                        break
                    # eviction ran: re-match against the surviving tree
                    match = self.prefix.match(bucket, padded.reshape(-1))
                    if (len(match.pages) == n_pp
                            and match.next_token is None):
                        match.pages.pop()
                        match.tokens -= self.page_size
                    need = n_pp - len(match.pages)
                    if not self.pool.can_admit(need):
                        break
                new_ids = self.pool.alloc(need) if need else []
                if new_ids is None:
                    break
                self.pool.share(match.pages)    # this lane's references
                page_ids = list(match.pages) + [int(p) for p in new_ids]
            elif self.paged:
                n_pp = self.pool.pages_for_tokens(bucket)
                if not self.pool.can_admit(n_pp):
                    break               # memory-based admission gate
                page_ids = self.pool.alloc(n_pp)
                # the monolithic prefill scatters these pages whole — no
                # scrub needed, but they are no longer first-touch clean
                self._virgin_pages.difference_update(page_ids)
            self.pending.popleft()
            slot = heapq.heappop(self._free)
            qsp = getattr(req, "_eng_queue_span", None)
            if qsp is not None:
                qsp.end()
                req._eng_queue_span = None
            adm = (req.trace.span("engine.admit", engine=self.engine_id,
                                  slot=slot, bucket=bucket)
                   if req.trace is not None else None)
            admit_cs = []
            read_c = None
            first_tok = None
            deferred_insert = None
            if self.paged and self.prefix is not None:
                first_tok, read_c, deferred_insert = self._admit_prefix(
                    req, bucket, padded, match, page_ids, slot, adm)
            elif (self.paged and self.spec is None
                    and not self._legacy_admit):
                # one-EXECUTE admission: prompt rides as a const arg, the
                # program prefills, installs the lane and scatters the
                # prompt pages in a single FIFO op
                admit_cs.append(self._exec(
                    f"prefill_admit_{bucket}",
                    ("params", "toks", "pos", "kv_pool"),
                    ("pf_tok", "toks", "pos", "kv_pool"),
                    const_args=(self._pad_prompt(req.prompt, bucket),
                                np.int32(slot),
                                np.asarray(page_ids, np.int32)),
                    donate=True,
                    dirty_pages={"kv_pool": tuple(page_ids)}, span=adm))
                self._bt_set_row(slot, page_ids)
                if self._pipelined:
                    # host-out-of-the-loop admission: the first token's
                    # d2h read is deferred to the commit site — the host
                    # never stalls behind the prefill EXECUTE, which now
                    # overlaps this step's decode submit and commit work
                    read_c = self._read_async("pf_tok", span=adm)
                else:
                    first_tok = int(np.asarray(self._read("pf_tok",
                                                          span=adm))[0])
            else:
                admit_cs.append(self._write(
                    f"pf_prompt_{bucket}",
                    self._pad_prompt(req.prompt, bucket), span=adm))
                admit_cs.append(self._exec(
                    f"prefill_{bucket}",
                    ("params", f"pf_prompt_{bucket}"),
                    ("pf_tok", f"pf_cache_{bucket}"), span=adm))
                if self.paged:
                    admit_cs.append(self._exec(
                        f"admit_{bucket}",
                        ("toks", "pos", "kv_pool", "pf_tok",
                         f"pf_cache_{bucket}"),
                        ("toks", "pos", "kv_pool"),
                        const_args=(np.int32(slot),
                                    np.asarray(page_ids, np.int32)),
                        donate=True,
                        dirty_pages={"kv_pool": tuple(page_ids)}, span=adm))
                    self._bt_set_row(slot, page_ids)
                    if self.spec is not None:
                        self._exec(
                            f"draft_prefill_{bucket}",
                            ("draft_params", f"pf_prompt_{bucket}"),
                            (f"pf_draft_cache_{bucket}",), span=adm)
                        self._exec(
                            f"admit_draft_{bucket}",
                            ("draft_caches", f"pf_draft_cache_{bucket}"),
                            ("draft_caches",),
                            const_args=(np.int32(slot),), donate=True,
                            span=adm)
                else:
                    self._exec(
                        "admit_slot",
                        ("toks", "pos", "caches", "pf_tok",
                         f"pf_cache_{bucket}"),
                        ("toks", "pos", "caches"),
                        const_args=(np.int32(slot),), donate=True, span=adm)
                # staged path (spec / reserved): the host mirror needs the
                # first token synchronously
                first_tok = int(np.asarray(self._read("pf_tok",
                                                      span=adm))[0])
            if adm is not None:
                adm.end()
            if self.spec is not None:
                self._toks_host[slot, 0] = first_tok
                self._pos_host[slot] = bucket
            now = self._clock()
            st = _SlotState(req=req, slot=slot,
                            tokens=[] if read_c is not None
                            else [first_tok],
                            submitted=1,
                            admit_t=now, first_token_t=now,
                            last_token_t=now,
                            limit=max(1, min(req.max_new_tokens,
                                             self.max_new_tokens)),
                            bucket=bucket, pos=bucket,
                            blocks=list(page_ids) if page_ids else [],
                            span=(req.trace.span("engine.decode",
                                                 engine=self.engine_id,
                                                 slot=slot)
                                  if req.trace is not None else None))
            st.deferred_insert = deferred_insert
            req.committed = st.tokens   # alias: crash-replay bookkeeping
            self.registry.record_event("engine_admit", rid=req.rid,
                                       slot=slot, engine=self.engine_id)
            if (read_c is None and self.eos_id is not None
                    and first_tok == self.eos_id):
                st.limit = 1            # prompt's continuation IS the stop
            if read_c is not None:
                # deferred admission: the lane decodes in this step's
                # fused EXECUTE (its device state is set by the admit
                # EXECUTE ahead of it in the FIFO); only the first token's
                # *value* and the TTFT observation wait for the commit
                self._active[slot] = st
                self._inflight.append(("admit", st, read_c,
                                       tuple(admit_cs)))
                continue
            st.first_token_t = self._observe_first_token(req, now)
            self._c_tokens.inc()
            admitted += 1
            if len(st.tokens) >= st.limit:
                self._retire(st, now)       # degenerate 1-token request
            else:
                self._active[slot] = st
        return admitted

    def _observe_first_token(self, req, now: float) -> float:
        """TTFT bookkeeping at first-token delivery; returns the moment
        the client first saw a token for this rid (an OOM-preempted
        request recomputes, but keeps its original TTFT)."""
        if self.paged:
            prior = self._first_token.get(req.rid)
            if prior is not None:
                return prior
            self._first_token[req.rid] = now
        self._h_ttft.observe(now - req.arrival_t)
        return now

    def _admit_prefix(self, req, bucket, padded, match, page_ids, slot,
                      adm):
        """Admission over the prefix cache: map the matched pages, chunk-
        prefill only the uncovered suffix.  A full-prompt match skips
        device compute entirely — the tree's stored greedy continuation IS
        the first token, delivered host-side while the (tiny) lane-state
        update rides the queue.  Finally the prompt's pages are donated to
        the tree so same-prefix requests (including this request's own OOM
        recompute) hit.

        Returns ``(first_tok, read_c, deferred_insert)``: on a pipelined
        engine the suffix prefill rides the async pipeline like plain
        paged admits — ``first_tok`` is None, the deferred ``read_c``
        commits later, and the tree insert (which needs the first token)
        is parked on the lane until then.  Prompt buckets are page-aligned
        in prefix mode, so decode writes can never land in a prompt page
        before the deferred insert happens."""
        ps = self.page_size
        n_pp = len(page_ids)
        flat = padded.reshape(-1)
        n_hit = len(match.pages)
        full_hit = n_hit == n_pp and match.next_token is not None
        self._bt_set_row(slot, page_ids)
        self.prefix_prompt_tokens += bucket
        self.prefix_cached_tokens += bucket if full_hit else n_hit * ps
        if full_hit:
            self.prefix_hits += 1
            first_tok = int(match.next_token)
            self._write("pf_tok", np.asarray([first_tok], np.int32),
                        span=adm)
            if adm is not None:
                adm.annotate(prefix_hit="full", cached_pages=n_hit)
        else:
            self.prefix_partial_hits += 1 if n_hit else 0
            self.prefix_misses += 0 if n_hit else 1
            new_ids = page_ids[n_hit:]
            # §3.4 freed-memory zeroing: the chunk gather must see INVALID
            # positions in the fresh suffix pages, never a previous
            # owner's tokens (first-touch pages already read INVALID)
            scrub_new = self._scrub_needed(new_ids)
            if scrub_new:
                ids = np.full((self._scrub_width,), self.pool_pages,
                              np.int32)
                ids[:len(scrub_new)] = scrub_new
                self._exec("scrub", ("kv_pool",), ("kv_pool",),
                           const_args=(ids,), donate=True,
                           dirty_pages={"kv_pool": tuple(scrub_new)},
                           span=adm)
            row = self._bt_host[slot].copy()
            for c in range(n_hit, n_pp):
                self._exec(
                    "prefill_chunk", ("params", "kv_pool"),
                    ("pf_tok", "kv_pool"),
                    const_args=(flat[c * ps:(c + 1) * ps].astype(np.int32),
                                np.int32(c), row),
                    donate=True,
                    dirty_pages={"kv_pool": (int(page_ids[c]),)},
                    span=adm)
            first_tok = None
            if adm is not None:
                adm.annotate(prefix_hit="partial" if n_hit else "miss",
                             cached_pages=n_hit, chunks=n_pp - n_hit)
        self._exec("admit_tok", ("toks", "pos", "pf_tok"),
                   ("toks", "pos"),
                   const_args=(np.int32(slot), np.int32(bucket)),
                   donate=True, span=adm)
        if self.spec is not None:
            # the draft lane has no paging: its dense prefill always runs
            # in full (throughput only — draft state never changes tokens)
            self._write(f"pf_prompt_{bucket}", padded, span=adm)
            self._exec(f"draft_prefill_{bucket}",
                       ("draft_params", f"pf_prompt_{bucket}"),
                       (f"pf_draft_cache_{bucket}",), span=adm)
            self._exec(f"admit_draft_{bucket}",
                       ("draft_caches", f"pf_draft_cache_{bucket}"),
                       ("draft_caches",),
                       const_args=(np.int32(slot),), donate=True, span=adm)
        if first_tok is None and self._pipelined and n_hit:
            # prefix-HIT lanes ride the pipeline: the suffix prefill's
            # first-token read defers to the commit site and the tree
            # insert (which needs that token as the continuation hint) is
            # parked on the lane.  MISS lanes keep the synchronous read:
            # their insert seeds the tree, and a same-step sibling with
            # the same prompt must be able to full-match it — parking the
            # miss insert would cost that hit, and dropping the hint
            # would downgrade it to a re-derived partial.
            read_c = self._read_async("pf_tok", span=adm)
            return None, read_c, (bucket, flat.copy(), list(page_ids))
        if first_tok is None:
            first_tok = int(np.asarray(self._read("pf_tok", span=adm))[0])
        self.prefix.insert(bucket, flat, page_ids, first_tok)
        return first_tok, None, None

    def _retire(self, st: _SlotState, now: float) -> None:
        rec = CompletedRequest(
            rid=st.req.rid, tokens=st.tokens, arrival_t=st.req.arrival_t,
            admit_t=st.admit_t, first_token_t=st.first_token_t,
            finish_t=now, tbts=st.tbts)
        self.completed[st.req.rid] = rec
        self._unreported.append(rec)
        self._active.pop(st.slot, None)
        heapq.heappush(self._free, st.slot)
        if self.paged:
            if self.prefix is not None and st.blocks:
                # donate every fully *committed* page (prompt + generated)
                # to the tree before dropping the lane's references: a
                # later request sharing this sequence as its prompt prefix
                # maps the pages instead of recomputing them.  The page
                # holding positions >= pos is excluded — it may hold
                # rejected speculative writes past the commit horizon.
                ps = self.page_size
                flat = self._pad_prompt(st.req.prompt,
                                        st.bucket).reshape(-1)
                full = np.concatenate(
                    [flat, np.asarray(st.tokens, np.int32)])
                n_complete = min(st.pos // ps, len(st.blocks))
                if n_complete:
                    nxt = (int(full[n_complete * ps])
                           if n_complete * ps < len(full) else None)
                    self.prefix.insert(st.bucket,
                                       full[:n_complete * ps],
                                       st.blocks[:n_complete], nxt)
            # the lane's references return to the pool the moment the
            # request retires (pages the prefix cache pinned survive); the
            # cleared row deactivates the lane for the next decode gather
            self.pool.free(st.blocks)
            self._bt_clear_row(st.slot)
            self._first_token.pop(st.req.rid, None)
        self._h_e2e.observe(rec.e2e_s)
        self._c_completions.inc()
        if st.req.slo_s is not None and rec.e2e_s > st.req.slo_s:
            self._c_violations.inc()
        self.registry.record_event("engine_retire", rid=st.req.rid,
                                   slot=st.slot, tokens=len(st.tokens),
                                   engine=self.engine_id)
        if st.span is not None:
            st.span.annotate(tokens=len(st.tokens)).end()
        if st.req.trace is not None:
            st.req.trace.finish(tokens=len(st.tokens),
                                engine=self.engine_id)

    # -- paged-mode page lifecycle ---------------------------------------
    def _pick_victim(self) -> _SlotState:
        """Youngest admission loses (its recomputation is cheapest); the
        oldest lane always keeps making progress, so the engine never
        livelocks as long as the pool holds one worst-case request."""
        return max(self._active.values(), key=lambda s: (s.admit_t, s.slot))

    def _preempt(self, st: _SlotState) -> None:
        self.pool.free(st.blocks)
        self._bt_clear_row(st.slot)
        self._active.pop(st.slot)
        heapq.heappush(self._free, st.slot)
        self.pending.appendleft(st.req)     # deterministic recompute
        self.preemptions += 1
        self._c_preemptions.inc()
        self.registry.record_event("engine_oom_preempt", rid=st.req.rid,
                                   slot=st.slot, engine=self.engine_id)
        if st.span is not None:
            st.span.annotate(preempted=True,
                             tokens_discarded=len(st.tokens)).end()
        if st.req.trace is not None:
            # requeued whole: a fresh queue span covers the wait until the
            # deterministic re-admission
            st.req._eng_queue_span = st.req.trace.span(
                "engine.queue", engine=self.engine_id, requeued=True)

    def _scrub_needed(self, ids) -> List[int]:
        """Split freshly-allocated pages into the subset that needs the
        freed-memory zeroing EXECUTE: first-touch pages already read
        INVALID (init_paged), only pages a previous owner wrote must be
        scrubbed.  Removes ``ids`` from the virgin set either way."""
        need = [p for p in ids if p not in self._virgin_pages]
        self._virgin_pages.difference_update(ids)
        return need

    def _alloc_urgent(self) -> Optional[List[int]]:
        """One-page urgent allocation; when the pool is dry, cold prefix
        cache is reclaimed before the caller escalates to preemption —
        dropping cached pages never costs a running request its work."""
        got = self.pool.alloc(1, urgent=True)
        if got is None and self.prefix is not None \
                and self.prefix.evict_pages(1):
            got = self.pool.alloc(1, urgent=True)
        return got

    def _cow_pages(self, st: _SlotState, lp_first: int,
                   lp_last: int) -> bool:
        """Privatize shared pages in the lane's write window [lp_first,
        lp_last]: allocate a fresh page, copy the shared page's bytes
        on-device (the copy is reported newly dirty so evict/checkpoint
        stays crash-consistent), swap the block-table entry, and drop this
        lane's shared reference.  Returns False if the lane preempted
        itself acquiring the copy."""
        for lp in range(lp_first, min(lp_last + 1, len(st.blocks))):
            old = st.blocks[lp]
            if self.pool.refcount(old) <= 1:
                continue
            got = self._alloc_urgent()
            while got is None:
                victim = self._pick_victim()
                self._preempt(victim)
                if victim is st:
                    return False
                got = self._alloc_urgent()
            new = got[0]
            self._virgin_pages.discard(new)     # copied into whole
            src = np.full((self.pool_pages,), self.pool_pages, np.int32)
            dst = np.full((self.pool_pages,), self.pool_pages, np.int32)
            src[0], dst[0] = old, new
            self._exec("compact_pool", ("kv_pool",), ("kv_pool",),
                       const_args=(src, dst), donate=True,
                       dirty_pages={"kv_pool": (new,)},
                       span=self._it_root)
            self.pool.free([old])       # drop this lane's shared reference
            st.blocks[lp] = new
            self._bt_set_cell(st.slot, lp, new)
            self.cow_copies += 1
            self.registry.record_event("engine_cow", rid=st.req.rid,
                                       slot=st.slot, page_from=old,
                                       page_to=new, engine=self.engine_id)
        return True

    def _append_pages(self) -> None:
        """Token-granularity growth: map the page(s) each lane's next write
        window lands in — one page for plain decode, up to the ``k+1``-token
        lookahead span for speculative decode (capped at the tokens the lane
        can still commit) — preempting the youngest lane(s) when the pool
        runs dry.  A lane preempted here mid-lookahead is requeued whole and
        recomputes deterministically."""
        scrub_ids: List[int] = []
        for slot in sorted(self._active):
            st = self._active.get(slot)
            if st is None:
                continue                # preempted by an earlier append
            if self.spec is not None:
                span_tok = min(self.spec_k_now + 1,
                               st.limit - len(st.tokens))
            elif self.fuse_steps > 1 or self.async_depth > 0:
                # fused decode: pre-map the whole k-step span (same
                # lookahead-span mapping as speculative decode)
                span_tok = min(self.fuse_steps, st.limit - st.submitted)
                if span_tok <= 0:
                    continue    # fully submitted: awaiting pipeline commit
            else:
                span_tok = 1
            lp_first = st.pos // self.page_size
            lp_last = (st.pos + span_tok - 1) // self.page_size
            # copy-on-write guard: a mapped page inside the imminent write
            # window that is still shared (prefix cache / another lane)
            # gets a private copy before any write can land in it
            if self.prefix is not None and not self._cow_pages(
                    st, lp_first, lp_last):
                continue                # st preempted itself during COW
            dead = False
            for lp in range(len(st.blocks), lp_last + 1):
                got = self._alloc_urgent()
                while got is None:
                    victim = self._pick_victim()
                    self._preempt(victim)
                    if victim is st:
                        dead = True     # st preempted itself: all freed
                        break
                    got = self._alloc_urgent()
                if dead:
                    break
                assert lp == len(st.blocks), (lp, st.blocks)
                st.blocks.append(got[0])
                self._bt_set_cell(slot, lp, got[0])
                scrub_ids.append(got[0])
        scrub_ids = self._scrub_needed(scrub_ids)
        if scrub_ids:
            assert len(scrub_ids) <= self._scrub_width
            ids = np.full((self._scrub_width,), self.pool_pages, np.int32)
            ids[:len(scrub_ids)] = scrub_ids
            self._exec(
                "scrub", ("kv_pool",), ("kv_pool",), const_args=(ids,),
                donate=True, dirty_pages={"kv_pool": tuple(scrub_ids)},
                span=self._it_root)

    def compact(self) -> dict:
        """Defragment the pool: pack used pages into the lowest physical
        ids (shrinks the evict-time dirty-page span after churn).  Call
        between iterations only."""
        if not self.paged:
            return {"moved": 0}
        if self._mid_step:
            raise RuntimeError(
                "compact() while pages are in flight: an iteration's "
                "EXECUTEs reference physical page ids — compaction is only "
                "legal between engine iterations")
        if self._inflight:
            # commit every pipelined batch first: their EXECUTEs were
            # submitted against pre-compaction physical page ids
            self._drain_pipeline()
        mapping = self.pool.compact()
        if mapping:
            # move targets receive a whole page's bytes; move sources
            # keep their stale content and were never virgin anyway
            self._virgin_pages.difference_update(mapping.values())
            src = np.full((self.pool_pages,), self.pool_pages, np.int32)
            dst = np.full((self.pool_pages,), self.pool_pages, np.int32)
            src[:len(mapping)] = list(mapping.keys())
            dst[:len(mapping)] = list(mapping.values())
            self._exec(
                "compact_pool", ("kv_pool",), ("kv_pool",),
                const_args=(src, dst), donate=True,
                dirty_pages={"kv_pool": tuple(mapping.values())},
                span=self._it_root)
            for st in self._active.values():
                st.blocks = [mapping.get(p, p) for p in st.blocks]
                self._bt_host[st.slot, :len(st.blocks)] = st.blocks
            if self.prefix is not None:
                # share-aware compaction: every owner of a moved page is
                # remapped from the same mapping — lanes above, tree here
                self.prefix.remap(mapping)
            self._bt_mark_full()
        return {"moved": len(mapping), "span": self.pool.used_span()}

    def _should_auto_compact(self) -> bool:
        if self.auto_compact_frag is None:
            return False
        used, span = self.pool.used_count(), self.pool.used_span()
        if used == 0 or span - used < self.auto_compact_min_pages:
            return False
        return 1.0 - used / span >= self.auto_compact_frag

    def _maybe_auto_compact(self) -> None:
        """Threshold-triggered defragmentation, fired at the top of an
        iteration — the only point where no EXECUTE holds page ids."""
        if not self._should_auto_compact():
            return
        used, span = self.pool.used_count(), self.pool.used_span()
        self.compact()
        self.auto_compactions += 1
        self.registry.record_event("engine_auto_compact",
                                   engine=self.engine_id, used=used,
                                   span_before=span)

    # -- device-resident block table -------------------------------------
    def _bt_set_row(self, slot: int, page_ids) -> None:
        self._bt_host[slot, :] = -1
        self._bt_host[slot, :len(page_ids)] = page_ids
        self._bt_delta.append((slot, -1, -1))
        self._bt_delta.extend(
            (slot, lp, int(p)) for lp, p in enumerate(page_ids))
        self._bt_dirty = True

    def _bt_clear_row(self, slot: int) -> None:
        self._bt_host[slot, :] = -1
        self._bt_delta.append((slot, -1, -1))
        self._bt_dirty = True

    def _bt_set_cell(self, slot: int, lp: int, phys: int) -> None:
        self._bt_host[slot, lp] = phys
        self._bt_delta.append((slot, lp, int(phys)))
        self._bt_dirty = True

    def _bt_mark_full(self) -> None:
        """Bulk rewrites (compact/evacuate/restore) skip the delta path."""
        self._bt_full = True
        self._bt_delta.clear()
        self._bt_dirty = True

    def _bt_take_delta(self) -> np.ndarray:
        """Claim pending block-table rows for in-program application by
        the fused decode EXECUTE — in the steady state the delta rides
        an EXECUTE the iteration issues anyway, costing zero extra FIFO
        ops.  Forced rewrites (compact/restore) and overflowing deltas
        still take the full h2d write here; the returned delta is then
        all-sentinel, a no-op for ``apply_block_table_delta``."""
        if self._bt_dirty and (self._bt_full or
                               len(self._bt_delta) > self._bt_delta_width):
            self._flush_block_table()
        delta = np.full((self._bt_delta_width, 3), -1, np.int32)
        if self._bt_delta:
            delta[:len(self._bt_delta)] = self._bt_delta
            self._bt_delta.clear()
            self.bt_delta_execs += 1
        self._bt_dirty = False
        self._bt_full = False
        return delta

    def _flush_block_table(self) -> None:
        """Ship pending block-table changes to the device: a small
        bt_update EXECUTE applying the accumulated delta rows in the
        steady state, a full h2d rewrite when one was forced (or the
        delta outgrew its fixed-width buffer)."""
        if not self._bt_dirty:
            return
        if self._bt_full or len(self._bt_delta) > self._bt_delta_width:
            self._write("block_table", self._bt_host.copy(),
                        span=self._it_root)
            self.bt_full_writes += 1
        else:
            delta = np.full((self._bt_delta_width, 3), -1, np.int32)
            if self._bt_delta:
                delta[:len(self._bt_delta)] = self._bt_delta
            self._exec("bt_update", ("block_table",), ("block_table",),
                       const_args=(delta,), donate=True,
                       span=self._it_root)
            self.bt_delta_execs += 1
        self._bt_full = False
        self._bt_delta.clear()
        self._bt_dirty = False

    def _commit_tokens(self, st: _SlotState, tokens, now: float, *,
                       advance: bool = True) -> int:
        """Append committed tokens to a lane; the first token carries the
        inter-token gap, the rest arrived in the same burst (TBT 0).
        Retirement stays at the call site — the speculative path must roll
        back the page tail first.  ``advance=False`` (pipelined decode)
        skips the position/submitted bump: it already happened at submit
        time, when the token count was determined."""
        for i, t in enumerate(tokens):
            st.tokens.append(int(t))
            tbt = (now - st.last_token_t) if i == 0 else 0.0
            st.tbts.append(tbt)
            self._h_tbt.observe(tbt)
        st.last_token_t = now
        if advance:
            st.pos += len(tokens)
            st.submitted = len(st.tokens)
        return len(tokens)

    # -- host-out-of-the-loop decode: fused multi-step + async pipeline --
    def _fused_iteration(self) -> int:
        """Submit one fused EXECUTE covering up to ``fuse_steps`` greedy
        tokens per lane, then commit the oldest in-flight batch(es).

        With ``async_depth > 0`` the submit goes to the monitor's FIFO
        queue *before* the previous iteration's tokens are read back, so
        host commit work overlaps device execution.  Token counts are
        deterministic at submit time (greedy sampling; the only early
        exit is the per-lane limit), so positions, ``submitted`` counters
        and page mapping advance at submit — only the token *values*
        arrive at commit."""
        kf, ps = self.fuse_steps, self.page_size
        # lanes finished by an earlier commit but kept active while later
        # in-flight EXECUTEs still referenced their pages (EOS mid-span,
        # or a dropped pipeline) retire here once the references drained
        for slot in sorted(self._active):
            st = self._active[slot]
            if (st.tokens and len(st.tokens) >= st.limit
                    and st.inflight == 0):
                self._retire(st, self._clock())
        entries: List[Tuple[_SlotState, int]] = []
        lims = np.zeros((self.slots,), np.int32)
        for slot in sorted(self._active):
            st = self._active[slot]
            n = min(kf, st.limit - st.submitted)
            if n > 0:
                entries.append((st, n))
                lims[slot] = n
        decoded = 0
        if entries:
            if self._resync_lanes:
                # a dropped pipeline left the device's toks/pos scalars
                # ahead of the host's rolled-back commit horizon — rewrite
                # them from the host-authoritative lane state (KV pages
                # need no repair: greedy decode rewrites the same values
                # at the same positions on resubmit).  Deferred admissions
                # from this step must commit first so every active lane
                # has a host-known last token to resync from.
                while self._inflight and self._inflight[0][0] == "admit":
                    decoded += self._commit_fused()
                toks_h = np.zeros((self.slots, 1), np.int32)
                pos_h = np.zeros((self.slots,), np.int32)
                for slot, st in self._active.items():
                    toks_h[slot, 0] = st.tokens[-1]
                    pos_h[slot] = st.pos
                self._write("toks", toks_h, span=self._it_root)
                self._write("pos", pos_h, span=self._it_root)
                self._resync_lanes = False
            delta = self._bt_take_delta() if kf > 1 else None
            if kf == 1:
                self._flush_block_table()
            # every active lane's write window is dirty — masked steps
            # past a lane's limit still write its mapped tail page
            dirty = set()
            for st in self._active.values():
                for lp in range(st.pos // ps,
                                min((st.pos + kf - 1) // ps,
                                    self.max_blocks - 1) + 1):
                    pid = int(self._bt_host[st.slot, lp])
                    if pid >= 0:
                        dirty.add(pid)
            if kf > 1:
                exec_c = self._exec(
                    "decode_multi",
                    ("params", "toks", "pos", "block_table", "kv_pool"),
                    ("fused_toks", "toks", "pos", "block_table", "kv_pool"),
                    donate=True,
                    const_args=(lims, delta),
                    dirty_pages={"kv_pool": tuple(sorted(dirty))},
                    span=self._it_root)
                read_c = self._read_async("fused_toks", span=self._it_root)
            else:
                exec_c = self._exec(
                    "decode_step",
                    ("params", "toks", "pos", "block_table", "kv_pool"),
                    ("toks", "pos", "kv_pool"), donate=True,
                    dirty_pages={"kv_pool": tuple(sorted(dirty))},
                    span=self._it_root)
                read_c = self._read_async("toks", span=self._it_root)
            for st, n in entries:
                st.submitted += n
                st.pos += n
                st.inflight += 1
            self._inflight.append(("batch", exec_c, read_c, entries))
        # only decode batches count against the pipeline depth: a deferred
        # admission commits when it reaches the head naturally — popping it
        # in its own step would stall the host on the prefill EXECUTE it
        # just enqueued, re-serializing exactly what the deferral hides
        if entries:
            while sum(1 for r in self._inflight
                      if r[0] == "batch") > self.async_depth:
                decoded += self._commit_fused()
        else:
            decoded += self._drain_pipeline()
        return decoded

    def _commit_fused(self) -> int:
        """Read back and commit the oldest in-flight record — a fused
        decode batch or a deferred admission.  A failed EXECUTE drops the
        whole pipeline and rolls the submit-time advance back: the
        monitor raises *before* any output buffer is written, so the
        failed span's device state is untouched and the next iteration
        resubmits it — bit-exact, since greedy decode recomputes the
        same tokens."""
        rec = self._inflight.popleft()
        kind, read_c = rec[0], rec[2]
        err = None
        try:
            val = np.asarray(read_c.wait())
        except BaseException as e:  # noqa: BLE001 - surfaced below
            read_c.error_seen = True
            err = e
        if err is None:
            # FIFO: the read completing proves every EXECUTE ahead of it
            # was processed — surface their failures instead of committing
            # stale bytes (a failed prefill leaves pf_tok untouched, and
            # the read of those stale bytes itself succeeds)
            for c in ((rec[1],) if kind == "batch" else rec[3]):
                if c.error is not None:
                    c.error_seen = True
                    err = c.error
                    break
        if err is not None:
            self._fail_pipeline([rec] + list(self._inflight))
            raise err
        now = self._clock()
        if kind == "admit":
            st = rec[1]
            if self._active.get(st.slot) is not st:
                return 0    # preempted since submit: recompute replays it
            tok = int(val[0])
            st.first_token_t = self._observe_first_token(st.req, now)
            st.tokens.append(tok)
            st.last_token_t = now
            self._c_tokens.inc()
            if st.deferred_insert is not None:
                # prefix insert parked at admission: the tree needs the
                # first token, which only just arrived
                b, flat, ids = st.deferred_insert
                self.prefix.insert(b, flat, ids, tok)
                st.deferred_insert = None
            if self.eos_id is not None and tok == self.eos_id:
                self._mark_eos(st)
            if len(st.tokens) >= st.limit and st.inflight == 0:
                self._retire(st, now)   # degenerate 1-token request
            return 1
        decoded = 0
        for st, n in rec[3]:
            if self._active.get(st.slot) is not st:
                continue    # preempted since submit: recompute replays it
            st.inflight -= 1
            if st.eos_done:
                # the device lane was frozen for this whole span: nothing
                # to commit, and pos/submitted were restored at EOS time
                if len(st.tokens) >= st.limit and st.inflight == 0:
                    self._retire(st, now)
                continue
            toks = np.asarray(val[st.slot, :n])
            if self.eos_id is not None:
                hit = np.nonzero(toks == self.eos_id)[0]
                if hit.size:
                    toks = toks[:int(hit[0]) + 1]
            decoded += self._commit_tokens(st, toks, now, advance=False)
            if (self.eos_id is not None and st.tokens
                    and st.tokens[-1] == self.eos_id):
                self._mark_eos(st)
            if len(st.tokens) >= st.limit and st.inflight == 0:
                self._retire(st, now)
        self._c_tokens.inc(decoded)
        return decoded

    def _mark_eos(self, st: _SlotState) -> None:
        """The lane's newest committed token is the stop token.  Clamp the
        limit so the lane retires, and restore the authoritative position
        invariant ``pos == bucket + len(tokens) - 1``: any submit-time
        advance still riding later in-flight spans is undone here, since
        the device lane froze at EOS (fused path) or retires before its
        slot is reused (single-step path, whose over-runs only ever write
        positions past the commit horizon)."""
        st.eos_done = True
        st.limit = len(st.tokens)
        st.submitted = len(st.tokens)
        if self.paged:
            st.pos = st.bucket + len(st.tokens) - 1

    def _fail_pipeline(self, records) -> None:
        """Drop every in-flight record after a failed EXECUTE: later
        pipelined EXECUTEs ran against the pre-failure state, so their
        results belong to the *failed* span.  Batch records roll their
        submit-time advances back; deferred admissions un-admit — the
        request is requeued whole and replays deterministically."""
        self._inflight.clear()
        # reversed so appendleft restores the admissions' arrival order
        for rec in reversed(records):
            if rec[0] == "admit":
                st = rec[1]
                if self._active.get(st.slot) is not st:
                    continue
                self.pool.free(st.blocks)
                self._bt_clear_row(st.slot)
                self._active.pop(st.slot)
                heapq.heappush(self._free, st.slot)
                self.pending.appendleft(st.req)
                self.registry.record_event("engine_unadmit",
                                           rid=st.req.rid, slot=st.slot,
                                           engine=self.engine_id)
                if st.span is not None:
                    st.span.annotate(unadmitted=True).end()
                if st.req.trace is not None:
                    st.req._eng_queue_span = st.req.trace.span(
                        "engine.queue", engine=self.engine_id,
                        requeued=True)
            else:
                for st, n in rec[3]:
                    if self._active.get(st.slot) is st:
                        st.inflight -= 1
                        if not st.eos_done:
                            # an EOS'd lane's pos/submitted were already
                            # restored to the authoritative values
                            st.submitted -= n
                            st.pos -= n
        self._resync_lanes = True
        # a failed fused EXECUTE never applied the delta rows it carried:
        # the device block table may be behind the host mirror, so the
        # next iteration rewrites it whole (host-authoritative)
        self._bt_mark_full()

    def _drain_pipeline(self) -> int:
        """Commit every in-flight batch (compaction / explicit flush)."""
        decoded = 0
        while self._inflight:
            decoded += self._commit_fused()
        return decoded

    # -- one speculative iteration: draft k, verify k+1, commit/rollback -
    def _spec_iteration(self) -> int:
        k, ps = self.spec_k_now, self.page_size
        self._flush_block_table()
        # host-authoritative lane state (acceptance is decided here)
        self._write("toks", self._toks_host.copy(), span=self._it_root)
        self._write("pos", self._pos_host.copy(), span=self._it_root)
        self._exec(
            f"draft_lookahead_k{k}",
            ("draft_params", "toks", "pos", "draft_caches"),
            (f"draft_toks_k{k}", "draft_caches"), donate=True,
            span=self._it_root)
        # every page the verify can write is dirty — including pages whose
        # acceptance is later partial; evict must serialize them whole
        dirty = set()
        for st in self._active.values():
            for lp in range(st.pos // ps,
                            min((st.pos + k) // ps, self.max_blocks - 1) + 1):
                pid = int(self._bt_host[st.slot, lp])
                if pid >= 0:
                    dirty.add(pid)
        self._exec(
            f"verify_step_k{k}",
            ("params", "toks", f"draft_toks_k{k}", "pos", "block_table",
             "kv_pool"),
            (f"verify_toks_k{k}", "kv_pool"), donate=True,
            dirty_pages={"kv_pool": tuple(sorted(dirty))},
            span=self._it_root)
        # token delivery doubles as the iteration's sync point
        target = np.asarray(self._read(f"verify_toks_k{k}",
                                       span=self._it_root))
        drafts = np.asarray(self._read(f"draft_toks_k{k}",
                                       span=self._it_root))
        now = self._clock()
        decoded = 0
        self.spec_iterations += 1
        for st in list(self._active.values()):
            remaining = st.limit - len(st.tokens)
            g, d = target[st.slot], drafts[st.slot]
            m = 0
            while m < k and int(d[m]) == int(g[m]):
                m += 1
            ncommit = min(m + 1, remaining)
            offered = min(k, remaining - 1)
            self.spec_offered_drafts += offered
            self.spec_accepted_drafts += min(m, offered)
            self._adapt_offered += offered
            self._adapt_accepted += min(m, offered)
            self.spec_lane_iterations += 1
            self.spec_committed += ncommit
            self._commit_tokens(st, g[:ncommit], now)
            self._toks_host[st.slot, 0] = st.tokens[-1]
            self._pos_host[st.slot] = st.pos
            decoded += ncommit
            # rollback: free the orphaned lookahead tail — pages wholly
            # past the last committed entry (the kept tail page may still
            # hold rejected writes; causal masking hides them until the
            # lane overwrites them in order)
            keep = (st.pos + ps - 1) // ps
            if len(st.blocks) > keep:
                freed = self.pool.free_tail(st.blocks, keep)
                for lp in range(keep, len(st.blocks)):
                    self._bt_set_cell(st.slot, lp, -1)
                del st.blocks[keep:]
                self.registry.record_event(
                    "engine_spec_rollback", rid=st.req.rid, slot=st.slot,
                    freed=len(freed), engine=self.engine_id)
            if len(st.tokens) >= st.limit:
                self._retire(st, now)
        self._c_tokens.inc(decoded)
        if self._publish_gauges and self.spec_offered_drafts:
            self._g_spec.set(self.spec_accepted_drafts
                             / self.spec_offered_drafts)
        self._adapt_spec_k()
        return decoded

    def _adapt_spec_k(self) -> None:
        """Dynamic lookahead: every ``adapt_window`` offered drafts, read
        the window's acceptance (the delta the ``spec_accept_rate`` gauge
        moved by) and resize the live ``k`` — shrink one step below
        ``shrink_below`` so rejected verify work stops burning iterations,
        regrow one step after two consecutive windows at/above
        ``grow_above``.  Only throughput changes; committed tokens are
        bit-exact at every depth."""
        spec = self.spec
        if spec is None or not spec.dynamic_k:
            return
        if self._adapt_offered < spec.adapt_window:
            return
        rate = self._adapt_accepted / self._adapt_offered
        self._adapt_offered = self._adapt_accepted = 0
        prev = self.spec_k_now
        if rate < spec.shrink_below:
            self._grow_streak = 0
            self.spec_k_now = max(spec.k_min, self.spec_k_now - 1)
        elif rate >= spec.grow_above:
            self._grow_streak += 1
            if self._grow_streak >= 2:
                self._grow_streak = 0
                self.spec_k_now = min(self.spec_k, self.spec_k_now + 1)
        else:
            self._grow_streak = 0
        if self.spec_k_now != prev:
            if self._publish_gauges:
                self._g_spec_k.set(self.spec_k_now)
            self.registry.record_event(
                "engine_spec_k_adapt", engine=self.engine_id,
                k_from=prev, k_to=self.spec_k_now, window_rate=rate)

    def spec_stats(self) -> dict:
        """Speculation throughput accounting (zeros when spec is off)."""
        lane_iters = max(self.spec_lane_iterations, 1)
        offered = max(self.spec_offered_drafts, 1)
        return {
            "k": self.spec_k,
            "k_now": self.spec_k_now,
            "iterations": self.spec_iterations,
            "lane_iterations": self.spec_lane_iterations,
            "committed_tokens": self.spec_committed,
            "tokens_per_lane_iteration": self.spec_committed / lane_iters,
            "accept_rate": self.spec_accepted_drafts / offered,
        }

    def prefix_stats(self) -> dict:
        """Prefix-cache effectiveness (zeros when the cache is off)."""
        out = {"hits": self.prefix_hits,
               "partial_hits": self.prefix_partial_hits,
               "misses": self.prefix_misses,
               "prompt_tokens": self.prefix_prompt_tokens,
               "cached_tokens": self.prefix_cached_tokens,
               "hit_rate": (self.prefix_cached_tokens
                            / max(self.prefix_prompt_tokens, 1)),
               "cow_copies": self.cow_copies}
        if self.prefix is not None:
            out.update(self.prefix.stats())
        return out

    def prefix_match_len(self, prompt) -> int:
        """Router probe: how many of this prompt's (padded) tokens the
        engine's tree would serve from cache.  Read-only and lock-guarded,
        so any router thread may call it against any replica."""
        if self.prefix is None:
            return 0
        bucket = self._pick_bucket(
            np.asarray(prompt).reshape(-1).shape[0])
        padded = self._pad_prompt(prompt, bucket).reshape(-1)
        return self.prefix.match_len(bucket, padded)

    # -- one iteration ---------------------------------------------------
    def step(self) -> dict:
        """One engine iteration; returns counts for the caller's pacing.

        On an unexpected exception the flight recorder is dumped to a JSON
        file (``funky_flight_<engine>.json`` in the temp dir) before the
        error propagates — the event ring is the post-mortem."""
        if not self._setup_done:
            raise RuntimeError("engine.setup() has not run")
        try:
            return self._step_inner()
        except BaseException as e:  # noqa: BLE001 - dump, then re-raise
            try:
                path = os.path.join(
                    tempfile.gettempdir(),
                    f"funky_flight_{self.engine_id}.json")
                self.registry.flight_record_to_file(
                    path, engine=self.engine_id, error=repr(e),
                    iteration=self.iterations)
            except Exception:  # noqa: BLE001 - never mask the original
                pass
            raise

    def _step_inner(self) -> dict:
        t_step0 = time.perf_counter()
        it_tr = None
        if self.tracer is not None and (self._active or self.pending):
            it_tr = self.tracer.start_trace(
                "engine.step", trace_id=f"{self.engine_id}:it"
                f"{self.iterations}", engine=self.engine_id)
            self._it_root = it_tr.root
        preempts0 = self.preemptions
        compacts0 = self.auto_compactions
        decoded = 0
        if self.paged:
            if self._inflight and self._should_auto_compact():
                # compaction remaps physical pages; commit the pipelined
                # batches first (their EXECUTEs were submitted against the
                # pre-move ids)
                decoded += self._drain_pipeline()
            self._maybe_auto_compact()
        self._mid_step = True
        try:
            admitted = self._admit()
            self.peak_active = max(self.peak_active, len(self._active))
            if self._active and self.paged:
                self._append_pages()
            if self._active and self.spec is not None:
                decoded += self._spec_iteration()
            elif self.paged and (self.fuse_steps > 1
                                 or self.async_depth > 0):
                if self._active or self._inflight:
                    decoded += self._fused_iteration()
            elif self._active:
                if self.paged:
                    self._flush_block_table()
                    dirty = sorted({int(self._bt_host[
                        s.slot, s.pos // self.page_size])
                        for s in self._active.values()})
                    self._exec(
                        "decode_step",
                        ("params", "toks", "pos", "block_table", "kv_pool"),
                        ("toks", "pos", "kv_pool"), donate=True,
                        dirty_pages={"kv_pool": tuple(dirty)},
                        span=self._it_root)
                else:
                    self._exec(
                        "decode_step", ("params", "toks", "pos", "caches"),
                        ("toks", "pos", "caches"), donate=True,
                        span=self._it_root)
                # token delivery doubles as the iteration's sync point —
                # the d2h TRANSFER drains the queue, landing on a token
                # boundary
                toks = np.asarray(self._read("toks", span=self._it_root))
                now = self._clock()
                for st in list(self._active.values()):
                    decoded += self._commit_tokens(
                        st, toks[st.slot], now)
                    if (self.eos_id is not None and st.tokens
                            and st.tokens[-1] == self.eos_id):
                        self._mark_eos(st)
                    if len(st.tokens) >= st.limit:
                        self._retire(st, now)
                self._c_tokens.inc(decoded)
        finally:
            self._mid_step = False
        self.iterations += 1
        self._c_iters.inc()
        # -- host/device attribution: wall minus the monitor-measured
        #    device phases is host overhead (batching, commit, paging)
        wall = time.perf_counter() - t_step0
        device_s = queue_wait_s = 0.0
        execs = 0
        carry: List = []
        for c in self._step_completions:
            if not c.done:
                # a pipelined EXECUTE (or a prefix-hit admit's lane write)
                # may still be in flight at this boundary: carry it to the
                # next step so a late failure — and its phase attribution —
                # surfaces exactly once instead of being dropped
                carry.append(c)
                continue
            # async EXECUTEs may only ever be awaited via a later read's
            # FIFO sync — surface their failures here instead of silently
            # committing stale tokens.  error_seen marks completions whose
            # failure already raised at a wait()/commit site.
            if c.error is not None:
                if c.error_seen:
                    continue
                c.error_seen = True
                raise c.error
            ph = c.phases or {}
            device_s += ph.get("device_s", 0.0)
            queue_wait_s += ph.get("queue_wait_s", 0.0)
            if ph.get("kind") == "EXECUTE":
                execs += 1
        tokens = decoded + admitted       # each admit emits a first token
        if tokens:
            self._attr_host_s += max(0.0, wall - device_s)
            self._attr_device_s += device_s
            self._attr_queue_wait_s += queue_wait_s
            self._attr_tokens += tokens
            self._attr_execs += execs
            # queue-wait denominator: EXECUTE completions only — counting
            # writes/reads/syncs inflated the denominator and diluted the
            # queue_wait_us gauge
            self._attr_reqs += execs
            if self._publish_gauges:
                self._g_host_us.set(
                    self._attr_host_s / self._attr_tokens * 1e6)
                self._g_device_us.set(
                    self._attr_device_s / self._attr_tokens * 1e6)
                self._g_queue_wait_us.set(
                    self._attr_queue_wait_s
                    / max(self._attr_reqs, 1) * 1e6)
        self._step_completions = carry
        if it_tr is not None:
            it_tr.finish(admitted=admitted, decoded=decoded,
                         active=len(self._active),
                         preemptions=self.preemptions - preempts0,
                         auto_compactions=(self.auto_compactions
                                           - compacts0),
                         device_s=device_s)
            self._it_root = None
        if self._publish_gauges:
            self._g_queue.set(len(self.pending))
            self._g_util.set(len(self._active) / self.slots)
            if self.paged:
                self._g_kv.set(self.pool.occupancy())
                if self.prefix is not None:
                    # tree-only pages are one eviction away from free:
                    # advertising them keeps KV-aware routing from
                    # penalizing a warm cache as memory pressure
                    self._g_kv_free.set(self.pool.free_count()
                                        + self.prefix.reclaimable_pages())
                    if self.prefix_prompt_tokens:
                        self._g_prefix.set(self.prefix_cached_tokens
                                           / self.prefix_prompt_tokens)
                else:
                    self._g_kv_free.set(self.pool.free_count())
        return {"admitted": admitted, "decoded": decoded,
                "active": len(self._active), "pending": len(self.pending)}

    def host_device_split(self) -> dict:
        """Cumulative host-vs-device attribution for the serving loop —
        the baseline the host-out-of-the-loop decode tentpole is measured
        against.  All times come from the monitor's per-request phase
        dicts, so the split is available with tracing off."""
        toks = max(self._attr_tokens, 1)
        return {"tokens": self._attr_tokens,
                "execs": self._attr_execs,
                "host_us_per_token": self._attr_host_s / toks * 1e6,
                "device_us_per_token": self._attr_device_s / toks * 1e6,
                "queue_wait_us_mean": (self._attr_queue_wait_s
                                       / max(self._attr_reqs, 1) * 1e6),
                "host_s_total": self._attr_host_s,
                "device_s_total": self._attr_device_s}

    def drain_completions(self) -> List[CompletedRequest]:
        out = list(self._unreported)
        self._unreported.clear()
        return out

    def evacuate(self) -> List[ServeRequest]:
        """Hand back every un-finished request (kill / drain path) and
        reset the lanes.  Finished-but-unreported completions stay
        available via ``drain_completions`` — report those first so the
        caller's in-flight accounting stays exact."""
        reqs = ([st.req for st in self._active.values()]
                + list(self.pending))
        for st in self._active.values():
            if st.span is not None:
                st.span.annotate(evacuated=True).end()
        for req in reqs:
            qsp = getattr(req, "_eng_queue_span", None)
            if qsp is not None:
                qsp.annotate(evacuated=True).end()
                req._eng_queue_span = None
            if req.trace is not None:
                # keep a handle for the router to span-link the
                # post-requeue trace back to this one (recovery timeline)
                req._prev_trace = req.trace
                req.trace.finish(evacuated=True, engine=self.engine_id)
                req.trace = None        # re-traced on resubmission
        self._active.clear()
        self.pending.clear()
        # in-flight pipelined tokens die with the lanes: the requests are
        # requeued whole and recompute deterministically elsewhere
        self._inflight.clear()
        self._resync_lanes = False
        self._free = list(range(self.slots))
        heapq.heapify(self._free)
        if self.paged:
            self.pool = BlockPool(self.pool_pages, self.page_size,
                                  reserve_pages=self.pool.reserve_pages)
            # the device pool keeps the dead lanes' bytes: nothing is
            # first-touch clean for whoever reuses this engine
            self._virgin_pages = set()
            if self.prefix is not None:
                # the old pool (and every tree reference into it) dies
                # with the evacuation; the index restarts cold
                self.prefix = PrefixCache(
                    self.pool, self.page_size,
                    max_nodes=self._prefix_max_nodes)
            self._bt_host[:] = -1
            self._bt_mark_full()
            self._first_token.clear()
            if self.spec is not None:
                self._toks_host[:] = 0
                self._pos_host[:] = 0
            if self._publish_gauges:
                # a killed replica must not pin the service-level pressure
                # signal at its last (hot) value — the aggregator keeps
                # gauges of dead engines forever.  kv_free advertises 0
                # (not the fresh pool's capacity): a dead engine must never
                # outrank live replicas in KV-aware routing, and the spec
                # gauge becomes a NaN tombstone the service-mean fold skips
                self._g_kv.set(0.0)
                self._g_kv_free.set(0.0)
                if self.spec is not None:
                    self._g_spec.set(float("nan"))
                    self._g_spec_k.set(float("nan"))   # same tombstone rule
                if self.prefix is not None:
                    self._g_prefix.set(float("nan"))   # same tombstone rule
        return reqs

    # ------------------------------------------------------------------
    # Disaggregated serving: live KV handoff between role replicas
    # ------------------------------------------------------------------
    def attach_transfer(self, queue) -> None:
        """Join a ``TransferQueue``: the prefill side offers freshly
        prefilled lanes, the decode side drains them.  Needs a declared
        role — mixed engines never hand lanes off."""
        if self.role == "mixed":
            raise ValueError("attach_transfer needs role='prefill' or "
                             "'decode'")
        self.transfer = queue
        queue.register(self)

    def exportable_lanes(self) -> List[_SlotState]:
        """Active lanes a prefill replica could hand off right now: the
        first token is committed, nothing is in flight against the lane's
        pages, and the request still has tokens to generate.  A lane that
        missed the transfer window simply keeps decoding here (TTFT-aware
        fallback) and is offered again at the next step boundary."""
        out = []
        for slot in sorted(self._active):
            st = self._active[slot]
            if (st.tokens and st.inflight == 0
                    and st.submitted == len(st.tokens)
                    and len(st.tokens) < st.limit
                    and st.deferred_insert is None):
                out.append(st)
        return out

    def export_lane(self, st: _SlotState):
        """Serialize an in-flight lane for handoff to a decode replica:
        gather its pages into the staging buffer (one EXECUTE), read them
        back d2h, then release the lane — pages return to this pool
        (prefix donation first, exactly like retire) and the slot frees.
        The request is NOT completed here; the importer continues it
        mid-decode, bit-exact because the gather reassembles the logical
        cache independent of physical page ids."""
        from repro.serve.disagg import KVHandoff
        rid = st.req.rid
        ids = np.full((self.max_blocks,), self.pool_pages, np.int32)
        ids[:len(st.blocks)] = st.blocks
        xsp = (st.req.trace.span("engine.handoff_out",
                                 engine=self.engine_id, slot=st.slot,
                                 pages=len(st.blocks))
               if st.req.trace is not None else None)
        self._exec("xfer_extract", ("kv_pool",), ("xfer_pages",),
                   const_args=(ids,), span=xsp)
        staged = self._read("xfer_pages", span=xsp)
        pages = jax.tree.map(np.asarray, staged)
        if xsp is not None:
            xsp.end()
        handoff = KVHandoff(
            req=st.req, rid=rid, tokens=st.tokens, tbts=st.tbts,
            pos=st.pos, bucket=st.bucket, limit=st.limit,
            n_pages=len(st.blocks), pages=pages, admit_t=st.admit_t,
            first_token_t=self._first_token.get(rid, st.first_token_t),
            last_token_t=st.last_token_t,
            src_engine=self.engine_id, export_t=self._clock())
        if self.prefix is not None and st.blocks:
            # donate committed pages to the tree before dropping the
            # lane's references — same rule as retire, so the handed-off
            # request's own OOM recompute (or a sibling prompt) still hits
            ps = self.page_size
            flat = self._pad_prompt(st.req.prompt, st.bucket).reshape(-1)
            full = np.concatenate([flat, np.asarray(st.tokens, np.int32)])
            n_complete = min(st.pos // ps, len(st.blocks))
            if n_complete:
                nxt = (int(full[n_complete * ps])
                       if n_complete * ps < len(full) else None)
                self.prefix.insert(st.bucket, full[:n_complete * ps],
                                   st.blocks[:n_complete], nxt)
        self.pool.free(st.blocks)
        self._bt_clear_row(st.slot)
        self._active.pop(st.slot, None)
        heapq.heappush(self._free, st.slot)
        self._first_token.pop(rid, None)
        if st.span is not None:
            st.span.annotate(handed_off=True, tokens=len(st.tokens)).end()
        self.registry.record_event("engine_handoff_out", rid=rid,
                                   slot=st.slot, engine=self.engine_id,
                                   pages=handoff.n_pages)
        return handoff

    def import_lane(self, handoff) -> bool:
        """Install a handed-off lane: allocate pages, upload + scatter the
        staged pages (whole-page overwrite — no scrub needed), install the
        lane scalars, and resume decode mid-request.  Returns False
        without side effects when there is no slot or page headroom."""
        if not self._free:
            return False
        n = handoff.n_pages
        if n > self.max_blocks or not self.pool.can_admit(n):
            return False
        page_ids = self.pool.alloc(n)
        if page_ids is None:
            return False
        page_ids = [int(p) for p in page_ids]
        self._virgin_pages.difference_update(page_ids)
        slot = heapq.heappop(self._free)
        self._bt_set_row(slot, page_ids)
        try:
            imp = (handoff.req.trace.span("engine.handoff_in",
                                          engine=self.engine_id, slot=slot,
                                          pages=n)
                   if handoff.req.trace is not None else None)
            W = self.max_blocks

            def fit(leaf):
                # replicas may be provisioned with different max_blocks;
                # pad/trim the staging width (padding never installs —
                # its ids point out of range)
                leaf = np.asarray(leaf)
                if leaf.shape[0] == W:
                    return leaf
                if leaf.shape[0] > W:
                    return leaf[:W]
                pad = np.zeros((W - leaf.shape[0],) + leaf.shape[1:],
                               leaf.dtype)
                return np.concatenate([leaf, pad], 0)

            staged = jax.tree.map(fit, handoff.pages)
            ids = np.full((W,), self.pool_pages, np.int32)
            ids[:n] = page_ids
            self._write("xfer_pages", staged, span=imp)
            self._exec("xfer_install", ("kv_pool", "xfer_pages"),
                       ("kv_pool",), const_args=(ids,), donate=True,
                       dirty_pages={"kv_pool": tuple(page_ids)}, span=imp)
            self._exec("lane_set", ("toks", "pos"), ("toks", "pos"),
                       const_args=(np.int32(handoff.tokens[-1]),
                                   np.int32(handoff.pos), np.int32(slot)),
                       donate=True, span=imp)
            if imp is not None:
                imp.end()
        except BaseException:
            self.pool.free(page_ids)
            self._bt_clear_row(slot)
            heapq.heappush(self._free, slot)
            raise
        st = _SlotState(req=handoff.req, slot=slot, tokens=handoff.tokens,
                        tbts=handoff.tbts, admit_t=handoff.admit_t,
                        first_token_t=handoff.first_token_t,
                        last_token_t=handoff.last_token_t,
                        limit=handoff.limit, bucket=handoff.bucket,
                        pos=handoff.pos, blocks=page_ids,
                        submitted=len(handoff.tokens),
                        span=(handoff.req.trace.span(
                            "engine.decode", engine=self.engine_id,
                            slot=slot, imported=True)
                            if handoff.req.trace is not None else None))
        handoff.req.committed = st.tokens   # re-alias: crash replay
        # seed the TTFT ledger so neither this engine's commits nor an
        # OOM-preempt recompute here observe TTFT a second time
        self._first_token[handoff.req.rid] = handoff.first_token_t
        self._active[slot] = st
        self.registry.record_event("engine_handoff_in",
                                   rid=handoff.req.rid, slot=slot,
                                   engine=self.engine_id, pages=n)
        return True

    def run_until_drained(self, max_iterations: int = 100000) -> None:
        while not self.idle:
            self.step()
            if self.iterations >= max_iterations:
                raise RuntimeError("engine did not drain "
                                   f"in {max_iterations} iterations")

    # ------------------------------------------------------------------
    # Router integration (live plane): pull admissible work, push results
    # ------------------------------------------------------------------
    def pump(self, router, admit: bool = True) -> bool:
        """One iteration against a ``RequestRouter``; True if work moved.
        ``admit=False`` (a draining replica) pulls nothing new and only
        finishes what it already holds.  The pop is engine-tagged so a
        KV-aware router can steer work toward the replica with the most
        free pages."""
        if self.prefix is not None and admit:
            # advertise this replica's prefix-cache warmth so the router
            # can steer repeat prefixes here (idempotent re-registration)
            router.register_prefix_probe(self.engine_id,
                                         self.prefix_match_len)
        reg_role = getattr(router, "register_engine_role", None)
        if reg_role is not None and admit:
            # declare this replica's role so the router sends fresh
            # prompts to prefill replicas only (idempotent)
            reg_role(self.engine_id, self.role, self.buckets)
        if self.transfer is not None and self.role == "decode":
            # drain admitted handoffs into free slots before stepping
            self.transfer.pump_dest(self)
        if admit:
            for req in router.pop(len(self._free), engine_id=self.engine_id):
                self.submit(req)
        moved = bool(self._active or self.pending)
        if moved:
            self.step()
        if self.transfer is not None and self.role == "prefill":
            # offer freshly prefilled lanes at the step boundary; lanes
            # the queue rejects keep decoding here (aggregated fallback)
            self.transfer.pump_source(self)
        for rec in self.drain_completions():
            router.complete(rec)
        return moved
