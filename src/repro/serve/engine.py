"""Continuous-batching serving engine (vLLM/Orca-style iteration-level
scheduling on top of the Funky monitor) over **paged** vFPGA device memory.

The engine owns ``slots`` fixed decode lanes.  Each lane is an independent
sequence with its own position counter; one *iteration* advances every
occupied lane by one token through a single vmapped EXECUTE request.
Between iterations the engine retires finished sequences and backfills
freed lanes with prefills of waiting requests — admission happens at
iteration granularity, so a long-running batch never stalls behind a
straggler (the continuous-batching property).

KV memory comes in two modes:

* **paged** (default) — device KV memory is a ``BlockPool`` of fixed-size
  pages shared by every lane.  A per-lane *block table* row maps logical
  page index -> physical page; the vmapped decode step gathers each lane's
  cache through its row and scatters back only the page it wrote.  Lanes
  hold pages at token granularity: prompt pages at admission, one more
  page whenever decode crosses a page boundary, all freed the moment the
  request retires.  Admission is therefore **memory-based** — admit while
  ``free_pages - prompt_pages >= reserve_pages`` — so ``slots`` can exceed
  what worst-case reservations would allow.  If the pool exhausts
  mid-decode the youngest lane is OOM-preempted: its pages are freed and
  its request requeued for deterministic recomputation (greedy decode, so
  the client sees identical tokens).  Freed pages are scrubbed (positions
  invalidated) on reallocation — the §3.4 freed-memory-zeroing rule — so a
  new owner can never attend to a previous lane's tokens.
* **reserved** — the old worst-case layout: every lane owns a
  ``prompt_len + max_new_tokens`` stripe up front.  Kept as the fig15
  baseline the paged mode is measured against.

Paged mode also supports **prompt buckets**: 2-3 prefill lengths compiled
up front, with each admission routed to the smallest bucket that fits
instead of padding everything to one ``prompt_len``.

Every device interaction is a Funky request through ``Monitor.submit``, so
serving stays preemptible at token boundaries: ``Monitor.evict`` between
iterations snapshots the dirty pages plus the (tiny) block table — the
``BufferTable`` tracks the pool at page granularity — and ``resume``
continues every in-flight ragged sequence bit-exactly.

Per-request latencies (TTFT, time-between-tokens, end-to-end) land in the
shared ``repro.scaling.metrics`` registry under the canonical service
schema, together with KV occupancy gauges the autoscaler reads as a memory
pressure signal.
"""

from __future__ import annotations

import heapq
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.guest import FunkyCL
from repro.core.programs import Program
from repro.scaling.autoscaler import (M_COMPLETIONS, M_KV_FREE_PAGES,
                                      M_KV_PAGES, M_PREEMPTIONS,
                                      M_QUEUE_DEPTH, M_SLO_VIOLATIONS,
                                      M_UTILIZATION)
from repro.scaling.metrics import MetricsRegistry
from repro.serve.kvcache import (BlockPool, cache_bytes, compact_pool,
                                 extract_written_page, gather_lane_cache,
                                 init_caches_from_specs,
                                 pool_specs_from_lane_cache, scatter_pages,
                                 scatter_prefill, scrub_pages,
                                 token_axes_from_lengths)

# Canonical per-request serving metrics (one schema across planes).
M_TTFT = "request_ttft_seconds"
M_TBT = "request_tbt_seconds"
M_E2E = "request_latency_seconds"
M_TOKENS = "engine_tokens_total"
M_ITERS = "engine_iterations_total"


@dataclass
class ServeRequest:
    """One generation request admitted into a decode slot."""
    rid: str
    prompt: np.ndarray                  # (P,) int32 token ids
    max_new_tokens: int = 8
    arrival_t: Optional[float] = None   # registry-clock timestamp
    slo_s: Optional[float] = None       # end-to-end SLO (None = untracked)


@dataclass
class CompletedRequest:
    rid: str
    tokens: List[int]
    arrival_t: float
    admit_t: float
    first_token_t: float
    finish_t: float
    tbts: List[float] = field(default_factory=list)

    @property
    def ttft_s(self) -> float:
        return self.first_token_t - self.arrival_t

    @property
    def e2e_s(self) -> float:
        return self.finish_t - self.arrival_t


@dataclass
class _SlotState:
    req: ServeRequest
    slot: int
    tokens: List[int]
    admit_t: float
    first_token_t: float
    last_token_t: float
    tbts: List[float] = field(default_factory=list)
    # effective generation cap: min(request ask, engine cap) — the engine's
    # cache/pages are provisioned for max_new_tokens, so an over-cap ask is
    # clamped instead of walking past the block table / ring capacity
    limit: int = 1
    # paged mode
    bucket: int = 0                     # prompt bucket this lane prefetched
    pos: int = 0                        # absolute position of the next write
    blocks: List[int] = field(default_factory=list)


class ContinuousBatchingEngine:
    def __init__(self, arch: str, cl: FunkyCL, *, slots: int = 4,
                 prompt_len: int = 16, max_new_tokens: int = 16,
                 service: str = "svc", engine_id: str = "engine0",
                 seed: int = 0, registry: Optional[MetricsRegistry] = None,
                 publish_gauges: bool = True, paged: bool = True,
                 page_size: int = 8, pool_pages: Optional[int] = None,
                 reserve_pages: int = 1,
                 prompt_buckets: Optional[Sequence[int]] = None):
        from repro.configs import get_arch
        from repro.models import build_model

        self.cl = cl
        self.slots = slots
        self.max_new_tokens = max_new_tokens   # per-request cap
        self.service = service
        self.engine_id = engine_id
        self.seed = seed
        self.cfg = get_arch(arch)
        self.paged = paged
        if prompt_buckets and prompt_len > max(prompt_buckets):
            raise ValueError(
                f"prompt_len {prompt_len} exceeds the largest prompt "
                f"bucket {max(prompt_buckets)}: prompts would be silently "
                "truncated — add prompt_len as the largest bucket")
        if paged:
            self.buckets = tuple(sorted(set(prompt_buckets or (prompt_len,))))
            self.prompt_len = max(self.buckets)
            self.page_size = page_size
            self.max_ctx = self.prompt_len + max_new_tokens
            self.max_blocks = math.ceil(self.max_ctx / page_size)
            # default pool covers the worst case (no oversubscription);
            # benchmarks/servers pass a smaller pool to oversubscribe
            self.pool_pages = (pool_pages if pool_pages is not None
                               else slots * self.max_blocks)
            if self.pool_pages < self.max_blocks:
                raise ValueError(
                    f"pool of {self.pool_pages} pages cannot hold one "
                    f"worst-case request ({self.max_blocks} pages)")
            max_prompt_pages = math.ceil(self.prompt_len / page_size)
            if self.pool_pages - max_prompt_pages < reserve_pages:
                raise ValueError(
                    f"reserve watermark {reserve_pages} can never clear for "
                    f"a {max_prompt_pages}-page prompt in a "
                    f"{self.pool_pages}-page pool (admission would starve)")
            self.pool = BlockPool(self.pool_pages, page_size,
                                  reserve_pages=reserve_pages)
            # paged prefill writes exactly the prompt (margin 0); decode
            # headroom comes from pages appended at token granularity
            self.bundle = build_model(self.cfg, cache_margin=0)
            self._bt_host = np.full((slots, self.max_blocks), -1, np.int32)
            self._bt_dirty = True
            self._first_token: Dict[str, float] = {}
        else:
            if prompt_buckets:
                raise ValueError("prompt buckets need paged=True (dense "
                                 "lanes are compiled to one prompt_len)")
            self.buckets = (prompt_len,)
            self.prompt_len = prompt_len
            # cache capacity = prompt_len + max_new_tokens: prefill reserves
            # the decode headroom so admission is a pure scatter
            self.bundle = build_model(self.cfg, cache_margin=max_new_tokens)
            self.pool = None
        self.registry = (registry if registry is not None
                         else cl._monitor.telemetry)
        self._clock = self.registry.clock
        self._publish_gauges = publish_gauges
        # handles resolved once — the per-iteration loop never takes the
        # registry lock (same rule as the monitor's dispatch loop)
        self._h_ttft = self.registry.histogram(M_TTFT, service=service)
        self._h_tbt = self.registry.histogram(M_TBT, service=service)
        self._h_e2e = self.registry.histogram(M_E2E, service=service)
        self._c_tokens = self.registry.counter(M_TOKENS, service=service)
        self._c_iters = self.registry.counter(M_ITERS, service=service)
        self._c_completions = self.registry.counter(M_COMPLETIONS,
                                                    service=service)
        self._c_violations = self.registry.counter(M_SLO_VIOLATIONS,
                                                   service=service)
        self._c_preemptions = self.registry.counter(M_PREEMPTIONS,
                                                    service=service)
        if publish_gauges:
            self._g_queue = self.registry.gauge(
                M_QUEUE_DEPTH, service=service, engine=engine_id)
            self._g_util = self.registry.gauge(
                M_UTILIZATION, service=service, engine=engine_id)
            self._g_kv = self.registry.gauge(
                M_KV_PAGES, service=service, engine=engine_id)
            self._g_kv_free = self.registry.gauge(
                M_KV_FREE_PAGES, service=service, engine=engine_id)

        self.pending: deque = deque()
        self._free: List[int] = list(range(slots))
        heapq.heapify(self._free)
        self._active: Dict[int, _SlotState] = {}
        self.completed: Dict[str, CompletedRequest] = {}
        self._unreported: deque = deque()   # completions not yet drained
        self.iterations = 0
        self.peak_active = 0                # max concurrent in-flight lanes
        self.preemptions = 0
        self._setup_done = False
        self._program_ids: List[str] = []

    # ------------------------------------------------------------------
    # Program/buffer setup (Funky guest-style, via FunkyCL only)
    # ------------------------------------------------------------------
    def setup(self, restore: bool = False) -> None:
        if self.paged:
            self._setup_paged(restore)
        else:
            self._setup_reserved(restore)
        self._setup_done = True

    def program_ids(self) -> tuple:
        return tuple(self._program_ids)

    def _register(self, cl, name, fn, abstracts, donate_argnums=()):
        cl.clCreateProgramWithBinary(Program(name, fn), abstracts,
                                     donate_argnums=donate_argnums)
        self._program_ids.append(name)

    def _prefill_fn(self):
        bundle = self.bundle

        def prefill_one(params, tokens):
            logits, cache = bundle.prefill_fn(params, {"tokens": tokens})
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        return prefill_one

    # -- paged layout ----------------------------------------------------
    def _setup_paged(self, restore: bool) -> None:
        bundle, B, ps = self.bundle, self.slots, self.page_size
        NP, max_blocks = self.pool_pages, self.max_blocks
        prefill_one = self._prefill_fn()

        def init_params(seed):
            return bundle.init(jax.random.PRNGKey(seed))

        params_abs = jax.eval_shape(lambda: init_params(0))
        pf_abs = {}
        for P in self.buckets:
            prompt_abs = jax.ShapeDtypeStruct((1, P), jnp.int32)
            pf_tok_abs, pf_cache_abs = jax.eval_shape(
                prefill_one, params_abs, prompt_abs)
            pf_abs[P] = (prompt_abs, pf_tok_abs, pf_cache_abs)
        # discover each cache leaf's token axis by diffing two prompt
        # lengths (rejects layouts paging cannot virtualize, e.g.
        # window-bounded rings) — buckets give the second length for free
        if len(self.buckets) > 1:
            alt, alt_cache = self.buckets[0], pf_abs[self.buckets[0]][2]
        else:
            alt = self.prompt_len - 1
            if alt < 1:
                raise ValueError("paged mode needs prompt_len >= 2")
            _, alt_cache = jax.eval_shape(
                prefill_one, params_abs,
                jax.ShapeDtypeStruct((1, alt), jnp.int32))
        token_axes = token_axes_from_lengths(
            alt_cache, pf_abs[self.prompt_len][2], alt, self.prompt_len)
        self._token_axes = token_axes
        pool_abs = pool_specs_from_lane_cache(
            pf_abs[self.prompt_len][2], token_axes, NP, ps)
        self._pool_abs = pool_abs
        self.pool_bytes = cache_bytes(pool_abs)
        self.page_bytes = self.pool_bytes // NP
        toks_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
        bt_abs = jax.ShapeDtypeStruct((B, max_blocks), jnp.int32)

        def init_paged():
            return (jnp.zeros((B, 1), jnp.int32), jnp.zeros((B,), jnp.int32),
                    init_caches_from_specs(pool_abs))

        def decode_step(params, toks, pos, bt, pool):
            def lane(tok, p, bt_row):
                caches = gather_lane_cache(pool, bt_row, token_axes,
                                           page_size=ps)
                logits, new_cache = bundle.decode_fn(params, tok, p, caches)
                new_tok = jnp.argmax(logits, -1).astype(jnp.int32)
                active = bt_row[0] >= 0
                lp = (p % (max_blocks * ps)) // ps
                pages = extract_written_page(new_cache, lp, token_axes,
                                             page_size=ps)
                phys = jnp.where(active, bt_row[lp], jnp.int32(NP))
                new_p = jnp.where(active, p + jnp.int32(1), p)
                return new_tok, new_p, pages, phys

            toks2, pos2, pages, phys = jax.vmap(
                lane, in_axes=(0, 0, 0))(toks, pos, bt)
            return toks2, pos2, scatter_pages(pool, phys, pages)

        def scrub(pool, page_ids):
            return scrub_pages(pool, page_ids)

        def compact(pool, src_ids, dst_ids):
            return compact_pool(pool, src_ids, dst_ids)

        cl = self.cl
        self._register(cl, "init_params", init_params, (0,))
        self._register(cl, "init_paged", init_paged, ())
        slot_abs = jnp.int32(0)
        ids_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
        np_abs = jax.ShapeDtypeStruct((NP,), jnp.int32)
        for P, (prompt_abs, pf_tok_abs, pf_cache_abs) in pf_abs.items():
            self._register(cl, f"prefill_{P}", prefill_one,
                           (params_abs, prompt_abs))
            n_pp = self.pool.pages_for_tokens(P)

            def admit(toks, pos, pool, pf_tok, pf_cache, slot, page_ids,
                      P=P):
                slot = jnp.asarray(slot, jnp.int32)
                toks = jax.lax.dynamic_update_slice(
                    toks, pf_tok[:, None], (slot, jnp.int32(0)))
                pos = jax.lax.dynamic_update_slice(
                    pos, jnp.full((1,), P, jnp.int32), (slot,))
                pool = scatter_prefill(pool, page_ids, pf_cache,
                                       token_axes, page_size=ps,
                                       prompt_len=P)
                return toks, pos, pool

            pp_abs = jax.ShapeDtypeStruct((n_pp,), jnp.int32)
            self._register(
                cl, f"admit_{P}", admit,
                (toks_abs, pos_abs, pool_abs, pf_tok_abs, pf_cache_abs,
                 slot_abs, pp_abs),
                donate_argnums=(0, 1, 2))
        self._register(cl, "scrub", scrub, (pool_abs, ids_abs),
                       donate_argnums=(0,))
        self._register(cl, "compact_pool", compact,
                       (pool_abs, np_abs, np_abs), donate_argnums=(0,))
        self._register(cl, "decode_step", decode_step,
                       (params_abs, toks_abs, pos_abs, bt_abs, pool_abs),
                       donate_argnums=(1, 2, 4))
        if not restore:
            cl.clCreateBuffer("params", params_abs)
            cl.clCreateBuffer("toks", toks_abs)
            cl.clCreateBuffer("pos", pos_abs)
            cl.clCreateBuffer("block_table", bt_abs)
            cl.clCreateBuffer("kv_pool", pool_abs, paged=True)
            cl.clCreateBuffer("pf_tok", pf_abs[self.prompt_len][1])
            for P, (prompt_abs, _, pf_cache_abs) in pf_abs.items():
                cl.clCreateBuffer(f"pf_prompt_{P}", prompt_abs)
                cl.clCreateBuffer(f"pf_cache_{P}", pf_cache_abs)
            cl.clEnqueueKernel("init_params", (), ("params",),
                               const_args=(self.seed,))
            cl.clEnqueueKernel("init_paged", (),
                               ("toks", "pos", "kv_pool"))
            cl.write_buffer("block_table", self._bt_host.copy())
            cl.clFinish()
            self._bt_dirty = False

    # -- reserved (worst-case stripe) layout -----------------------------
    def _setup_reserved(self, restore: bool) -> None:
        bundle, B, P = self.bundle, self.slots, self.prompt_len
        prefill_one = self._prefill_fn()

        def init_params(seed):
            return bundle.init(jax.random.PRNGKey(seed))

        def decode_step(params, toks, pos, caches):
            def lane(tok, p, cache):
                logits, new_cache = bundle.decode_fn(params, tok, p, cache)
                return (jnp.argmax(logits, -1).astype(jnp.int32),
                        p + jnp.int32(1), new_cache)
            return jax.vmap(lane)(toks, pos, caches)

        def admit_slot(toks, pos, caches, pf_tok, pf_cache, slot):
            slot = jnp.asarray(slot, jnp.int32)
            toks = jax.lax.dynamic_update_slice(
                toks, pf_tok[:, None], (slot, jnp.int32(0)))
            pos = jax.lax.dynamic_update_slice(
                pos, jnp.full((1,), P, jnp.int32), (slot,))
            caches = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_slice(
                    c, n[None], (slot,) + (jnp.int32(0),) * n.ndim),
                caches, pf_cache)
            return toks, pos, caches

        params_abs = jax.eval_shape(lambda: init_params(0))
        prompt_abs = jax.ShapeDtypeStruct((1, P), jnp.int32)
        pf_tok_abs, pf_cache_abs = jax.eval_shape(
            prefill_one, params_abs, prompt_abs)
        caches_abs = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((B,) + l.shape, l.dtype),
            pf_cache_abs)
        toks_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
        self._caches_abs = caches_abs
        self.pool_bytes = cache_bytes(caches_abs)

        def init_slots():
            return (jnp.zeros((B, 1), jnp.int32), jnp.zeros((B,), jnp.int32),
                    init_caches_from_specs(caches_abs))

        cl = self.cl
        self._register(cl, "init_params", init_params, (0,))
        self._register(cl, "init_slots", init_slots, ())
        self._register(cl, f"prefill_{P}", prefill_one,
                       (params_abs, prompt_abs))
        slot_abs = jnp.int32(0)
        self._register(
            cl, "admit_slot", admit_slot,
            (toks_abs, pos_abs, caches_abs, pf_tok_abs, pf_cache_abs,
             slot_abs),
            donate_argnums=(0, 1, 2))
        self._register(
            cl, "decode_step", decode_step,
            (params_abs, toks_abs, pos_abs, caches_abs),
            donate_argnums=(1, 2, 3))
        if not restore:
            cl.clCreateBuffer("params", params_abs)
            cl.clCreateBuffer("toks", toks_abs)
            cl.clCreateBuffer("pos", pos_abs)
            cl.clCreateBuffer("caches", caches_abs)
            cl.clCreateBuffer(f"pf_prompt_{P}", prompt_abs)
            cl.clCreateBuffer("pf_tok", pf_tok_abs)
            cl.clCreateBuffer(f"pf_cache_{P}", pf_cache_abs)
            cl.clEnqueueKernel("init_params", (), ("params",),
                               const_args=(self.seed,))
            cl.clEnqueueKernel("init_slots", (), ("toks", "pos", "caches"))
            cl.clFinish()

    # ------------------------------------------------------------------
    # Request intake
    # ------------------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        if req.arrival_t is None:
            req.arrival_t = self._clock()
        self.pending.append(req)

    @property
    def idle(self) -> bool:
        return not self._active and not self.pending

    @property
    def active_count(self) -> int:
        return len(self._active)

    def _pick_bucket(self, prompt_len: int) -> int:
        for b in self.buckets:
            if prompt_len <= b:
                return b
        return self.buckets[-1]         # over-long prompts truncate

    def _pad_prompt(self, prompt: np.ndarray, bucket: int) -> np.ndarray:
        p = np.asarray(prompt, np.int32).reshape(-1)[:bucket]
        if p.shape[0] < bucket:
            p = np.pad(p, (0, bucket - p.shape[0]))
        return p.reshape(1, bucket)

    def kv_stats(self) -> dict:
        """Cache-memory occupancy in the shared byte accounting."""
        if not self.paged:
            return {"paged": False, "pool_bytes": self.pool_bytes,
                    "bytes_in_use": self.pool_bytes, "occupancy": 1.0}
        used = self.pool.used_count()
        return {"paged": True, "pool_bytes": self.pool_bytes,
                "page_bytes": self.page_bytes,
                "pages_used": used, "pages_free": self.pool.free_count(),
                "bytes_in_use": used * self.page_bytes,
                "occupancy": self.pool.occupancy(),
                "used_span": self.pool.used_span()}

    # ------------------------------------------------------------------
    # One iteration: admit into free lanes, decode all occupied lanes
    # ------------------------------------------------------------------
    def _admit(self) -> int:
        admitted = 0
        cl = self.cl
        while self._free and self.pending:
            req = self.pending[0]
            bucket = self._pick_bucket(
                np.asarray(req.prompt).reshape(-1).shape[0])
            page_ids = None
            if self.paged:
                n_pp = self.pool.pages_for_tokens(bucket)
                if not self.pool.can_admit(n_pp):
                    break               # memory-based admission gate
                page_ids = self.pool.alloc(n_pp)
            self.pending.popleft()
            slot = heapq.heappop(self._free)
            cl.write_buffer(f"pf_prompt_{bucket}",
                            self._pad_prompt(req.prompt, bucket))
            cl.clEnqueueKernel(f"prefill_{bucket}",
                               ("params", f"pf_prompt_{bucket}"),
                               ("pf_tok", f"pf_cache_{bucket}"))
            if self.paged:
                cl.clEnqueueKernel(
                    f"admit_{bucket}",
                    ("toks", "pos", "kv_pool", "pf_tok",
                     f"pf_cache_{bucket}"),
                    ("toks", "pos", "kv_pool"),
                    const_args=(np.int32(slot),
                                np.asarray(page_ids, np.int32)),
                    donate=True,
                    dirty_pages={"kv_pool": tuple(page_ids)})
                self._bt_host[slot, :] = -1
                self._bt_host[slot, :len(page_ids)] = page_ids
                self._bt_dirty = True
            else:
                cl.clEnqueueKernel(
                    "admit_slot",
                    ("toks", "pos", "caches", "pf_tok",
                     f"pf_cache_{bucket}"),
                    ("toks", "pos", "caches"),
                    const_args=(np.int32(slot),), donate=True)
            first_tok = int(np.asarray(cl.read_buffer("pf_tok"))[0])
            now = self._clock()
            first_t = now
            if self.paged:
                # an OOM-preempted request recomputes, but the client saw
                # its first token on the first admission — keep that TTFT
                prior = self._first_token.get(req.rid)
                if prior is not None:
                    first_t = prior
                else:
                    self._first_token[req.rid] = now
                    self._h_ttft.observe(now - req.arrival_t)
            else:
                self._h_ttft.observe(now - req.arrival_t)
            st = _SlotState(req=req, slot=slot, tokens=[first_tok],
                            admit_t=now, first_token_t=first_t,
                            last_token_t=now,
                            limit=max(1, min(req.max_new_tokens,
                                             self.max_new_tokens)),
                            bucket=bucket, pos=bucket,
                            blocks=list(page_ids) if page_ids else [])
            self._c_tokens.inc()
            self.registry.record_event("engine_admit", rid=req.rid,
                                       slot=slot, engine=self.engine_id)
            admitted += 1
            if len(st.tokens) >= st.limit:
                self._retire(st, now)       # degenerate 1-token request
            else:
                self._active[slot] = st
        return admitted

    def _retire(self, st: _SlotState, now: float) -> None:
        rec = CompletedRequest(
            rid=st.req.rid, tokens=st.tokens, arrival_t=st.req.arrival_t,
            admit_t=st.admit_t, first_token_t=st.first_token_t,
            finish_t=now, tbts=st.tbts)
        self.completed[st.req.rid] = rec
        self._unreported.append(rec)
        self._active.pop(st.slot, None)
        heapq.heappush(self._free, st.slot)
        if self.paged:
            # pages return to the pool the moment the request retires; the
            # cleared row deactivates the lane for the next decode gather
            self.pool.free(st.blocks)
            self._bt_host[st.slot, :] = -1
            self._bt_dirty = True
            self._first_token.pop(st.req.rid, None)
        self._h_e2e.observe(rec.e2e_s)
        self._c_completions.inc()
        if st.req.slo_s is not None and rec.e2e_s > st.req.slo_s:
            self._c_violations.inc()
        self.registry.record_event("engine_retire", rid=st.req.rid,
                                   slot=st.slot, tokens=len(st.tokens),
                                   engine=self.engine_id)

    # -- paged-mode page lifecycle ---------------------------------------
    def _pick_victim(self) -> _SlotState:
        """Youngest admission loses (its recomputation is cheapest); the
        oldest lane always keeps making progress, so the engine never
        livelocks as long as the pool holds one worst-case request."""
        return max(self._active.values(), key=lambda s: (s.admit_t, s.slot))

    def _preempt(self, st: _SlotState) -> None:
        self.pool.free(st.blocks)
        self._bt_host[st.slot, :] = -1
        self._bt_dirty = True
        self._active.pop(st.slot)
        heapq.heappush(self._free, st.slot)
        self.pending.appendleft(st.req)     # deterministic recompute
        self.preemptions += 1
        self._c_preemptions.inc()
        self.registry.record_event("engine_oom_preempt", rid=st.req.rid,
                                   slot=st.slot, engine=self.engine_id)

    def _append_pages(self) -> None:
        """Token-granularity growth: map the page each lane's next write
        lands in, preempting the youngest lane(s) when the pool runs dry."""
        scrub_ids: List[int] = []
        for slot in sorted(self._active):
            st = self._active.get(slot)
            if st is None:
                continue                # preempted by an earlier append
            lp = st.pos // self.page_size
            if self._bt_host[slot, lp] >= 0:
                continue
            got = self.pool.alloc(1, urgent=True)
            while got is None:
                victim = self._pick_victim()
                self._preempt(victim)
                if victim is st:
                    break
                got = self.pool.alloc(1, urgent=True)
            if got is None:
                continue                # st preempted itself
            assert lp == len(st.blocks), (lp, st.blocks)
            st.blocks.append(got[0])
            self._bt_host[slot, lp] = got[0]
            self._bt_dirty = True
            scrub_ids.append(got[0])
        if scrub_ids:
            ids = np.full((self.slots,), self.pool_pages, np.int32)
            ids[:len(scrub_ids)] = scrub_ids
            self.cl.clEnqueueKernel(
                "scrub", ("kv_pool",), ("kv_pool",), const_args=(ids,),
                donate=True, dirty_pages={"kv_pool": tuple(scrub_ids)})

    def compact(self) -> dict:
        """Defragment the pool: pack used pages into the lowest physical
        ids (shrinks the evict-time dirty-page span after churn).  Call
        between iterations only."""
        if not self.paged:
            return {"moved": 0}
        mapping = self.pool.compact()
        if mapping:
            src = np.full((self.pool_pages,), self.pool_pages, np.int32)
            dst = np.full((self.pool_pages,), self.pool_pages, np.int32)
            src[:len(mapping)] = list(mapping.keys())
            dst[:len(mapping)] = list(mapping.values())
            self.cl.clEnqueueKernel(
                "compact_pool", ("kv_pool",), ("kv_pool",),
                const_args=(src, dst), donate=True,
                dirty_pages={"kv_pool": tuple(mapping.values())})
            for st in self._active.values():
                st.blocks = [mapping.get(p, p) for p in st.blocks]
                self._bt_host[st.slot, :len(st.blocks)] = st.blocks
            self._bt_dirty = True
        return {"moved": len(mapping), "span": self.pool.used_span()}

    # -- one iteration ---------------------------------------------------
    def step(self) -> dict:
        """One engine iteration; returns counts for the caller's pacing."""
        if not self._setup_done:
            raise RuntimeError("engine.setup() has not run")
        admitted = self._admit()
        self.peak_active = max(self.peak_active, len(self._active))
        decoded = 0
        if self._active and self.paged:
            self._append_pages()
        if self._active:
            if self.paged:
                if self._bt_dirty:
                    self.cl.write_buffer("block_table", self._bt_host.copy())
                    self._bt_dirty = False
                dirty = sorted({int(self._bt_host[
                    s.slot, s.pos // self.page_size])
                    for s in self._active.values()})
                self.cl.clEnqueueKernel(
                    "decode_step",
                    ("params", "toks", "pos", "block_table", "kv_pool"),
                    ("toks", "pos", "kv_pool"), donate=True,
                    dirty_pages={"kv_pool": tuple(dirty)})
            else:
                self.cl.clEnqueueKernel(
                    "decode_step", ("params", "toks", "pos", "caches"),
                    ("toks", "pos", "caches"), donate=True)
            # token delivery doubles as the iteration's sync point — the
            # d2h TRANSFER drains the queue and lands on a token boundary
            toks = np.asarray(self.cl.read_buffer("toks"))
            now = self._clock()
            for st in list(self._active.values()):
                st.tokens.append(int(toks[st.slot, 0]))
                st.pos += 1
                st.tbts.append(now - st.last_token_t)
                self._h_tbt.observe(now - st.last_token_t)
                st.last_token_t = now
                decoded += 1
                if len(st.tokens) >= st.limit:
                    self._retire(st, now)
            self._c_tokens.inc(decoded)
        self.iterations += 1
        self._c_iters.inc()
        if self._publish_gauges:
            self._g_queue.set(len(self.pending))
            self._g_util.set(len(self._active) / self.slots)
            if self.paged:
                self._g_kv.set(self.pool.occupancy())
                self._g_kv_free.set(self.pool.free_count())
        return {"admitted": admitted, "decoded": decoded,
                "active": len(self._active), "pending": len(self.pending)}

    def drain_completions(self) -> List[CompletedRequest]:
        out = list(self._unreported)
        self._unreported.clear()
        return out

    def evacuate(self) -> List[ServeRequest]:
        """Hand back every un-finished request (kill / drain path) and
        reset the lanes.  Finished-but-unreported completions stay
        available via ``drain_completions`` — report those first so the
        caller's in-flight accounting stays exact."""
        reqs = ([st.req for st in self._active.values()]
                + list(self.pending))
        self._active.clear()
        self.pending.clear()
        self._free = list(range(self.slots))
        heapq.heapify(self._free)
        if self.paged:
            self.pool = BlockPool(self.pool_pages, self.page_size,
                                  reserve_pages=self.pool.reserve_pages)
            self._bt_host[:] = -1
            self._bt_dirty = True
            self._first_token.clear()
            if self._publish_gauges:
                # a killed replica must not pin the service-level pressure
                # signal at its last (hot) value — the aggregator keeps
                # gauges of dead engines forever
                self._g_kv.set(0.0)
                self._g_kv_free.set(self.pool.free_count())
        return reqs

    def run_until_drained(self, max_iterations: int = 100000) -> None:
        while not self.idle:
            self.step()
            if self.iterations >= max_iterations:
                raise RuntimeError("engine did not drain "
                                   f"in {max_iterations} iterations")

    # ------------------------------------------------------------------
    # Router integration (live plane): pull admissible work, push results
    # ------------------------------------------------------------------
    def pump(self, router, admit: bool = True) -> bool:
        """One iteration against a ``RequestRouter``; True if work moved.
        ``admit=False`` (a draining replica) pulls nothing new and only
        finishes what it already holds."""
        if admit:
            for req in router.pop(len(self._free)):
                self.submit(req)
        moved = bool(self._active or self.pending)
        if moved:
            self.step()
        for rec in self.drain_completions():
            router.complete(rec)
        return moved
