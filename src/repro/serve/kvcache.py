"""Paged vFPGA device-memory virtualization for KV caches (paper §3.4).

The serving engine used to reserve a worst-case ``prompt_len +
max_new_tokens`` KV stripe per decode lane at admission.  This module
virtualizes that memory behind an indirection layer, PagedAttention-style:

* ``BlockPool`` — the host-side allocator.  Device KV memory is a pool of
  fixed-size pages; lanes hold pages at *token* granularity (prompt pages
  at admission, one page at a time as decode crosses page boundaries) and
  free them the moment a request retires.  Admission is memory-based:
  admit while ``free_pages - need >= reserve_pages``, so the lane count can
  exceed what worst-case reservations would allow.
* **block table** — per-lane ``(max_blocks,)`` int32 rows mapping logical
  page index -> physical page id (-1 = unmapped).  The vmapped decode step
  gathers each lane's logical cache through its row; admission scatters the
  prefill cache into freshly allocated pages.
* traced helpers (``gather_lane_cache`` / ``extract_written_page`` /
  ``scatter_pages`` / ``scatter_prefill`` / ``scrub_pages`` /
  ``compact_pool``) — the kernel-side pieces the engine's programs are
  built from.  ``scrub_pages`` invalidates the position row of every page
  on (re)allocation, the paged analogue of the monitor zeroing freed device
  memory (§3.4 isolation): a new owner can never attend to a previous
  lane's tokens.
* ``BlockPool.compact`` — defragmentation: pack used pages into the lowest
  physical ids so the pool's high-water span (and therefore the worst-case
  dirty-page walk on evict) shrinks after churn.

Every leaf of the device pool has the page axis as axis 0, matching the
``BufferTable``'s page-granular dirtiness: evict/checkpoint serialize only
the pages written since the last sync plus the (tiny) block table.
"""

from __future__ import annotations

import heapq
import math
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.state import tree_bytes
from repro.models.attention import _INVALID_POS

# one exported byte-accounting helper (shared with the buffer state machine)
cache_bytes = tree_bytes


def init_caches_from_specs(specs):
    """Zeros for k/v/state leaves; INVALID sentinel for kv_pos leaves."""
    def mk(path, leaf):
        if _is_pos_leaf(path):
            return jnp.full(leaf.shape, _INVALID_POS, jnp.int32)
        return jnp.zeros(leaf.shape, leaf.dtype)

    return jax.tree_util.tree_map_with_path(mk, specs)


def _is_pos_leaf(path) -> bool:
    names = [k.key for k in path if hasattr(k, "key")]
    return bool(names) and names[-1] == "kv_pos"


def pages_for_tokens(n_tokens: int, page_size: int) -> int:
    return max(1, math.ceil(n_tokens / page_size))


# ---------------------------------------------------------------------------
# Host-side page allocator
# ---------------------------------------------------------------------------
class BlockPoolError(RuntimeError):
    pass


class BlockPool:
    """Fixed-size page allocator over the device KV pool.

    Deterministic by construction (lowest free id first) so paged decoding
    replays bit-exactly across evict/resume.  ``reserve_pages`` is the
    admission watermark: normal allocations keep that many pages free for
    in-flight decode appends; ``urgent=True`` (the append path) may dip
    into the reserve — when even that fails the engine preempts a lane.
    """

    def __init__(self, num_pages: int, page_size: int, *,
                 reserve_pages: int = 0):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("need num_pages > 0 and page_size > 0")
        if reserve_pages >= num_pages:
            raise ValueError("reserve watermark leaves no usable pages")
        self.num_pages = num_pages
        self.page_size = page_size
        self.reserve_pages = reserve_pages
        self._free: List[int] = list(range(num_pages))
        heapq.heapify(self._free)
        self._used: set = set()
        # reference counts: a page may be owned by several lanes plus the
        # prefix cache at once.  ``free`` drops one reference; the page
        # only returns to the free heap when the last reference drops, so
        # a shared page can never be scrubbed or reallocated under a
        # surviving owner.
        self._rc: Dict[int, int] = {}

    # -- accounting ------------------------------------------------------
    def free_count(self) -> int:
        return len(self._free)

    def used_count(self) -> int:
        return len(self._used)

    def refcount(self, page_id: int) -> int:
        return self._rc.get(page_id, 0)

    def shared_count(self) -> int:
        """Pages currently referenced by more than one owner."""
        return sum(1 for c in self._rc.values() if c > 1)

    def occupancy(self) -> float:
        return len(self._used) / self.num_pages

    def used_span(self) -> int:
        """High-water mark: 1 + the highest physical id in use."""
        return max(self._used) + 1 if self._used else 0

    def pages_for_tokens(self, n_tokens: int) -> int:
        return pages_for_tokens(n_tokens, self.page_size)

    def can_admit(self, n_pages: int) -> bool:
        return self.free_count() - n_pages >= self.reserve_pages

    # -- alloc / free ----------------------------------------------------
    def alloc(self, n_pages: int, *, urgent: bool = False,
              ) -> Optional[List[int]]:
        """Allocate ``n_pages`` (lowest ids first), or None if the request
        would breach the watermark (``urgent`` ignores the watermark)."""
        avail = self.free_count() - (0 if urgent else self.reserve_pages)
        if n_pages > avail:
            return None
        out = [heapq.heappop(self._free) for _ in range(n_pages)]
        self._used.update(out)
        for p in out:
            self._rc[p] = 1
        return out

    def share(self, page_ids: Sequence[int]) -> None:
        """Add one reference to each (already used) page — a new owner
        mapping cached pages into its block table, or the prefix cache
        pinning a lane's pages."""
        for p in page_ids:
            if p not in self._used:
                raise BlockPoolError(f"share of free page {p}")
            self._rc[p] += 1

    def free(self, page_ids: Sequence[int]) -> List[int]:
        """Drop one reference per page; returns the pages whose *last*
        reference dropped (those actually returned to the free heap).
        Shared pages survive under their remaining owners."""
        out: List[int] = []
        for p in page_ids:
            if p not in self._used:
                raise BlockPoolError(f"double free of page {p}")
            self._rc[p] -= 1
            if self._rc[p] == 0:
                del self._rc[p]
                self._used.discard(p)
                heapq.heappush(self._free, p)
                out.append(p)
        return out

    def free_tail(self, page_ids: Sequence[int], keep: int) -> List[int]:
        """Drop this owner's reference on ``page_ids[keep:]`` and return
        the pages that actually freed — the speculative-decode rollback
        primitive: a rejected lookahead orphans the pages past the last
        committed token, and only those go back to the pool (the kept
        prefix still holds the lane's committed history).  A *shared* tail
        page is unshared rather than freed: the surviving owners (prefix
        cache, other lanes) keep their copy untouched."""
        if keep < 0:
            raise ValueError("keep must be >= 0")
        return self.free(list(page_ids[keep:]))

    # -- defragmentation -------------------------------------------------
    def compact(self) -> Dict[int, int]:
        """Pack used pages into the lowest physical ids.

        Returns {old_id: new_id} for every page that moves (destinations
        are free before the call, so a single gather+scatter applies the
        whole mapping without ordering hazards).  The caller must rewrite
        its block tables and move the device pages.
        """
        k = len(self._used)
        dests = [i for i in range(k) if i not in self._used]
        movers = [p for p in sorted(self._used) if p >= k]
        mapping = dict(zip(movers, dests))
        if mapping:
            self._used = (self._used - set(movers)) | set(mapping.values())
            self._free = [i for i in range(self.num_pages)
                          if i not in self._used]
            heapq.heapify(self._free)
            # reference counts travel with the page: every owner (lanes,
            # prefix-cache nodes) is remapped by the caller from the same
            # mapping, so a shared page stays shared at its new id
            for old, new in mapping.items():
                self._rc[new] = self._rc.pop(old)
        return mapping

    def check_invariants(self) -> None:
        free = set(self._free)
        if len(free) != len(self._free):
            raise BlockPoolError("duplicate ids in free list")
        if free & self._used:
            raise BlockPoolError("page both free and used")
        if free | self._used != set(range(self.num_pages)):
            raise BlockPoolError("pages leaked from the pool")
        if set(self._rc) != self._used:
            raise BlockPoolError("refcount map out of sync with used set")
        if any(c < 1 for c in self._rc.values()):
            raise BlockPoolError("used page with refcount < 1")


# ---------------------------------------------------------------------------
# Pool pytree construction
# ---------------------------------------------------------------------------
# Models differ in cache leaf layout: a scanned backbone stacks a layer
# axis in front ((L, 1, cap, H, hd) k/v, (L, cap) kv_pos), MLA keeps
# compressed latents, etc.  Rather than hard-coding layouts, the engine
# discovers each leaf's *token axis* once at setup by diffing the abstract
# prefill cache at two prompt lengths; every traced helper then normalizes
# a leaf by moving that axis to the front, so the pool layout is always
# ``(num_pages, page_size, *rest)`` with ``rest`` the per-token residue in
# original order (layer/batch/head axes included).

def token_axes_from_lengths(cache_a, cache_b, len_a: int, len_b: int, *,
                            exact: bool = True):
    """Per-leaf token-axis pytree: the unique axis whose size tracks the
    prompt length.  Raises for window-bounded ring caches (no axis moves)
    or exotic layouts (several axes move) — those need reserved mode.

    ``exact=False`` only requires the axis size *delta* to match the prompt
    length delta (rather than the sizes themselves) — the case for caches
    built with a constant decode margin, e.g. the speculative-decode draft
    lane whose capacity is ``prompt_len + margin``.
    """
    def ax(la, lb):
        diffs = [i for i, (x, y) in enumerate(zip(la.shape, lb.shape))
                 if x != y]
        bad = len(diffs) != 1
        if not bad:
            d = diffs[0]
            if exact:
                bad = la.shape[d] != len_a or lb.shape[d] != len_b
            else:
                bad = lb.shape[d] - la.shape[d] != len_b - len_a
        if bad:
            raise ValueError(
                f"cannot page cache leaf {la.shape} -> {lb.shape}: token "
                "axis is not uniquely prompt-length-sized (window-bounded "
                "ring cache?); run the engine with paged=False")
        return diffs[0]

    return jax.tree.map(ax, cache_a, cache_b)


def _token_first(leaf, axis):
    return jnp.moveaxis(leaf, axis, 0)


def pool_specs_from_lane_cache(lane_cache_abs, token_axes, num_pages: int,
                               page_size: int):
    """Per-lane cache pytree -> page-pool pytree: each leaf becomes
    ``(num_pages, page_size, *rest)``.  Structure (and the ``kv_pos`` leaf
    names the init helper keys on) is preserved."""
    def mk(leaf, axis):
        rest = leaf.shape[:axis] + leaf.shape[axis + 1:]
        return jax.ShapeDtypeStruct((num_pages, page_size) + rest,
                                    leaf.dtype)

    return jax.tree.map(mk, lane_cache_abs, token_axes)


# ---------------------------------------------------------------------------
# Traced kernel-side helpers
# ---------------------------------------------------------------------------
def gather_lane_cache(pool, block_row, token_axes, *, page_size: int):
    """Reassemble one lane's logical cache from the pool through its block
    table row (traced, vmapped over lanes by the engine).

    Unmapped pages (id < 0) are clamped for the gather but their positions
    are forced to the INVALID sentinel, so attention masks them out no
    matter what the clamped page holds.
    """
    max_blocks = block_row.shape[0]
    cap = max_blocks * page_size

    def gk(path, leaf, axis):
        safe = jnp.clip(block_row, 0, leaf.shape[0] - 1)
        pages = leaf[safe]                       # (max_blocks, ps, *rest)
        flat = pages.reshape((cap,) + leaf.shape[2:])
        if _is_pos_leaf(path):
            valid = jnp.repeat(block_row >= 0, page_size)
            flat = jnp.where(
                valid.reshape((cap,) + (1,) * (flat.ndim - 1)),
                flat, _INVALID_POS)
        return jnp.moveaxis(flat, 0, axis)       # original lane layout

    return jax.tree_util.tree_map_with_path(gk, pool, token_axes)


def extract_written_page(new_lane_cache, logical_page, token_axes, *,
                         page_size: int):
    """Slice the page containing this step's single-token write back out of
    a lane's updated logical cache (traced; ``logical_page`` is dynamic)."""
    def ex(leaf, axis):
        tf = _token_first(leaf, axis)
        start = (logical_page * page_size,) + (0,) * (tf.ndim - 1)
        return jax.lax.dynamic_slice(tf, start,
                                     (page_size,) + tf.shape[1:])

    return jax.tree.map(ex, new_lane_cache, token_axes)


def scatter_pages(pool, phys_ids, pages):
    """Write per-lane updated pages back into the pool.  ``phys_ids`` is
    (lanes,); out-of-range ids (inactive lanes) are dropped.  Active lanes
    own disjoint pages, so the scatter is conflict-free."""
    return jax.tree.map(
        lambda pl, pg: pl.at[phys_ids].set(pg, mode="drop"), pool, pages)


def scatter_prefill(pool, page_ids, pf_cache, token_axes, *,
                    page_size: int, prompt_len: int):
    """Admission: distribute a prefill cache across freshly allocated pages.

    The tail page's unfilled slots get zeros / INVALID positions, so decode
    can write into them later without a scrub.
    """
    n_pp = page_ids.shape[0]
    pad = n_pp * page_size - prompt_len

    def sc(path, pool_leaf, pf_leaf, axis):
        vals = _token_first(pf_leaf, axis)       # (P, *rest)
        if pad:
            fill = (jnp.full((pad,) + vals.shape[1:], _INVALID_POS,
                             jnp.int32) if _is_pos_leaf(path)
                    else jnp.zeros((pad,) + vals.shape[1:], vals.dtype))
            vals = jnp.concatenate([vals, fill])
        vals = vals.reshape((n_pp, page_size) + vals.shape[1:])
        return pool_leaf.at[page_ids].set(vals)

    return jax.tree_util.tree_map_with_path(sc, pool, pf_cache, token_axes)


def scrub_pages(pool, page_ids):
    """Invalidate the kv_pos rows of (re)allocated pages — freed-memory
    zeroing (§3.4): whatever k/v bytes the previous owner left behind are
    unreachable once their positions read INVALID.  Out-of-range ids in the
    fixed-width ``page_ids`` vector are padding and dropped."""
    def f(path, leaf):
        if _is_pos_leaf(path):
            return leaf.at[page_ids].set(_INVALID_POS, mode="drop")
        return leaf

    return jax.tree_util.tree_map_with_path(f, pool)


def extract_pool_pages(pool, page_ids):
    """Gather whole pages out of a pool by physical id into a fixed-width
    staging pytree ``(width, page_size, *rest)`` — the serialization side
    of a cross-pool KV handoff (prefill -> decode replica).  ``page_ids``
    is a fixed-width vector; out-of-range entries are padding (clamped for
    the gather, ignored by the host, dropped again at install)."""
    return jax.tree.map(
        lambda leaf: leaf[jnp.clip(page_ids, 0, leaf.shape[0] - 1)], pool)


def install_pool_pages(pool, staged, page_ids):
    """Scatter a staged page pytree (from ``extract_pool_pages`` on another
    replica's pool) into this pool at ``page_ids``.  Whole pages are
    overwritten, so the destination needs no scrub; padding ids point out
    of range and are dropped."""
    return jax.tree.map(
        lambda pl, pg: pl.at[page_ids].set(pg, mode="drop"), pool, staged)


def compact_pool(pool, src_ids, dst_ids):
    """Apply a ``BlockPool.compact`` mapping on-device: move page ``src``
    to ``dst`` for each pair (destinations were free, so gather-then-
    scatter is safe).  Padding entries point out of range and are dropped.
    """
    return jax.tree.map(
        lambda leaf: leaf.at[dst_ids].set(leaf[jnp.clip(
            src_ids, 0, leaf.shape[0] - 1)], mode="drop"), pool)


def apply_block_table_delta(block_table, delta):
    """Apply a fixed-width update vector to the device-resident block
    table (traced).  ``delta`` is ``(width, 3)`` int32 rows of
    ``(slot, logical_page, phys)``:

    * ``slot < 0`` — padding, ignored;
    * ``logical_page < 0`` — clear the whole row to -1 (retire/preempt);
    * otherwise — set one cell (append/COW remap; ``phys`` may be -1 for
      a speculative rollback clearing mapped tail cells).

    Rows apply in order inside one EXECUTE, so a row clear followed by a
    re-mapping of the same slot composes the way the host applied them.
    This replaces the host-authoritative full-table h2d rewrite on the
    decode hot path — only the handful of cells that changed ride along.
    """
    max_blocks = block_table.shape[1]

    def body(i, bt):
        s, lp, v = delta[i, 0], delta[i, 1], delta[i, 2]
        s_safe = jnp.clip(s, 0, bt.shape[0] - 1)
        row = bt[s_safe]
        cell = row.at[jnp.clip(lp, 0, max_blocks - 1)].set(v)
        cleared = jnp.full((max_blocks,), -1, jnp.int32)
        new_row = jnp.where(lp < 0, cleared, cell)
        new_row = jnp.where(s < 0, row, new_row)
        return bt.at[s_safe].set(new_row)

    return jax.lax.fori_loop(0, delta.shape[0], body, block_table)
