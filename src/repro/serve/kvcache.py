"""Concrete cache construction + prompt utilities for serving."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import _INVALID_POS


def init_caches_from_specs(specs):
    """Zeros for k/v/state leaves; INVALID sentinel for kv_pos leaves."""
    def mk(path, leaf):
        names = [k.key for k in path if hasattr(k, "key")]
        if names and names[-1] == "kv_pos":
            return jnp.full(leaf.shape, _INVALID_POS, jnp.int32)
        return jnp.zeros(leaf.shape, leaf.dtype)

    return jax.tree_util.tree_map_with_path(mk, specs)


def cache_bytes(caches) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches))
