"""Cross-request KV prefix cache: a radix tree over committed token pages.

At production scale most prompts repeat — system prompts, few-shot
preambles, chat history replayed turn after turn.  Recomputing those
prefixes per request wastes device time (TTFT) and recomputing *and*
double-storing them wastes pool pages (admission concurrency).  This
module is the sharing layer PR 3's ``BlockPool`` was built to enable,
vLLM/SGLang-style:

* **Nodes are whole pages.**  A node at depth ``d`` keys on the exact
  ``page_size`` token ids occupying logical page ``d`` and owns one
  refcounted physical page whose KV bytes were produced by a *committed*
  computation over exactly that token history.  Page granularity keeps
  sharing trivially bit-exact: a matched page is mapped, never recomputed.
* **One tree per prompt bucket.**  Prefill KV at a position is bitwise
  invariant to the *suffix* tokens only within one compiled prompt shape
  (causal masking contributes exact zeros); across buckets the reduction
  shapes differ, so trees never share pages across buckets.
* **Lookup is longest-prefix match** (``match``), walking child pages
  until the first divergence.  A full-prompt match additionally yields the
  stored greedy continuation (``next_token``) — the engine then admits the
  request with *zero* prefill compute.  A partial match maps the covered
  pages and leaves only the uncovered suffix to compute.
* **Ownership is refcounts in the pool.**  The tree holds one reference
  per node (taken at ``insert``); each lane mapping a node's page takes
  its own (``BlockPool.share``).  Freeing is symmetric: a retiring or
  preempted lane drops its references and the tree's copy survives; an
  evicted node drops the tree's reference and an active lane's copy
  survives.  A page never reaches the free heap (and is therefore never
  scrubbed or reallocated) while any reference remains.
* **Eviction is LRU over evictable leaves** — nodes with no children
  whose page only the tree still references.  The engine calls
  ``evict_pages`` when the reserve-watermark admission gate or an urgent
  decode append would otherwise fail: cold cache is reclaimed before any
  running request is preempted.  Evicting a leaf can cascade: its parent
  may become the next evictable leaf.
* **Compaction-safe.**  ``BlockPool.compact`` moves physical pages; the
  engine applies the returned mapping to lane block tables *and* calls
  ``remap`` here, so every owner of a shared page follows it.

The tree is an index, not an owner of device memory beyond its
refcounts: all device bytes live in the engine's ``kv_pool`` buffer and
all moves/scrubs go through the engine's programs.  Methods take a lock
so router threads can probe ``match_len`` while the engine admits.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serve.kvcache import BlockPool


class _Node:
    """One cached page: ``key`` is the page's exact token ids."""

    __slots__ = ("key", "page_id", "children", "parent", "next_token",
                 "last_use")

    def __init__(self, key: Tuple[int, ...], page_id: int,
                 parent: "_Node"):
        self.key = key
        self.page_id = page_id
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        # greedy continuation after this page boundary (the first token a
        # full match can emit with no device work); None until known
        self.next_token: Optional[int] = None
        self.last_use = 0

    def depth_first(self):
        stack = [self]
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())


@dataclass
class PrefixMatch:
    """Longest-prefix lookup result (page-granular)."""
    pages: List[int] = field(default_factory=list)   # matched physical ids
    tokens: int = 0                                  # matched token count
    next_token: Optional[int] = None                 # set on a full match


class PrefixCache:
    def __init__(self, pool: BlockPool, page_size: int, *,
                 max_nodes: int = 4096):
        if max_nodes < 1:
            raise ValueError("max_nodes must be >= 1")
        self.pool = pool
        self.page_size = page_size
        self.max_nodes = max_nodes
        self._roots: Dict[int, _Node] = {}      # bucket -> sentinel root
        self._lock = threading.Lock()
        self._tick = 0                          # logical LRU clock
        self._n_nodes = 0
        # counters (engine folds these into its prefix_hit_rate gauge)
        self.lookups = 0
        self.inserts = 0
        self.evicted_nodes = 0
        self.evicted_pages = 0

    # -- lookup ----------------------------------------------------------
    def _keys(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        ps = self.page_size
        toks = [int(t) for t in tokens]
        if len(toks) % ps:
            raise ValueError(
                f"prefix cache is page-granular: {len(toks)} tokens is not "
                f"a multiple of page_size {ps}")
        return [tuple(toks[i:i + ps]) for i in range(0, len(toks), ps)]

    def match(self, bucket: int, tokens: Sequence[int]) -> PrefixMatch:
        """Longest page-aligned prefix of ``tokens`` present in the tree.

        Bumps LRU recency on every matched node.  The caller owns taking
        page references (``pool.share``) *before* mapping the pages — a
        match result is only stable until the next eviction otherwise.
        """
        keys = self._keys(tokens)
        out = PrefixMatch()
        with self._lock:
            self.lookups += 1
            self._tick += 1
            node = self._roots.get(bucket)
            if node is None:
                return out
            node.last_use = self._tick
            for key in keys:
                child = node.children.get(key)
                if child is None:
                    return out
                child.last_use = self._tick
                out.pages.append(child.page_id)
                out.tokens += self.page_size
                node = child
            out.next_token = node.next_token
        return out

    def match_len(self, bucket: int, tokens: Sequence[int]) -> int:
        """Matched-token count only — the router's routing probe.  Does
        *not* bump recency: being considered for routing is not a use."""
        ps = self.page_size
        toks = [int(t) for t in tokens[:len(tokens) - len(tokens) % ps]]
        with self._lock:
            node = self._roots.get(bucket)
            if node is None:
                return 0
            n = 0
            for i in range(0, len(toks), ps):
                child = node.children.get(tuple(toks[i:i + ps]))
                if child is None:
                    break
                n += ps
                node = child
            return n

    # -- insertion -------------------------------------------------------
    def insert(self, bucket: int, tokens: Sequence[int],
               page_ids: Sequence[int],
               next_token: Optional[int] = None) -> int:
        """Donate complete committed pages rooted at position 0.

        ``tokens`` must cover whole pages; ``page_ids[i]`` holds the KV of
        page ``i``.  New nodes take a tree-owned reference on their page
        (the caller keeps its own).  Pages whose token content is already
        cached under a different physical id are deduplicated — the
        existing node wins and the caller's copy is simply not pinned.
        ``next_token`` is the greedy continuation after the final page.
        Returns the number of nodes created.
        """
        keys = self._keys(tokens)
        if len(keys) != len(page_ids):
            raise ValueError(f"{len(keys)} pages of tokens but "
                             f"{len(page_ids)} page ids")
        created = 0
        with self._lock:
            self._tick += 1
            node = self._roots.setdefault(bucket, _Node((), -1, None))
            for i, key in enumerate(keys):
                child = node.children.get(key)
                if child is None:
                    pid = int(page_ids[i])
                    self.pool.share([pid])          # the tree's reference
                    child = _Node(key, pid, node)
                    node.children[key] = child
                    self._n_nodes += 1
                    created += 1
                child.last_use = self._tick
                hint = (int(tokens[(i + 1) * self.page_size])
                        if (i + 1) * self.page_size < len(tokens)
                        else next_token)
                if child.next_token is None and hint is not None:
                    child.next_token = int(hint)
                node = child
            self.inserts += created
            if self._n_nodes > self.max_nodes:
                self._evict_locked(self._n_nodes - self.max_nodes,
                                   count_nodes=True)
        return created

    # -- eviction --------------------------------------------------------
    def _leaves(self) -> List[_Node]:
        out = []
        for root in self._roots.values():
            out.extend(n for n in root.depth_first()
                       if n.parent is not None and not n.children)
        return out

    def _evict_locked(self, need: int, *, count_nodes: bool) -> int:
        """Drop LRU evictable leaves until ``need`` pages free (or, with
        ``count_nodes``, until ``need`` nodes dropped).  A leaf whose page
        a lane still references may be dropped from the *index* (it frees
        no memory, so it only counts under ``count_nodes``) — its page
        survives with the lane."""
        done = 0
        while done < need:
            leaves = self._leaves()
            if not count_nodes:
                leaves = [n for n in leaves
                          if self.pool.refcount(n.page_id) == 1]
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.last_use)
            del victim.parent.children[victim.key]
            self._n_nodes -= 1
            self.evicted_nodes += 1
            freed = self.pool.free([victim.page_id])
            self.evicted_pages += len(freed)
            done += 1 if count_nodes else len(freed)
        return done

    def evict_pages(self, need: int) -> int:
        """Reclaim up to ``need`` pool pages by dropping cold subtrees
        (LRU leaves first, cascading upward).  Respects refcounts: only
        pages the tree alone references can free.  Returns pages freed."""
        with self._lock:
            return self._evict_locked(need, count_nodes=False)

    # -- maintenance -----------------------------------------------------
    def remap(self, mapping: Dict[int, int]) -> None:
        """Follow a ``BlockPool.compact`` move: every node pointing at a
        moved page follows it to the new physical id."""
        if not mapping:
            return
        with self._lock:
            for root in self._roots.values():
                for n in root.depth_first():
                    if n.parent is not None:
                        n.page_id = mapping.get(n.page_id, n.page_id)

    def reclaimable_pages(self) -> int:
        """Pages that an eviction pass could return to the pool right now
        (tree-only references).  The engine advertises these as free-ish:
        they are one ``evict_pages`` call away from admission headroom."""
        with self._lock:
            count = 0
            for root in self._roots.values():
                for n in root.depth_first():
                    if (n.parent is not None
                            and self.pool.refcount(n.page_id) == 1):
                        count += 1
            return count

    def check_invariants(self) -> None:
        """Every node's page must be live in the pool (the tree holds a
        reference, so it can never be on the free heap)."""
        with self._lock:
            n = 0
            for root in self._roots.values():
                for node in root.depth_first():
                    if node.parent is None:
                        continue
                    n += 1
                    if self.pool.refcount(node.page_id) < 1:
                        raise AssertionError(
                            f"tree node holds freed page {node.page_id}")
                    if len(node.key) != self.page_size:
                        raise AssertionError("non-page-sized node key")
            if n != self._n_nodes:
                raise AssertionError(
                    f"node count drift: walked {n}, tracked {self._n_nodes}")

    def stats(self) -> dict:
        with self._lock:
            return {"nodes": self._n_nodes,
                    "buckets": len(self._roots),
                    "lookups": self.lookups,
                    "inserts": self.inserts,
                    "evicted_nodes": self.evicted_nodes,
                    "evicted_pages": self.evicted_pages}

    @property
    def nodes(self) -> int:
        with self._lock:
            return self._n_nodes
