from repro.serve.engine import (CompletedRequest, ContinuousBatchingEngine,
                                ServeRequest, SpecConfig)
from repro.serve.equivalence import (assert_transcripts_equal,
                                     check_equivalence, evict_resume_every,
                                     run_transcript)
from repro.serve.kvcache import (BlockPool, cache_bytes,
                                 init_caches_from_specs)
from repro.serve.serve_step import (generate, make_decode_step,
                                    make_prefill_step, sample_token)

__all__ = ["BlockPool", "CompletedRequest", "ContinuousBatchingEngine",
           "ServeRequest", "SpecConfig", "assert_transcripts_equal",
           "cache_bytes", "check_equivalence", "evict_resume_every",
           "generate", "init_caches_from_specs", "make_decode_step",
           "make_prefill_step", "run_transcript", "sample_token"]
