from repro.serve.engine import (CompletedRequest, ContinuousBatchingEngine,
                                ServeRequest)
from repro.serve.kvcache import (BlockPool, cache_bytes,
                                 init_caches_from_specs)
from repro.serve.serve_step import (generate, make_decode_step,
                                    make_prefill_step, sample_token)

__all__ = ["BlockPool", "CompletedRequest", "ContinuousBatchingEngine",
           "ServeRequest", "cache_bytes", "generate",
           "init_caches_from_specs", "make_decode_step", "make_prefill_step",
           "sample_token"]
