"""Prefill/decode disaggregation: the KV transfer queue.

A ``prefill`` replica admits fresh prompts (prefill is the long, bursty
EXECUTE) and, as soon as a lane's first token is committed, offers the
lane to this queue.  Admission is **TTFT-aware**: the lane moves only
when a decode replica has page headroom *and* the predicted queue wait
keeps the handoff stall under the target — otherwise the offer is
refused, the prefill replica keeps decoding the lane itself (aggregated
fallback), and the lane is offered again at the next step boundary.
Disaggregation therefore can never be slower than falling back to the
aggregated engine.

The payload (``KVHandoff``) is the lane's pages gathered into a staging
buffer by one EXECUTE (dirty-page-only serialization: exactly the pages
the lane maps, nothing else), its block-table row re-derived from fresh
pages on the importer, the committed tokens, and the prefix-tree
linkage (the exporter donates committed pages to its tree — same rule
as retire — so siblings and OOM recomputes still hit).

Greedy decode is deterministic and ``gather_lane_cache`` reassembles
the logical cache independent of physical page ids, so a handoff never
changes a single token vs. the aggregated engine.

Fault site ``kv.transfer`` fires between dequeue and install: a torn
transfer loses the lane (the prefill side already released it), so the
request replays through the router lease — zero lost, zero duplicated
tokens, bit-exact by deterministic recompute.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from repro.chaos.faults import InjectedCrash, TransientFault

M_HANDOFF = "handoff_total"
M_HANDOFF_FALLBACK = "handoff_fallback_total"
M_TRANSFER_BYTES = "kv_transfer_bytes_total"


@dataclass
class KVHandoff:
    """A serialized in-flight lane, in transit between replicas."""
    req: Any                    # the live ServeRequest (lease continuity)
    rid: str
    tokens: List[int]           # committed tokens (aliased by req.committed)
    tbts: List[float]
    pos: int                    # absolute next-write position
    bucket: int                 # prompt bucket the lane prefilled at
    limit: int                  # effective generation cap
    n_pages: int
    pages: Any                  # host pytree: (max_blocks, page_size, ...)
    admit_t: float
    first_token_t: float
    last_token_t: float
    src_engine: str
    export_t: float


class TransferQueue:
    """Moves freshly prefilled lanes from prefill to decode replicas.

    Engines join via ``engine.attach_transfer(queue)``; the prefill side
    calls ``pump_source`` at its step boundary, the decode side
    ``pump_dest`` before stepping.  ``ttft_target_s`` bounds the
    predicted transfer wait (EWMA of observed install costs × queue
    depth); offers that would blow it are refused and counted as
    fallbacks.
    """

    def __init__(self, router=None, registry=None, *, service: str = "svc",
                 ttft_target_s: Optional[float] = None, chaos=None):
        self.router = router
        self.registry = registry
        self.service = service
        self.ttft_target_s = ttft_target_s
        self.chaos = chaos
        self._clock = (registry.clock if registry is not None
                       else time.perf_counter)
        self._q: deque = deque()
        self.decode_engines: List[Any] = []
        self.source_engines: List[Any] = []
        # EWMA of the observed per-handoff install cost, seeding the
        # queue-wait prediction; None until the first install lands
        self._ewma_install_s: Optional[float] = None
        self.torn = 0
        if registry is not None:
            self._c_handoff = registry.counter(M_HANDOFF, service=service)
            self._c_fallback = registry.counter(M_HANDOFF_FALLBACK,
                                                service=service)
            self._c_bytes = registry.counter(M_TRANSFER_BYTES,
                                             service=service)
        else:
            self._c_handoff = self._c_fallback = self._c_bytes = None

    # -- membership ------------------------------------------------------
    def register(self, engine) -> None:
        side = (self.decode_engines if engine.role == "decode"
                else self.source_engines)
        if engine not in side:
            side.append(engine)

    # -- TTFT-aware admission --------------------------------------------
    def predicted_wait_s(self) -> float:
        """Predicted wait for a lane enqueued now: queue depth (plus the
        newcomer) times the EWMA install cost."""
        if self._ewma_install_s is None:
            return 0.0
        return (len(self._q) + 1) * self._ewma_install_s

    def would_admit(self, n_pages: int) -> bool:
        """True when some decode replica has headroom for an ``n_pages``
        lane — a free slot *and* free pages beyond what the already
        queued transfers will consume — and the predicted queue wait
        stays under the TTFT target (when one is set).  A slot- or
        page-saturated decode side refuses on the spot: the lane decodes
        where it is (aggregated fallback) instead of stalling in the
        queue behind lanes that retire at decode speed."""
        pending_pages = sum(h.n_pages for h in self._q)
        depth = len(self._q)
        if not any(len(e._free) > depth
                   and e.pool.can_admit(n_pages + pending_pages)
                   for e in self.decode_engines):
            return False
        if (self.ttft_target_s is not None
                and self.predicted_wait_s() > self.ttft_target_s):
            return False
        return True

    # -- prefill side ----------------------------------------------------
    def pump_source(self, engine) -> int:
        """Offer every exportable lane of a prefill replica; refused
        offers fall back to aggregated decode on the spot."""
        moved = 0
        for st in engine.exportable_lanes():
            if not self.would_admit(len(st.blocks)):
                if self._c_fallback is not None:
                    self._c_fallback.inc()
                continue
            handoff = engine.export_lane(st)
            self._q.append(handoff)
            if self._c_handoff is not None:
                self._c_handoff.inc()
            if self._c_bytes is not None:
                self._c_bytes.inc(handoff.n_pages * engine.page_bytes)
            moved += 1
        return moved

    # -- decode side -----------------------------------------------------
    def pump_dest(self, engine) -> int:
        """Install queued handoffs into a decode replica's free slots.
        A torn transfer (``kv.transfer`` fault) loses the lane in
        transit: the request replays through the router lease and
        recomputes deterministically."""
        installed = 0
        while self._q and engine._free:
            handoff = self._q[0]
            if not engine.pool.can_admit(handoff.n_pages):
                break
            self._q.popleft()
            t0 = self._clock()
            try:
                if self.chaos is not None:
                    self.chaos.raise_if("kv.transfer", key=handoff.rid)
                ok = engine.import_lane(handoff)
            except (TransientFault, InjectedCrash):
                self.torn += 1
                if self.registry is not None:
                    self.registry.record_event(
                        "kv_transfer_torn", rid=handoff.rid,
                        src=handoff.src_engine, dst=engine.engine_id)
                if self.router is not None:
                    self.router.replay_request(handoff.req)
                continue
            if not ok:
                self._q.appendleft(handoff)   # lost the slot/page race
                break
            dt = self._clock() - t0
            self._ewma_install_s = (
                dt if self._ewma_install_s is None
                else 0.9 * self._ewma_install_s + 0.1 * dt)
            if self.router is not None:
                self.router.transfer_lease(handoff.rid, engine.engine_id)
            installed += 1
        return installed

    # -- introspection ---------------------------------------------------
    def __len__(self) -> int:
        return len(self._q)

    def stats(self) -> Dict[str, Any]:
        return {"queued": len(self._q),
                "torn": self.torn,
                "ewma_install_s": self._ewma_install_s,
                "decode_engines": [e.engine_id
                                   for e in self.decode_engines],
                "source_engines": [e.engine_id
                                   for e in self.source_engines]}
