"""llava-next-mistral-7b [vlm] — Mistral-7B backbone, anyres patch embeddings.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The modality frontend (CLIP ViT + anyres tiling + projector) is a STUB per the
assignment: ``input_specs()`` provides precomputed patch embeddings of shape
(batch, num_image_tokens, d_model) that the backbone splices in front of the
text tokens.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32_000,
    mlp_kind="silu_glu",
    rope_theta=1_000_000.0,
    num_image_tokens=2880,      # anyres: 5 tiles x 576 patches
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
