from repro.configs.base import (
    SHAPES,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RecurrentConfig,
    SSMConfig,
    ShapeConfig,
    applicable,
    reduced,
)
from repro.configs.registry import ARCHS, all_cells, get_arch, get_shape

__all__ = [
    "ARCHS",
    "SHAPES",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "RecurrentConfig",
    "SSMConfig",
    "ShapeConfig",
    "all_cells",
    "applicable",
    "get_arch",
    "get_shape",
    "reduced",
]
