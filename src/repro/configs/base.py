"""Config dataclasses shared by every architecture.

Design notes
------------
* ``ModelConfig`` is a frozen dataclass covering every model family in the
  assigned pool (dense/GQA, MLA+MoE, SSM, RG-LRU hybrid, enc-dec, VLM).
  Family-specific fields default to "off" so each arch file only states what
  it uses.
* ``ShapeConfig`` is one of the four assigned input shapes.  ``kind`` selects
  which step function the dry-run lowers (train_step vs serve prefill/decode).
* ``reduced()`` produces the smoke-test variant of a config: same family
  features (MoE routing, MLA projections, SSD scan, hybrid pattern, ...) at
  toy width so a single CPU device can run a real forward/backward step.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (DeepSeek-style fine-grained MoE)."""

    num_experts: int = 0            # routed experts
    num_shared_experts: int = 0     # always-on experts
    top_k: int = 0
    d_ff: int = 0                   # per-expert hidden dim
    n_dense_layers: int = 0         # leading layers that use a dense FFN
    dense_d_ff: int = 0             # hidden dim of those dense layers
    capacity_factor: float = 1.25   # capacity-based dispatch (GShard-style)
    router_aux_weight: float = 0.001

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2/V3)."""

    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    @property
    def enabled(self) -> bool:
        return self.kv_lora_rank > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD settings."""

    d_state: int = 0
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    @property
    def enabled(self) -> bool:
        return self.d_state > 0


@dataclass(frozen=True)
class RecurrentConfig:
    """RG-LRU (RecurrentGemma/Griffin) settings."""

    lru_width: int = 0
    conv_width: int = 4
    # block pattern, cycled over layers: "r" = recurrent block, "a" = attention
    block_pattern: Tuple[str, ...] = ()

    @property
    def enabled(self) -> bool:
        return self.lru_width > 0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # ---- attention features -------------------------------------------------
    qk_norm: bool = False           # qwen3-style per-head RMSNorm on q/k
    rope_theta: float = 10000.0
    rope_pct: float = 1.0           # stablelm uses partial rotary (25%)
    sliding_window: int = 0         # 0 -> full attention
    attn_logit_softcap: float = 0.0

    # ---- MLP ----------------------------------------------------------------
    mlp_kind: str = "silu_glu"      # silu_glu | geglu | gelu
    norm_kind: str = "rmsnorm"      # rmsnorm | layernorm
    tie_embeddings: bool = False

    # ---- family sub-configs ---------------------------------------------------
    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    rec: RecurrentConfig = field(default_factory=RecurrentConfig)

    # ---- enc-dec ----------------------------------------------------------------
    encoder_layers: int = 0         # >0 -> encoder-decoder; num_layers = decoder
    # ratio of target length to source length for enc-dec training shapes
    tgt_ratio: float = 0.25

    # ---- VLM ---------------------------------------------------------------------
    num_image_tokens: int = 0       # >0 -> precomputed patch embeddings spliced

    # ---- numerics -----------------------------------------------------------------
    dtype: str = "bfloat16"         # activations/params compute dtype

    # ---- runtime/layout choices (overridden per run, not per arch) -----------
    moe_dispatch: str = "local"     # local | a2a (2D expert parallelism)

    # ---- provenance -----------------------------------------------------------
    source: str = ""                # citation tag from the assignment table

    # ------------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve a 500k-token context?"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline cross-checks)."""
        from repro.models.model_zoo import analytic_param_count

        return analytic_param_count(self)

    def active_param_count(self) -> int:
        from repro.models.model_zoo import analytic_param_count

        return analytic_param_count(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                       # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        """Tokens processed per step (decode: one new token per sequence)."""
        if self.kind == "decode":
            return self.global_batch
        return self.seq_len * self.global_batch


# The four assigned input shapes (identical for all 10 LM-family archs).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch, shape) cell runs, per the assignment rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention; %s is full-attention" % cfg.family
    return True, ""


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant: same family features, toy width."""
    ch: dict = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 4),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
    )
    if cfg.moe.enabled:
        ch["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=8,
            num_shared_experts=min(cfg.moe.num_shared_experts, 2),
            top_k=2,
            d_ff=32,
            n_dense_layers=min(cfg.moe.n_dense_layers, 1),
            dense_d_ff=128,
        )
    if cfg.mla.enabled:
        ch["mla"] = MLAConfig(
            q_lora_rank=32, kv_lora_rank=32,
            qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        )
    if cfg.ssm.enabled:
        ch["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk_size=32)
    if cfg.rec.enabled:
        ch["rec"] = dataclasses.replace(cfg.rec, lru_width=64, conv_width=4)
        ch["num_layers"] = max(len(cfg.rec.block_pattern), 3)
    if cfg.encoder_layers:
        ch["encoder_layers"] = 2
    if cfg.num_image_tokens:
        ch["num_image_tokens"] = 8
    return dataclasses.replace(cfg, **ch)
