"""deepseek-v3-671b [moe] — MLA + 1 shared / 256 routed top-8 fine-grained MoE.

61L d_model=7168 128H d_ff=2048(expert) vocab=129280
[arXiv:2412.19437; hf]

MLA: q_lora_rank=1536, kv_lora_rank=512, qk_nope=128, qk_rope=64, v_head=128.
First 3 layers use a dense FFN (d_ff=18432).  The MTP (multi-token prediction)
auxiliary head is out of scope (DESIGN.md §7).
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,           # MLA decompresses to full heads
    head_dim=128,
    d_ff=2048,                  # routed-expert hidden dim (per assignment)
    vocab_size=129_280,
    mlp_kind="silu_glu",
    rope_theta=10_000.0,
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256,
        num_shared_experts=1,
        top_k=8,
        d_ff=2048,
        n_dense_layers=3,
        dense_d_ff=18432,
    ),
    source="arXiv:2412.19437; hf",
)
