"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2 recurrent : 1 attn.

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000
[arXiv:2402.19427; unverified]
"""

from repro.configs.base import ModelConfig, RecurrentConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    sliding_window=2048,        # local attention window for the "a" blocks
    mlp_kind="geglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    rec=RecurrentConfig(
        lru_width=4096,
        conv_width=4,
        block_pattern=("r", "r", "a"),
    ),
    source="arXiv:2402.19427; unverified",
)
