"""Architecture registry: ``--arch <id>`` resolution for every entry point."""

from __future__ import annotations

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, applicable, reduced
from repro.configs.deepseek_moe_16b import CONFIG as _deepseek_moe_16b
from repro.configs.deepseek_v3_671b import CONFIG as _deepseek_v3_671b
from repro.configs.llava_next_mistral_7b import CONFIG as _llava_next_mistral_7b
from repro.configs.mamba2_1_3b import CONFIG as _mamba2_1_3b
from repro.configs.qwen3_8b import CONFIG as _qwen3_8b
from repro.configs.recurrentgemma_9b import CONFIG as _recurrentgemma_9b
from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless_m4t_large_v2
from repro.configs.stablelm_3b import CONFIG as _stablelm_3b
from repro.configs.starcoder2_15b import CONFIG as _starcoder2_15b
from repro.configs.yi_9b import CONFIG as _yi_9b

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _recurrentgemma_9b,
        _yi_9b,
        _stablelm_3b,
        _qwen3_8b,
        _starcoder2_15b,
        _llava_next_mistral_7b,
        _deepseek_v3_671b,
        _deepseek_moe_16b,
        _seamless_m4t_large_v2,
        _mamba2_1_3b,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return reduced(get_arch(name[: -len("-smoke")]))
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells() -> list[tuple[ModelConfig, ShapeConfig, bool, str]]:
    """All 40 (arch x shape) cells with applicability verdicts."""
    cells = []
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            ok, why = applicable(arch, shape)
            cells.append((arch, shape, ok, why))
    return cells
