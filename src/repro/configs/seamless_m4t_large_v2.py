"""seamless-m4t-large-v2 [audio] — encoder-decoder transformer backbone.

24L d_model=1024 16H (MHA) d_ff=8192 vocab=256206
[arXiv:2308.11596; unverified]

We model the text-to-text backbone: a 24-layer encoder + 24-layer decoder with
cross-attention.  The speech frontend (w2v-BERT conformer) is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings
(batch, src_len, d_model) consumed directly by the encoder.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,              # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    mlp_kind="gelu",
    norm_kind="layernorm",
    tgt_ratio=0.25,             # target length = seq_len/4 for train shapes
    source="arXiv:2308.11596; unverified",
)
