"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

48L d_model=2048 vocab=50280 ssm_state=128
[arXiv:2405.21060; unverified]

d_inner = expand * d_model = 4096, head_dim = 64 -> 64 SSD heads, conv width 4,
chunk size 256 for the chunked SSD scan.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=1,                # unused by SSD blocks
    num_kv_heads=1,
    d_ff=0,                     # attention-free, no separate MLP block
    vocab_size=50_280,
    tie_embeddings=True,
    ssm=SSMConfig(
        d_state=128,
        d_conv=4,
        expand=2,
        head_dim=64,
        chunk_size=256,
    ),
    source="arXiv:2405.21060; unverified",
)
