"""starcoder2-15b [dense] — GQA, RoPE, non-gated GELU MLP, LayerNorm.

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152
[arXiv:2402.19173; hf]

Note: the released model uses a 4k sliding window; the assignment classifies it
as a full-attention dense arch, so we model full attention (long_500k skipped
either way — see DESIGN.md §4).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49_152,
    mlp_kind="gelu",
    norm_kind="layernorm",
    rope_theta=100_000.0,
    source="arXiv:2402.19173; hf",
)
