"""deepseek-moe-16b [moe] — fine-grained MoE, 2 shared + 64 routed top-6.

28L d_model=2048 16H (MHA) d_ff=1408(expert) vocab=102400
[arXiv:2401.06066; hf]

First layer uses a dense FFN (d_ff=10944).
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102_400,
    mlp_kind="silu_glu",
    rope_theta=10_000.0,
    moe=MoEConfig(
        num_experts=64,
        num_shared_experts=2,
        top_k=6,
        d_ff=1408,
        n_dense_layers=1,
        dense_d_ff=10944,
    ),
    source="arXiv:2401.06066; hf",
)
