"""Resharding loader: re-materialize checkpointed state on a different mesh.

Checkpoints store *global* host arrays (per-buffer files), so restoring onto
a different mesh shape — vertical scaling (``update``), migration to a
bigger/smaller slice, or elastic recovery after node loss — is a
``jax.device_put`` with the target ``NamedSharding``s.  The ``ShardingRules``
recompute the PartitionSpecs for the new mesh; dimensions that no longer
divide the axis sizes fall back to replication automatically.
"""

from __future__ import annotations

from typing import Any, Optional

import jax

from repro.sharding.rules import ShardingRules


def reshard_tree(host_tree: Any, shardings: Any) -> Any:
    """device_put each leaf with its target sharding."""
    return jax.tree.map(jax.device_put, host_tree, shardings)


def reshard_params(cfg, host_params: Any, new_mesh,
                   policy: str = "fsdp_tp") -> Any:
    rules = ShardingRules(cfg, new_mesh, policy)
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), host_params)
    shardings = rules.param_shardings(abstract)
    return reshard_tree(host_params, shardings)


def reshard_snapshot_buffers(cfg, buffers: dict, new_mesh,
                             policy: str = "fsdp_tp") -> dict:
    """Reshard the checkpointed buffer dict; params/opt get param rules,
    other buffers are placed replicated (they are small or re-created)."""
    out = {}
    for buff_id, tree in buffers.items():
        if buff_id in ("params",):
            out[buff_id] = reshard_params(cfg, tree, new_mesh, policy)
        elif buff_id in ("opt_state",):
            # moments share the param layout
            rules = ShardingRules(cfg, new_mesh, policy)
            abstract = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
            m_abs = abstract.get("m") if isinstance(abstract, dict) else None
            if m_abs is not None:
                sh = rules.param_shardings(m_abs)
                out[buff_id] = {
                    "m": reshard_tree(tree["m"], sh),
                    "v": reshard_tree(tree["v"], sh),
                    "count": jax.device_put(tree["count"]),
                }
            else:
                out[buff_id] = jax.device_put(tree)
        else:
            out[buff_id] = jax.device_put(tree)
    return out
