"""Sharded, incremental, async-capable, crash-consistent checkpointing.

Layout of one checkpoint directory (on-disk format v2)::

    <path>/manifest.json       step, guest state, buffer index, versions,
                               per-file sha256 digests, prev_path chain link
    <path>/image.pkl           TaskImage (how to re-instantiate the guest)
    <path>/guest.pkl           full-fidelity guest (VM) state
    <path>/specs.pkl           buffer spec map
    <path>/<buff>.npz          flattened pytree leaves (one file per buffer)
    <path>/<buff>.treedef      pickled treedef (exact pytree structure)

**Crash consistency**: everything is written into a hidden ``.tmp-*``
sibling directory first (invisible to ``snapshot_candidates``), each file
is fsync'd, the manifest is written *last* via temp-file + ``os.replace``,
and only then is the directory atomically renamed into place.  A crash at
any byte leaves either the previous snapshot or debris that is never
discoverable as valid.

**Integrity**: the manifest records a sha256 per payload file.
``load_snapshot`` verifies them and raises ``CheckpointCorruptError``
naming the offending buffer/file — a truncated or bit-flipped checkpoint
is never restored silently.  ``load_latest_good`` walks the incremental
``prev_path`` chain back to the last snapshot that verifies.

**Incremental**: pass ``prev_path`` — buffers whose write-version is
unchanged since the previous checkpoint are *referenced*, not rewritten
(the on-disk analogue of the paper's dirty-only eviction, §3.4); their
digests carry over so a rotted ancestor file is still caught.

**Async**: ``AsyncCheckpointer`` runs ``save_snapshot`` on a background
thread so training continues while bytes hit the disk; ``wait()`` joins
before the next snapshot (checkpoint/compute overlap).
"""

from __future__ import annotations

import glob as _glob
import hashlib
import json
import os
import pickle
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.state import GuestState, TaskSnapshot


class CheckpointCorruptError(RuntimeError):
    """A snapshot failed integrity verification: missing/truncated/
    bit-flipped file or unreadable manifest.  The message names the
    offending buffer and path so operators can see *what* rotted."""


_VIEW_FOR_ITEMSIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _write_tree(path_prefix: str, tree: Any) -> int:
    """npz stores leaves; non-native dtypes (bfloat16, ...) are stored as a
    same-itemsize unsigned view with the true dtype recorded in the sidecar."""
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {}
    dtypes = []
    for i, l in enumerate(leaves):
        a = np.asarray(l)
        dtypes.append(a.dtype.str if a.dtype.kind != "V" else str(a.dtype))
        if a.dtype.kind == "V" or not a.dtype.isnative:
            a = a.view(_VIEW_FOR_ITEMSIZE[a.dtype.itemsize])
        arrays[f"leaf_{i:05d}"] = a
    np.savez(path_prefix + ".npz", **arrays)
    with open(path_prefix + ".treedef", "wb") as f:
        pickle.dump((treedef, dtypes), f)
    return sum(a.nbytes for a in arrays.values())


def _read_tree(path_prefix: str) -> Any:
    with open(path_prefix + ".treedef", "rb") as f:
        treedef, dtypes = pickle.load(f)
    with np.load(path_prefix + ".npz") as z:
        leaves = []
        for k, dt in zip(sorted(z.files), dtypes):
            a = z[k]
            want = np.dtype(dt)
            if a.dtype != want:
                a = a.view(want)
            leaves.append(a)
    return jax.tree.unflatten(treedef, leaves)


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _fsync_file(path: str) -> None:
    with open(path, "rb") as f:
        os.fsync(f.fileno())


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _peek_manifest(path: str) -> Optional[dict]:
    """Best-effort manifest read (chain walking); None when unreadable."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def snapshot_candidates(roots, cid: str) -> List[str]:
    """Published snapshot dirs for ``cid`` under the given ckpt root(s),
    newest step first.  ``.tmp-*`` write debris never matches; steps sort
    numerically (``step10`` after ``step9``)."""
    if isinstance(roots, str):
        roots = [roots]
    hits = []
    for root in roots:
        for p in _glob.glob(os.path.join(root, f"{cid}-step*")):
            try:
                step = int(p.rsplit("-step", 1)[1])
            except ValueError:
                continue
            hits.append((step, p))
    return [p for _, p in sorted(hits, reverse=True)]


def save_snapshot(path: str, snap: TaskSnapshot, image=None,
                  prev_path: Optional[str] = None, chaos=None) -> dict:
    """Crash-consistently write a snapshot; returns stats
    {written_bytes, reused_buffers, seconds}.

    ``chaos`` (a ``repro.chaos.FaultPlan``) may fire ``ckpt.save`` (torn
    write — raises mid-stream with nothing published) or ``ckpt.corrupt``
    (post-publish bit flip in one buffer file, caught by digests)."""
    t0 = time.perf_counter()
    path = os.path.abspath(path)
    if prev_path is not None and os.path.abspath(prev_path) == path:
        prev_path = None                   # re-checkpoint of the same step
    parent = os.path.dirname(path)
    os.makedirs(parent, exist_ok=True)

    prev_index: dict = {}
    prev_versions: dict = {}
    prev_digests: dict = {}
    if prev_path and os.path.exists(os.path.join(prev_path, "manifest.json")):
        with open(os.path.join(prev_path, "manifest.json")) as f:
            prev = json.load(f)
        prev_index = prev.get("buffers", {})
        prev_versions = prev.get("versions", {})
        prev_digests = prev.get("digests", {})

    # hidden tmp dir: the leading dot keeps write debris out of the
    # "<cid>-step*" discovery glob if we crash before the publish rename
    tmp = tempfile.mkdtemp(prefix=".tmp-" + os.path.basename(path) + "-",
                           dir=parent)
    try:
        index = {}
        digests: Dict[str, dict] = {}
        written = 0
        reused = 0
        for buff_id, tree in snap.buffers.items():
            version = snap.versions.get(buff_id, -1)
            if (buff_id in prev_index
                    and prev_versions.get(buff_id) == version
                    and version >= 0):
                index[buff_id] = prev_index[buff_id]  # reference, not rewrite
                if buff_id in prev_digests:
                    digests[buff_id] = prev_digests[buff_id]
                reused += 1
                continue
            if chaos is not None:
                chaos.raise_if("ckpt.save", key=f"{path}:{buff_id}")
            name = buff_id.replace("/", "_")
            written += _write_tree(os.path.join(tmp, name), tree)
            for ext in (".npz", ".treedef"):
                _fsync_file(os.path.join(tmp, name + ext))
            # the manifest records the *final* location; files move there
            # with the directory rename
            index[buff_id] = os.path.join(path, name)
            digests[buff_id] = {
                ext.lstrip("."): _sha256(os.path.join(tmp, name + ext))
                for ext in (".npz", ".treedef")}

        # Full-fidelity guest (VM) state (may contain arrays, e.g. results
        # a guest extracted before teardown) goes to a pickle; the manifest
        # keeps a human-readable summary.
        file_digests = {}
        sidecars = [("guest.pkl", snap.guest_state),
                    ("specs.pkl", snap.buffer_specs)]
        if image is not None:
            sidecars.append(("image.pkl", image))
        for fname, obj in sidecars:
            fpath = os.path.join(tmp, fname)
            with open(fpath, "wb") as f:
                pickle.dump(obj, f)
            _fsync_file(fpath)
            file_digests[fname] = _sha256(fpath)
        if chaos is not None:
            chaos.raise_if("ckpt.save", key=f"{path}:manifest")
        manifest = {
            "format": 2,
            "task_id": snap.task_id,
            "step": snap.step,
            "created_at": snap.created_at,
            "program_ids": list(snap.program_ids),
            "guest_state": {
                "step": snap.guest_state.step,
                "seed": snap.guest_state.seed,
                "data_position": snap.guest_state.data_position,
                "user_keys": sorted(snap.guest_state.user),
            },
            "buffers": index,
            "versions": snap.versions,
            "digests": digests,
            "file_digests": file_digests,
            "prev_path": (os.path.abspath(prev_path)
                          if prev_path else None),
        }
        # manifest last, atomically: its existence is what makes the
        # directory a valid snapshot
        mtmp = os.path.join(tmp, "manifest.json.tmp")
        with open(mtmp, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(mtmp, os.path.join(tmp, "manifest.json"))
        _fsync_dir(tmp)
    except BaseException:
        # a *real* caller error should not leave debris; an injected torn
        # write keeps it (that is the point — restore must cope)
        if chaos is None:
            shutil.rmtree(tmp, ignore_errors=True)
        raise

    # publish: atomic directory rename (same-step overwrite moves the old
    # dir aside first — nothing newer can reference a same-step path)
    if os.path.exists(path):
        aside = tmp + ".old"
        os.rename(path, aside)
        os.rename(tmp, path)
        shutil.rmtree(aside, ignore_errors=True)
    else:
        os.rename(tmp, path)
    _fsync_dir(parent)

    if chaos is not None and chaos.check("ckpt.corrupt", key=path):
        _corrupt_one_file(path, chaos.rng)
    return {"written_bytes": written, "reused_buffers": reused,
            "seconds": time.perf_counter() - t0}


def _corrupt_one_file(path: str, rng) -> None:
    """Bit-rot simulation: flip one byte mid-file in a (seeded-)random
    buffer file of a published snapshot."""
    files = sorted(_glob.glob(os.path.join(path, "*.npz")))
    if not files:
        return
    victim = files[rng.randrange(len(files))]
    with open(victim, "r+b") as f:
        f.seek(0, os.SEEK_END)
        size = f.tell()
        if size == 0:
            return
        off = size // 2
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ 0xFF]))


def _verify_file(path: str, want: Optional[str], what: str) -> None:
    if not os.path.exists(path):
        raise CheckpointCorruptError(f"{what}: missing file {path}")
    if want is not None and _sha256(path) != want:
        raise CheckpointCorruptError(
            f"{what}: digest mismatch in {path} (truncated or corrupt)")


def load_snapshot(path: str, verify: bool = True) -> Tuple[TaskSnapshot, Any]:
    """Load and (for format-2 manifests) digest-verify one snapshot.

    Raises ``CheckpointCorruptError`` naming the offending buffer/file on
    any integrity failure — including a missing ``prev_path``-referenced
    incremental buffer — instead of surfacing raw ``FileNotFoundError`` /
    ``KeyError`` / ``BadZipFile`` from deep inside ``np.load``."""
    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        raise CheckpointCorruptError(
            f"{path}: manifest.json missing (torn or unpublished snapshot)")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise CheckpointCorruptError(
            f"{path}: unreadable manifest.json ({e})") from e

    digests = manifest.get("digests", {}) if verify else {}
    file_digests = manifest.get("file_digests", {}) if verify else {}
    buffers = {}
    for buff_id, prefix in manifest["buffers"].items():
        d = digests.get(buff_id) or {}
        for ext in (".npz", ".treedef"):
            _verify_file(prefix + ext, d.get(ext.lstrip(".")),
                         f"buffer {buff_id!r}")
        try:
            buffers[buff_id] = _read_tree(prefix)
        except CheckpointCorruptError:
            raise
        except Exception as e:  # noqa: BLE001 - zip/pickle/shape errors
            raise CheckpointCorruptError(
                f"buffer {buff_id!r}: unreadable at {prefix} ({e!r})") from e

    def _load_pickle(fname: str, required: bool):
        fpath = os.path.join(path, fname)
        if not os.path.exists(fpath):
            if required:
                raise CheckpointCorruptError(f"{path}: missing {fname}")
            return None
        _verify_file(fpath, file_digests.get(fname), fname)
        try:
            with open(fpath, "rb") as f:
                return pickle.load(f)
        except Exception as e:  # noqa: BLE001
            raise CheckpointCorruptError(
                f"{path}: unreadable {fname} ({e!r})") from e

    guest_state = _load_pickle("guest.pkl", required=False)
    if guest_state is None:  # legacy manifests
        gs = manifest["guest_state"]
        guest_state = GuestState(step=gs["step"], seed=gs["seed"],
                                 data_position=gs["data_position"],
                                 user=dict(gs.get("user", {})))
    specs = _load_pickle("specs.pkl", required=False) or {}
    snap = TaskSnapshot(
        task_id=manifest["task_id"],
        guest_state=guest_state,
        buffers=buffers,
        buffer_specs=specs,
        program_ids=tuple(manifest["program_ids"]),
        created_at=manifest["created_at"],
        step=manifest["step"],
        versions={k: int(v) for k, v in manifest.get("versions", {}).items()},
    )
    image = _load_pickle("image.pkl", required=False)
    return snap, image


def load_latest_good(path: str) -> Tuple[TaskSnapshot, Any, str, list]:
    """Load ``path`` or, when it fails verification, walk the incremental
    ``prev_path`` chain back to the last-good ancestor.

    Returns ``(snap, image, used_path, skipped)`` where ``skipped`` is a
    list of ``(path, reason)`` for every corrupt snapshot passed over.
    Raises ``CheckpointCorruptError`` (listing everything tried) when no
    ancestor verifies."""
    cur: Optional[str] = path
    skipped: list = []
    seen = set()
    while cur is not None and cur not in seen:
        seen.add(cur)
        try:
            snap, image = load_snapshot(cur)
            return snap, image, cur, skipped
        except CheckpointCorruptError as e:
            skipped.append((cur, str(e)))
            m = _peek_manifest(cur)
            cur = m.get("prev_path") if m else None
    tried = "; ".join(f"{p}: {r}" for p, r in skipped)
    raise CheckpointCorruptError(
        f"no restorable snapshot in chain starting at {path} ({tried})")


class AsyncCheckpointer:
    """Overlap checkpoint I/O with compute (one outstanding save)."""

    def __init__(self, chaos=None):
        self._thread: Optional[threading.Thread] = None
        self._last_stats: Optional[dict] = None
        self._error: Optional[BaseException] = None
        self.chaos = chaos

    def save(self, path: str, snap: TaskSnapshot, image=None,
             prev_path: Optional[str] = None):
        self.wait()

        def run():
            try:
                self._last_stats = save_snapshot(path, snap, image,
                                                 prev_path,
                                                 chaos=self.chaos)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> Optional[dict]:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e
        return self._last_stats
