"""Sharded, incremental, async-capable checkpointing.

Layout of one checkpoint directory::

    <path>/manifest.json       step, guest state, buffer index, versions
    <path>/image.pkl           TaskImage (how to re-instantiate the guest)
    <path>/<buff>.npz          flattened pytree leaves (one file per buffer)
    <path>/<buff>.treedef      pickled treedef (exact pytree structure)

**Incremental**: pass ``prev_path`` — buffers whose write-version is
unchanged since the previous checkpoint are *referenced*, not rewritten
(the on-disk analogue of the paper's dirty-only eviction, §3.4).

**Async**: ``AsyncCheckpointer`` runs ``save_snapshot`` on a background
thread so training continues while bytes hit the disk; ``wait()`` joins
before the next snapshot (checkpoint/compute overlap).
"""

from __future__ import annotations

import json
import os
import pickle
import threading
import time
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.core.state import GuestState, TaskSnapshot


_VIEW_FOR_ITEMSIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _write_tree(path_prefix: str, tree: Any) -> int:
    """npz stores leaves; non-native dtypes (bfloat16, ...) are stored as a
    same-itemsize unsigned view with the true dtype recorded in the sidecar."""
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {}
    dtypes = []
    for i, l in enumerate(leaves):
        a = np.asarray(l)
        dtypes.append(a.dtype.str if a.dtype.kind != "V" else str(a.dtype))
        if a.dtype.kind == "V" or not a.dtype.isnative:
            a = a.view(_VIEW_FOR_ITEMSIZE[a.dtype.itemsize])
        arrays[f"leaf_{i:05d}"] = a
    np.savez(path_prefix + ".npz", **arrays)
    with open(path_prefix + ".treedef", "wb") as f:
        pickle.dump((treedef, dtypes), f)
    return sum(a.nbytes for a in arrays.values())


def _read_tree(path_prefix: str) -> Any:
    with open(path_prefix + ".treedef", "rb") as f:
        treedef, dtypes = pickle.load(f)
    with np.load(path_prefix + ".npz") as z:
        leaves = []
        for k, dt in zip(sorted(z.files), dtypes):
            a = z[k]
            want = np.dtype(dt)
            if a.dtype != want:
                a = a.view(want)
            leaves.append(a)
    return jax.tree.unflatten(treedef, leaves)


def save_snapshot(path: str, snap: TaskSnapshot, image=None,
                  prev_path: Optional[str] = None) -> dict:
    """Write a snapshot; returns stats {written_bytes, reused_buffers, seconds}."""
    t0 = time.perf_counter()
    os.makedirs(path, exist_ok=True)

    prev_index: dict = {}
    prev_versions: dict = {}
    if prev_path and os.path.exists(os.path.join(prev_path, "manifest.json")):
        with open(os.path.join(prev_path, "manifest.json")) as f:
            prev = json.load(f)
        prev_index = prev.get("buffers", {})
        prev_versions = prev.get("versions", {})

    index = {}
    written = 0
    reused = 0
    for buff_id, tree in snap.buffers.items():
        version = snap.versions.get(buff_id, -1)
        if (buff_id in prev_index and prev_versions.get(buff_id) == version
                and version >= 0):
            index[buff_id] = prev_index[buff_id]     # reference, don't rewrite
            reused += 1
            continue
        prefix = os.path.join(path, buff_id.replace("/", "_"))
        written += _write_tree(prefix, tree)
        index[buff_id] = prefix

    # Full-fidelity guest (VM) state (may contain arrays, e.g. results a
    # guest extracted before teardown) goes to a pickle; the manifest keeps
    # a human-readable summary.
    with open(os.path.join(path, "guest.pkl"), "wb") as f:
        pickle.dump(snap.guest_state, f)
    with open(os.path.join(path, "specs.pkl"), "wb") as f:
        pickle.dump(snap.buffer_specs, f)
    manifest = {
        "task_id": snap.task_id,
        "step": snap.step,
        "created_at": snap.created_at,
        "program_ids": list(snap.program_ids),
        "guest_state": {
            "step": snap.guest_state.step,
            "seed": snap.guest_state.seed,
            "data_position": snap.guest_state.data_position,
            "user_keys": sorted(snap.guest_state.user),
        },
        "buffers": index,
        "versions": snap.versions,
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if image is not None:
        with open(os.path.join(path, "image.pkl"), "wb") as f:
            pickle.dump(image, f)
    return {"written_bytes": written, "reused_buffers": reused,
            "seconds": time.perf_counter() - t0}


def load_snapshot(path: str) -> Tuple[TaskSnapshot, Any]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    buffers = {b: _read_tree(prefix)
               for b, prefix in manifest["buffers"].items()}
    gs_path = os.path.join(path, "guest.pkl")
    if os.path.exists(gs_path):
        with open(gs_path, "rb") as f:
            guest_state = pickle.load(f)
    else:  # legacy manifests
        gs = manifest["guest_state"]
        guest_state = GuestState(step=gs["step"], seed=gs["seed"],
                                 data_position=gs["data_position"],
                                 user=dict(gs.get("user", {})))
    specs = {}
    sp = os.path.join(path, "specs.pkl")
    if os.path.exists(sp):
        with open(sp, "rb") as f:
            specs = pickle.load(f)
    snap = TaskSnapshot(
        task_id=manifest["task_id"],
        guest_state=guest_state,
        buffers=buffers,
        buffer_specs=specs,
        program_ids=tuple(manifest["program_ids"]),
        created_at=manifest["created_at"],
        step=manifest["step"],
        versions={k: int(v) for k, v in manifest.get("versions", {}).items()},
    )
    image = None
    img_path = os.path.join(path, "image.pkl")
    if os.path.exists(img_path):
        with open(img_path, "rb") as f:
            image = pickle.load(f)
    return snap, image


class AsyncCheckpointer:
    """Overlap checkpoint I/O with compute (one outstanding save)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._last_stats: Optional[dict] = None
        self._error: Optional[BaseException] = None

    def save(self, path: str, snap: TaskSnapshot, image=None,
             prev_path: Optional[str] = None):
        self.wait()

        def run():
            try:
                self._last_stats = save_snapshot(path, snap, image, prev_path)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> Optional[dict]:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e
        return self._last_stats
