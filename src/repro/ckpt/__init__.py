from repro.ckpt.checkpoint import (AsyncCheckpointer, load_snapshot,
                                   save_snapshot)
from repro.ckpt.resharding import (reshard_params, reshard_snapshot_buffers,
                                   reshard_tree)

__all__ = ["AsyncCheckpointer", "load_snapshot", "reshard_params",
           "reshard_snapshot_buffers", "reshard_tree", "save_snapshot"]
