from repro.ckpt.checkpoint import (AsyncCheckpointer, CheckpointCorruptError,
                                   load_latest_good, load_snapshot,
                                   save_snapshot, snapshot_candidates)
from repro.ckpt.resharding import (reshard_params, reshard_snapshot_buffers,
                                   reshard_tree)

__all__ = ["AsyncCheckpointer", "CheckpointCorruptError", "load_latest_good",
           "load_snapshot", "reshard_params", "reshard_snapshot_buffers",
           "reshard_tree", "save_snapshot", "snapshot_candidates"]
