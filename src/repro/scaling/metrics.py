"""Dependency-free telemetry registry shared by both execution planes.

The live runtime (``Monitor``, ``NodeAgent``, ``Orchestrator``) and the
discrete-event ``Simulator`` publish into the *same* metric types with the
*same* naming schema; the only difference is the injected clock — wall time
for the live plane, the simulator's virtual ``now`` for replayed traces.
That symmetry is what lets the autoscaler (and Fig 14) run unchanged against
either plane, mirroring how the paper drives the trace simulator with the
overheads measured on the live runtime (§5.6).

Types:

* ``Counter``      monotonically increasing float (requests_total, ...)
* ``Gauge``        last-write-wins float (queue_depth, replicas, ...)
* ``Histogram``    windowed samples with p50/p95/p99 (request latency)
* ``TimeSeries``   fixed-capacity ring buffer of (t, value) observations

All metrics are identified by ``name`` plus sorted key=value labels, printed
Prometheus-style: ``request_latency_seconds{service=svc}``.
"""

from __future__ import annotations

import math
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

Clock = Callable[[], float]


def metric_key(name: str, labels: Dict[str, str]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    def __init__(self):
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0):
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self.value += amount


class Gauge:
    def __init__(self):
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float):
        self.value = float(value)

    def add(self, delta: float):
        with self._lock:
            self.value += delta


class Histogram:
    """Sliding-window sample reservoir with exact quantiles.

    Samples older than ``window_s`` (by the registry clock) are evicted
    lazily on observe/quantile; a bounded ring keeps worst-case memory flat
    under sustained load. Cumulative count/sum survive eviction so rates can
    still be derived from snapshots.
    """

    def __init__(self, clock: Clock, window_s: float = 60.0,
                 max_samples: int = 4096):
        self._clock = clock
        self.window_s = window_s
        self._samples: deque = deque(maxlen=max_samples)   # (t, value)
        self.count = 0            # cumulative, never evicted
        self.sum = 0.0
        # writers (monitor workers, drive loop) race readers (autoscaler
        # reconcile thread) on the deque; guard every touch
        self._lock = threading.Lock()

    def observe(self, value: float):
        now = self._clock()
        with self._lock:
            self.count += 1
            self.sum += value
            self._samples.append((now, float(value)))
            self._prune(now)

    def _prune(self, now: float):
        cutoff = now - self.window_s
        while self._samples and self._samples[0][0] < cutoff:
            self._samples.popleft()

    def window_values(self) -> List[float]:
        with self._lock:
            self._prune(self._clock())
            return [v for _, v in self._samples]

    def quantile(self, q: float) -> float:
        """Linear-interpolated quantile over the current window.

        Sentinel contract: an *empty* window (nothing observed yet, or all
        samples pruned by ``window_s``) returns ``math.nan`` — never raises
        and never reports a stale value.  Consumers (autoscaler signals,
        the Prometheus exporter) must treat NaN as "no data".  ``q`` is
        clamped to [0, 1] so an out-of-range request cannot index past the
        sample list."""
        vals = sorted(self.window_values())
        if not vals:
            return math.nan
        if len(vals) == 1:
            return vals[0]
        q = min(1.0, max(0.0, q))
        pos = q * (len(vals) - 1)
        lo = int(math.floor(pos))
        hi = min(lo + 1, len(vals) - 1)
        frac = pos - lo
        return vals[lo] * (1 - frac) + vals[hi] * frac

    def summary(self) -> dict:
        """Windowed summary.  On an empty (fully pruned) window every
        statistic is the NaN sentinel while cumulative ``count``/``sum``
        survive and ``window_count`` is 0 — same contract as
        ``quantile``."""
        vals = self.window_values()
        out = {"count": self.count, "sum": self.sum,
               "window_count": len(vals)}
        if vals:
            out.update(mean=sum(vals) / len(vals), max=max(vals),
                       p50=self.quantile(0.50), p95=self.quantile(0.95),
                       p99=self.quantile(0.99))
        else:
            out.update(mean=math.nan, max=math.nan, p50=math.nan,
                       p95=math.nan, p99=math.nan)
        return out


class TimeSeries:
    """Ring buffer of (t, value); oldest points evicted at capacity."""

    def __init__(self, clock: Clock, capacity: int = 1024):
        self._clock = clock
        self.capacity = capacity
        self._points: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()

    def record(self, value: float, t: Optional[float] = None):
        with self._lock:
            self._points.append((self._clock() if t is None else t,
                                 float(value)))

    def points(self) -> List[Tuple[float, float]]:
        with self._lock:
            return list(self._points)

    def window(self, t0: float, t1: float) -> List[Tuple[float, float]]:
        return [(t, v) for t, v in self.points() if t0 <= t <= t1]

    def __len__(self):
        return len(self._points)

    def time_weighted_mean(self) -> float:
        """Mean of a step function sampled at the recorded points."""
        pts = self.points()
        if not pts:
            return math.nan
        if len(pts) == 1:
            return pts[0][1]
        area = 0.0
        for (t0, v0), (t1, _) in zip(pts, pts[1:]):
            area += v0 * (t1 - t0)
        span = pts[-1][0] - pts[0][0]
        return area / span if span > 0 else pts[-1][1]


class MetricsRegistry:
    """Get-or-create metric store; thread-safe, clock-injectable.

    Live components pass nothing (wall clock); the simulator passes
    ``clock=lambda: sim.now`` so every sample carries virtual time and the
    emitted schema is identical across planes.
    """

    def __init__(self, clock: Optional[Clock] = None,
                 flight_capacity: int = 4096):
        self.clock: Clock = clock or time.time
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._series: Dict[str, TimeSeries] = {}
        # key -> (bare name, sorted label items); lets the Prometheus
        # exporter re-quote labels without parsing flattened keys
        self._meta: Dict[str, Tuple[str, Tuple[Tuple[str, str], ...]]] = {}
        # flight recorder: bounded ring of notable events (admissions,
        # retirements, evictions, scaling actions) for post-mortem dumps.
        # Guarded by its own lock so event bursts never contend with the
        # metric get-or-create path; the deque maxlen enforces the cap
        # even under concurrent writers.
        self._events: deque = deque(maxlen=flight_capacity)
        self._events_lock = threading.Lock()
        self._event_seq = 0

    def _remember(self, key: str, name: str, labels: Dict[str, str]):
        self._meta[key] = (name, tuple(sorted(labels.items())))

    # -- get-or-create accessors -------------------------------------------
    def counter(self, name: str, **labels) -> Counter:
        key = metric_key(name, labels)
        with self._lock:
            if key not in self._counters:
                self._counters[key] = Counter()
                self._remember(key, name, labels)
            return self._counters[key]

    def gauge(self, name: str, **labels) -> Gauge:
        key = metric_key(name, labels)
        with self._lock:
            if key not in self._gauges:
                self._gauges[key] = Gauge()
                self._remember(key, name, labels)
            return self._gauges[key]

    def histogram(self, name: str, window_s: Optional[float] = None,
                  max_samples: Optional[int] = None, **labels) -> Histogram:
        """Get-or-create; an explicit ``window_s``/``max_samples`` always
        wins, so configuration is order-independent — a reader that merely
        gets the histogram first (e.g. ``signals_from_registry``) cannot
        pin the defaults."""
        key = metric_key(name, labels)
        with self._lock:
            h = self._histograms.get(key)
            if h is None:
                h = Histogram(self.clock,
                              window_s=60.0 if window_s is None else window_s,
                              max_samples=max_samples or 4096)
                self._histograms[key] = h
                self._remember(key, name, labels)
            else:
                if window_s is not None:
                    h.window_s = window_s
                if max_samples is not None \
                        and max_samples != h._samples.maxlen:
                    with h._lock:
                        h._samples = deque(h._samples,
                                           maxlen=max_samples)
            return h

    def series(self, name: str, capacity: int = 1024, **labels) -> TimeSeries:
        key = metric_key(name, labels)
        with self._lock:
            if key not in self._series:
                self._series[key] = TimeSeries(self.clock, capacity=capacity)
                self._remember(key, name, labels)
            return self._series[key]

    def drop_series(self, name: str, **labels) -> None:
        """Remove one time series (e.g. a finished task's progress
        history) so per-entity series don't accumulate forever."""
        key = metric_key(name, labels)
        with self._lock:
            self._series.pop(key, None)
            if (key not in self._counters and key not in self._gauges
                    and key not in self._histograms):
                self._meta.pop(key, None)

    def gauge_values(self, name: str, **labels) -> Dict[str, float]:
        """All gauges of one metric family whose labels contain ``labels``
        — e.g. every replica's ``kv_pages_in_use_ratio`` for a service, so
        a drive loop can aggregate per-engine gauges into the service-level
        signal the autoscaler reads."""
        want = set(labels.items())
        out = {}
        with self._lock:
            for key, g in self._gauges.items():
                mname, items = self._meta.get(key, (None, ()))
                if mname == name and want <= set(items):
                    out[key] = g.value
        return out

    def labeled_gauge_values(self, name: str, **labels,
                             ) -> List[Tuple[Dict[str, str], float]]:
        """Like ``gauge_values`` but returns ``(label_dict, value)`` pairs,
        so a caller can select on a specific label (e.g. pick the engine
        with the most ``kv_free_pages``) without parsing flattened keys."""
        want = set(labels.items())
        out = []
        with self._lock:
            for key, g in self._gauges.items():
                mname, items = self._meta.get(key, (None, ()))
                if mname == name and want <= set(items):
                    out.append((dict(items), g.value))
        return out

    # -- flight recorder ----------------------------------------------------
    def record_event(self, kind: str, **fields):
        """Append a (t, kind, fields, seq) event to the post-mortem ring.
        ``seq`` is a monotonic sequence number assigned under the event
        lock, so total order is recoverable even when the injected clock is
        coarse (virtual time) or two threads race on the same instant.
        Not for per-token hot paths — admissions, retirements, evictions,
        scaling decisions and the like."""
        with self._events_lock:
            seq = self._event_seq
            self._event_seq += 1
            self._events.append((self.clock(), kind, fields, seq))

    def flight_record(self, series_tail: int = 64) -> dict:
        """Post-mortem dump: the event ring plus the tail of every time
        series — everything needed to reconstruct 'what just happened'
        after an SLO blowup, without scraping histories elsewhere."""
        with self._events_lock:
            events = list(self._events)
        with self._lock:
            series = {k: s.points()[-series_tail:]
                      for k, s in self._series.items()}
        return {"ts": self.clock(), "events": events,
                "series_tail": series}

    def flight_record_to_file(self, path: str, series_tail: int = 64,
                              **context) -> str:
        """Serialize ``flight_record()`` (plus caller context, e.g. the
        crashing engine id and exception text) to a JSON file.  Invoked on
        engine crash paths so the event ring survives the process."""
        import json

        dump = self.flight_record(series_tail=series_tail)
        dump["events"] = [
            {"t": t, "kind": kind, "fields": fields, "seq": seq}
            for t, kind, fields, seq in dump["events"]]
        if context:
            dump["context"] = {k: str(v) for k, v in context.items()}
        with open(path, "w") as f:
            json.dump(dump, f, default=str)
        return path

    # -- export ------------------------------------------------------------
    @staticmethod
    def _prom_quote(items: Tuple[Tuple[str, str], ...]) -> str:
        """Prometheus-quoted label string (escaped backslash/quote/newline)."""
        if not items:
            return ""
        def esc(v) -> str:
            return (str(v).replace("\\", "\\\\").replace('"', '\\"')
                    .replace("\n", "\\n"))
        return "{" + ",".join(f'{k}="{esc(v)}"' for k, v in items) + "}"

    def to_prometheus_text(self) -> str:
        """Prometheus exposition format (text/plain; version 0.0.4).

        Counters and gauges map directly; histograms are exported as
        summaries (windowed quantiles + cumulative _sum/_count).  Samples
        are grouped per metric family (one # TYPE header, contiguous
        lines), as strict parsers require.  Time series are post-mortem
        artifacts and are served by ``flight_record`` instead."""
        with self._lock:
            counters = list(self._counters.items())
            gauges = list(self._gauges.items())
            hists = list(self._histograms.items())
            meta = dict(self._meta)

        families: Dict[str, List[str]] = {}
        order: List[Tuple[str, str]] = []    # (name, kind) in first-seen order

        def family(key: str, kind: str) -> Tuple[str, List[str], tuple]:
            name, items = meta.get(key, (key, ()))
            if name not in families:
                families[name] = []
                order.append((name, kind))
            return name, families[name], items

        for key, c in counters:
            name, fam, items = family(key, "counter")
            fam.append(f"{name}{self._prom_quote(items)} {c.value:g}")
        for key, g in gauges:
            # NaN/inf gauges are tombstones (e.g. ``evacuate()`` poisons
            # spec_accept_rate so a stale value can't steer the autoscaler)
            # — meaningful in-process, but a literal ``nan`` sample breaks
            # strict Prometheus scrapers, so non-finite gauges are dropped
            # from the export.  (Histogram quantiles keep NaN: summaries
            # legitimately report "no data in window".)
            if not math.isfinite(g.value):
                continue
            name, fam, items = family(key, "gauge")
            fam.append(f"{name}{self._prom_quote(items)} {g.value:g}")
        for key, h in hists:
            name, fam, items = family(key, "summary")
            for q in (0.5, 0.95, 0.99):
                v = h.quantile(q)
                lab = self._prom_quote(items + (("quantile", f"{q:g}"),))
                fam.append(f"{name}{lab} "
                           f"{'NaN' if math.isnan(v) else f'{v:g}'}")
            lab = self._prom_quote(items)
            fam.append(f"{name}_sum{lab} {h.sum:g}")
            fam.append(f"{name}_count{lab} {h.count:g}")

        lines: List[str] = []
        for name, kind in order:
            lines.append(f"# TYPE {name} {kind}")
            lines.extend(families[name])
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """One schema for live and simulated runs (ts = injected clock)."""
        with self._lock:
            return {
                "ts": self.clock(),
                "counters": {k: c.value for k, c in self._counters.items()},
                "gauges": {k: g.value for k, g in self._gauges.items()},
                "histograms": {k: h.summary()
                               for k, h in self._histograms.items()},
                "series": {k: s.points() for k, s in self._series.items()},
            }
