"""Live-plane elastic-serving drive loops, shared by
``examples/elastic_serving.py`` and ``benchmarks/fig14_autoscale.py``.

Two drivers:

* ``drive_engine_open_loop`` — the per-request serving path.  Requests are
  published to a service-scoped ``RequestRouter``; every RUNNING replica is
  an ``EngineServeTask`` whose continuous-batching engine pulls admissible
  requests from the router and dispatches each decode iteration as an
  EXECUTE through its monitor.  Request *termination happens on-device*:
  TTFT/TBT/end-to-end latencies are engine-reported into the shared
  registry, and SLO attainment is computed from those.
* ``drive_open_loop`` — the legacy modeled-completion driver (each RUNNING
  replica retires ``service_rate`` requests/s in the load generator); kept
  for quick experiments that don't need real decoding.

Either way, every scaling action underneath is the real paper machinery —
checkpoint-clone replicate and kill+delete through node agents and CRI —
and the orchestrator's autoscaler reconcile thread consumes the canonical
service signals from the registry.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.scaling.autoscaler import (M_COMPLETIONS, M_KV_FREE_PAGES,
                                      M_KV_PAGES, M_LATENCY,
                                      M_PREFIX_HIT_RATE, M_QUEUE_DEPTH,
                                      M_REQUESTS, M_SLO_VIOLATIONS,
                                      M_SPEC_ACCEPT_RATE, M_UTILIZATION)
from repro.scaling.loadgen import Request
from repro.scaling.metrics import metric_key


@dataclass
class DriveResult:
    served: int
    violations: int
    max_replicas: int

    @property
    def attainment(self) -> float:
        if not self.served:
            return float("nan")
        return (self.served - self.violations) / self.served


# ---------------------------------------------------------------------------
# Per-request serving path: router + engine replicas
# ---------------------------------------------------------------------------
class RequestRouter:
    """Service-scoped request frontend shared by every engine replica.

    The drive loop publishes arrivals here; each replica's
    ``ContinuousBatchingEngine.pump`` pops as many as it has free decode
    slots.  The router is intake + bookkeeping only — per-request latency
    metrics are engine-reported at retirement (``complete``), so the
    numbers in the registry are measured on-device, not modeled.  In a
    multi-host deployment this object is the service's RPC frontend; here
    replicas share it in-process.

    **KV-aware routing** (``kv_aware=True``, needs a registry): a pop
    tagged with an ``engine_id`` prefers the replica with the most free KV
    pages (the per-engine ``kv_free_pages`` gauge every paged engine
    already publishes) — admitting where memory is plentiful cuts OOM
    preemptions at high load.  A non-preferred replica is deferred exactly
    once and served on its next pop, so preference never starves a
    replica; on ties every replica is preferred and the replicas' pump
    loops take turns (round-robin).

    **Prefix-hit-aware routing**: engines with a prefix cache register a
    probe (``register_prefix_probe``) that reports how many tokens of a
    prompt their radix tree already holds.  A pop then prefers the
    replica with the warmest matching prefix for the request at the head
    of the queue — cached pages are mapped instead of recomputed, so
    warm routing converts repeat prefixes into TTFT and pool-page wins.
    Warmth is capped by free-page headroom: a warm replica whose pool has
    fallen below half the best replica's free pages loses its preference
    (hit-skew must not concentrate all traffic on one starving engine),
    and the router falls back to the free-page load balance above.

    **Role-aware routing** (disaggregated serving): replicas declare a
    role via ``register_engine_role``.  ``decode`` replicas never pop
    fresh prompts — their work arrives through the KV transfer queue;
    with several ``prefill`` replicas, prompts route by bucketed prompt
    length (deterministic bucket→replica assignment) so each replica's
    per-bucket prefill program stays hot.  ``transfer_lease`` follows a
    lane across a handoff so crash replay keeps conserving requests,
    and ``replay_request`` replays a single request lost to a torn
    transfer.
    """

    def __init__(self, service: str = "svc", registry=None,
                 kv_aware: bool = True, tracer=None, chaos=None):
        self.service = service
        self.registry = registry
        self.kv_aware = kv_aware
        # optional repro.obs.Tracer: each submitted request starts a trace
        # (trace_id = rid) with a router.queue span ending at pop; engines
        # sharing the tracer hang their admit/decode/monitor spans off the
        # same trace, so one request is one connected tree
        self.tracer = tracer
        self.chaos = chaos              # repro.chaos.FaultPlan (router.pop)
        self.closed = False
        self._lock = threading.Lock()
        self._pending: deque = deque()
        self._deferred: set = set()     # engines already held back once
        # engine_id -> prompt -> matched-token count (prefix-cache warmth)
        self._prefix_probes: Dict[str, Callable] = {}
        # disaggregated serving: engine_id -> role / prompt buckets
        self._roles: Dict[str, str] = {}
        self._role_buckets: Dict[str, tuple] = {}
        # every popped request holds a lease (rid -> (req, engine_id))
        # until the owning engine completes or requeues it; a replica
        # crash replays exactly its leased requests (fail_engine)
        self._leases: Dict[str, tuple] = {}
        self.completed: Dict[str, object] = {}   # rid -> CompletedRequest
        # replay bookkeeping: rid -> tokens committed before the crash
        # (the replayed run must reproduce them as a prefix), plus
        # conservation counters the chaos soak asserts on
        self.replayed: Dict[str, list] = {}
        self.duplicates = 0
        self.replay_mismatches = 0

    @property
    def in_flight(self) -> int:
        return len(self._leases)

    def submit(self, req) -> None:
        with self._lock:
            if self.closed:
                raise RuntimeError(f"router {self.service} is closed")
            if req.arrival_t is None and self.registry is not None:
                req.arrival_t = self.registry.clock()
            if (self.tracer is not None
                    and getattr(req, "trace", None) is None):
                req.trace = self.tracer.start_trace(
                    "request", trace_id=req.rid, service=self.service)
            if getattr(req, "trace", None) is not None:
                req._router_span = req.trace.span("router.queue",
                                                  service=self.service)
            self._pending.append(req)
        if self.registry is not None:
            self.registry.counter(M_REQUESTS, service=self.service).inc()

    def register_prefix_probe(self, engine_id: str, probe: Callable) -> None:
        """Install a replica's prefix-cache warmth probe:
        ``probe(prompt) -> matched token count``.  Engines with a prefix
        cache call this from ``pump``; idempotent."""
        with self._lock:
            self._prefix_probes[engine_id] = probe

    def register_engine_role(self, engine_id: str, role: str,
                             buckets: tuple = ()) -> None:
        """Declare a replica's serving role (and its prompt buckets, for
        bucketed prefill routing).  Idempotent; engines call this from
        ``pump``."""
        with self._lock:
            self._roles[engine_id] = role
            self._role_buckets[engine_id] = tuple(buckets)

    def _prefill_preferred(self, engine_id: str) -> bool:
        """Bucketed prompt-length routing between prefill replicas: the
        head request's bucket maps deterministically onto the sorted
        prefill replica ids, so each replica's per-bucket prefill
        program stays hot instead of every replica cycling through every
        compiled signature."""
        prefills = sorted(e for e, r in self._roles.items()
                          if r == "prefill")
        if len(prefills) < 2 or engine_id not in prefills:
            return True
        buckets = sorted(set(self._role_buckets.get(engine_id) or ()))
        if not buckets:
            return True
        plen = int(np.asarray(self._pending[0].prompt).reshape(-1).shape[0])
        fit = [i for i, b in enumerate(buckets) if b >= plen]
        idx = fit[0] if fit else len(buckets) - 1
        return prefills[idx % len(prefills)] == engine_id

    def _free_pages(self) -> Dict[str, float]:
        if self.registry is None:
            return {}
        return {lbl["engine"]: v for lbl, v in
                self.registry.labeled_gauge_values(
                    M_KV_FREE_PAGES, service=self.service)
                if "engine" in lbl}

    def _kv_preferred(self, engine_id: str) -> bool:
        """True unless another engine publishes strictly more free pages
        (unknown engines and registry-less routers are always preferred)."""
        per_engine = self._free_pages()
        if not per_engine or engine_id not in per_engine:
            return True
        return per_engine[engine_id] >= max(per_engine.values())

    def _preferred(self, engine_id: str) -> bool:
        """Routing preference for the request at the head of the queue:
        warmest matching prefix first (capped by free-page headroom so
        hit-skew cannot starve the cold replicas), free KV pages as the
        load-balance fallback."""
        if self._prefix_probes:
            head = self._pending[0]
            warmth = {}
            for eid, probe in self._prefix_probes.items():
                try:
                    warmth[eid] = int(probe(head.prompt))
                except Exception:  # noqa: BLE001 - replica mid-evacuation
                    warmth[eid] = 0
            best = max(warmth.values(), default=0)
            if best > 0:
                warm = {e for e, w in warmth.items() if w == best}
                free = self._free_pages()
                if free:
                    # headroom cap: a warm replica running low on pages
                    # loses its preference — admitting there would trade
                    # the prefill saving for OOM preemptions
                    bar = max(free.values()) / 2.0
                    warm = {e for e in warm if free.get(e, bar) >= bar}
                if warm:
                    return engine_id in warm
        return self._kv_preferred(engine_id)

    def pop(self, n: int, engine_id: Optional[str] = None) -> list:
        if n <= 0:
            return []
        if self.chaos is not None:
            self.chaos.maybe_delay("router.pop", key=engine_id or "")
        with self._lock:
            role = self._roles.get(engine_id) if engine_id else None
            if role == "decode":
                # decode replicas receive work through the KV transfer
                # queue, never fresh prompts
                return []
            if role == "prefill":
                if (self._pending
                        and not self._prefill_preferred(engine_id)):
                    if engine_id not in self._deferred:
                        self._deferred.add(engine_id)
                        return []
            elif (self.kv_aware and engine_id is not None and self._pending
                    and not self._preferred(engine_id)):
                if engine_id not in self._deferred:
                    self._deferred.add(engine_id)
                    return []
            self._deferred.discard(engine_id)
            out = []
            while self._pending and len(out) < n:
                req = self._pending.popleft()
                rsp = getattr(req, "_router_span", None)
                if rsp is not None:
                    rsp.annotate(engine=engine_id).end()
                    req._router_span = None
                self._leases[req.rid] = (req, engine_id)
                out.append(req)
            return out

    def complete(self, record) -> None:
        with self._lock:
            self._leases.pop(record.rid, None)
            if record.rid in self.completed:
                # exactly-once guard: a replayed request that the dead
                # replica already terminated must not count twice
                self.duplicates += 1
                if self.registry is not None:
                    self.registry.counter("router_duplicate_completions",
                                          service=self.service).inc()
                return
            pre = self.replayed.get(record.rid)
            if pre is not None and list(record.tokens[:len(pre)]) != pre:
                # replay determinism check: tokens committed before the
                # crash must be a prefix of the replayed completion
                self.replay_mismatches += 1
                if self.registry is not None:
                    self.registry.record_event(
                        "replay_mismatch", rid=record.rid,
                        committed=pre, got=list(record.tokens))
            self.completed[record.rid] = record

    def transfer_lease(self, rid: str, engine_id: str) -> None:
        """Move a popped request's lease to the replica now holding its
        lane (KV handoff): crash replay keeps conserving requests — a
        crash of the *new* owner replays it, the old owner no longer
        does."""
        with self._lock:
            lease = self._leases.get(rid)
            if lease is not None:
                self._leases[rid] = (lease[0], engine_id)

    def replay_request(self, req) -> None:
        """A single request lost in transit (torn KV transfer): drop its
        lease and replay it.  Committed tokens are recorded so
        ``complete`` verifies the recompute reproduces them as a prefix,
        and the exactly-once guard rejects double completion — zero lost,
        zero duplicated."""
        with self._lock:
            self._leases.pop(req.rid, None)
            self.replayed[req.rid] = list(
                getattr(req, "committed", None) or [])
            tr = getattr(req, "trace", None)
            if tr is not None:
                req._prev_trace = tr
                tr.finish(torn_transfer=True)
                req.trace = None
            self._requeue_locked([req], reason="replayed")
            if self.registry is not None:
                self.registry.record_event(
                    "router_replay", service=self.service,
                    engine="kv.transfer", replayed=1)

    def requeue(self, reqs: list) -> None:
        """Return popped-but-unfinished requests (killed replica) to the
        head of the queue; original arrival times stick, so the disruption
        shows up in their end-to-end latency."""
        with self._lock:
            self._requeue_locked(reqs, reason="requeued")

    def _requeue_locked(self, reqs: list, reason: str) -> None:
        for req in reqs:
            self._leases.pop(req.rid, None)
        if self.closed:
            return
        for req in reqs:
            if self.tracer is not None and getattr(req, "trace",
                                                   None) is None:
                req.trace = self.tracer.start_trace(
                    "request", trace_id=req.rid,
                    service=self.service, **{reason: True})
                # span-link the recovery trace back to the pre-crash /
                # pre-evacuation one: trace_dump then shows one timeline
                prev = getattr(req, "_prev_trace", None)
                if prev is not None:
                    req.trace.link(prev, relation="recovers")
                    req._prev_trace = None
            if getattr(req, "trace", None) is not None:
                req._router_span = req.trace.span(
                    "router.queue", service=self.service,
                    **{reason: True})
        self._pending.extendleft(reversed(reqs))

    def fail_engine(self, engine_id: str) -> int:
        """Replica crash recovery: replay every request the dead engine
        still holds a lease on.  Each re-enters the queue (head) with its
        committed-token state recorded, so ``complete`` can verify the
        replayed run reproduces the pre-crash tokens as a prefix and the
        exactly-once guard rejects double completion.  Returns the number
        of requests replayed."""
        with self._lock:
            self._prefix_probes.pop(engine_id, None)
            self._roles.pop(engine_id, None)
            self._role_buckets.pop(engine_id, None)
            reqs = [req for req, eng in self._leases.values()
                    if eng == engine_id]
            for req in reqs:
                self.replayed[req.rid] = list(
                    getattr(req, "committed", None) or [])
                tr = getattr(req, "trace", None)
                if tr is not None:
                    req._prev_trace = tr
                    tr.finish(crashed=True, engine=engine_id)
                    req.trace = None
            self._requeue_locked(reqs, reason="replayed")
            if self.registry is not None and reqs:
                self.registry.record_event(
                    "router_replay", service=self.service,
                    engine=engine_id, replayed=len(reqs))
            return len(reqs)

    def pending_count(self) -> int:
        return len(self._pending)

    def outstanding(self) -> int:
        with self._lock:
            return len(self._pending) + self.in_flight

    def close(self) -> None:
        self.closed = True


# Engine replicas are instantiated by the runtime from a TaskImage, which
# must stay a plain serializable config (it rides in snapshots) — so tasks
# find their router here by service name instead of carrying a handle.
_ROUTERS: Dict[str, RequestRouter] = {}
_ROUTERS_LOCK = threading.Lock()


def get_router(service: str, registry=None, tracer=None) -> RequestRouter:
    with _ROUTERS_LOCK:
        r = _ROUTERS.get(service)
        if r is None:
            r = RequestRouter(service, registry=registry, tracer=tracer)
            _ROUTERS[service] = r
        if registry is not None and r.registry is None:
            r.registry = registry
        if tracer is not None and r.tracer is None:
            r.tracer = tracer
        return r


def reset_router(service: str) -> RequestRouter:
    """Fresh router for a new run (tests/benchmarks)."""
    with _ROUTERS_LOCK:
        r = RequestRouter(service)
        _ROUTERS[service] = r
        return r


def drive_engine_open_loop(orch, scaler, requests: List[Request], *,
                           duration_s: float, slo_s: float,
                           service: str = "svc", prompt_len: int = 16,
                           slots_per_replica: int = 4,
                           latency_window_s: float = 3.0,
                           tokens_range: tuple = (4, 9),
                           tick_s: float = 0.05, drain_timeout_s: float = 60.0,
                           on_tick: Optional[Callable] = None) -> DriveResult:
    """Replay an open-loop trace through the per-request serving path.

    Arrivals become ``ServeRequest``s on the service's router; the engine
    replicas terminate them on-device and report TTFT/TBT/e2e into
    ``orch.metrics``.  This loop only feeds the router and publishes the
    service-level queue/utilization gauges the autoscaler reads.
    """
    from repro.serve.engine import ServeRequest

    reg = orch.metrics
    # pin the shared window config before engines observe into it
    reg.histogram(M_LATENCY, window_s=latency_window_s, service=service)
    router = get_router(service, registry=reg)
    rng = np.random.Generator(np.random.Philox(1234))
    pending = deque(sorted(requests, key=lambda r: r.arrival_t))
    t0 = time.time()
    max_replicas = 1
    last_report = 0.0
    deadline = None
    while True:
        now = time.time() - t0
        while pending and pending[0].arrival_t <= now:
            r = pending.popleft()
            n_tok = (r.n_tokens if getattr(r, "n_tokens", None)
                     else int(rng.integers(*tokens_range)))
            router.submit(ServeRequest(
                rid=r.rid, prompt=rng.integers(0, 512, prompt_len),
                max_new_tokens=n_tok, arrival_t=reg.clock(), slo_s=slo_s))
        if not pending and router.outstanding() == 0 and now > duration_s:
            break
        if not pending and deadline is None and now > duration_s:
            deadline = time.time() + drain_timeout_s
        if deadline is not None and time.time() > deadline:
            break                        # replicas wedged; report what we have
        n_rep = scaler.current_replicas()
        max_replicas = max(max_replicas, n_rep)
        reg.gauge(M_QUEUE_DEPTH, service=service).set(router.pending_count())
        cap = max(1, n_rep * slots_per_replica)
        reg.gauge(M_UTILIZATION, service=service).set(
            min(1.0, router.in_flight / cap))
        # cache-memory occupancy: fold per-engine KV pool gauges into the
        # service-level pressure signal (worst replica wins — that is the
        # one about to OOM-preempt), so the autoscaler sees memory
        # pressure alongside queue depth and tail latency
        svc_key = metric_key(M_KV_PAGES, {"service": service})
        kv = [v for k, v in
              reg.gauge_values(M_KV_PAGES, service=service).items()
              if k != svc_key]
        if kv:
            reg.gauge(M_KV_PAGES, service=service).set(max(kv))
        # speculation acceptance: service-level mean of the per-engine
        # gauges (an efficiency signal, so the mean — not the worst — is
        # what capacity planning and the simulator's service model want);
        # killed replicas tombstone their gauge with NaN — skip those
        spec_key = metric_key(M_SPEC_ACCEPT_RATE, {"service": service})
        sv = [v for k2, v in
              reg.gauge_values(M_SPEC_ACCEPT_RATE, service=service).items()
              if k2 != spec_key and not np.isnan(v)]
        if sv:
            reg.gauge(M_SPEC_ACCEPT_RATE, service=service).set(
                sum(sv) / len(sv))
        # prefix-cache hit rate: same NaN-skipping service mean — an
        # efficiency signal the simulator's TTFT model consumes
        px_key = metric_key(M_PREFIX_HIT_RATE, {"service": service})
        pv = [v for k2, v in
              reg.gauge_values(M_PREFIX_HIT_RATE, service=service).items()
              if k2 != px_key and not np.isnan(v)]
        if pv:
            reg.gauge(M_PREFIX_HIT_RATE, service=service).set(
                sum(pv) / len(pv))
        if on_tick is not None and now - last_report >= 1.0:
            last_report = now
            on_tick(now, n_rep, router.pending_count(),
                    reg.histogram(M_LATENCY, service=service).quantile(0.95))
        time.sleep(tick_s)
    router.close()
    completed = list(router.completed.values())
    violations = sum(1 for c in completed if c.e2e_s > slo_s)
    return DriveResult(served=len(completed), violations=violations,
                       max_replicas=max_replicas)


def wait_for_service(cluster, orch, cid: str, timeout_s: float = 120.0,
                     ) -> str:
    """Block until the service task is deployed AND its guest finished
    setup (first step taken); returns the node it landed on."""
    deadline = time.time() + timeout_s
    while time.time() < deadline:
        node = orch._sched_tasks[cid].node_id
        if node is not None and orch.deployments[cid].status == "running":
            rec = cluster.nodes[node].runtime.tasks.get(cid)
            if rec is not None and rec.guest_state.step > 0:
                return node
        time.sleep(0.1)
    raise TimeoutError(f"service {cid} failed to start in {timeout_s}s")


def drive_open_loop(orch, scaler, requests: List[Request], *,
                    duration_s: float, service_rate: float, slo_s: float,
                    service: str = "svc", latency_window_s: float = 3.0,
                    tick_s: float = 0.05,
                    on_tick: Optional[Callable] = None) -> DriveResult:
    """Replay an open-loop trace against the live cluster in wall time.

    ``on_tick(now, replicas, queue_len, p95)`` fires about once a second
    for progress reporting.
    """
    reg = orch.metrics
    lat_hist = reg.histogram(M_LATENCY, window_s=latency_window_s,
                             service=service)
    pending = deque(sorted(requests, key=lambda r: r.arrival_t))
    queue: deque = deque()
    t0 = time.time()
    served = violations = 0
    max_replicas = 1
    last_report = 0.0
    while True:
        now = time.time() - t0
        # drain arrivals before testing the exit so requests landing in
        # the final tick window are still admitted and counted; arrivals
        # enter requests_total here (completions at serve time), matching
        # the simulator's arrival/departure split
        while pending and pending[0].arrival_t <= now:
            queue.append(pending.popleft())
            reg.counter(M_REQUESTS, service=service).inc()
        if now > duration_s and not pending and not queue:
            break
        n_rep = scaler.current_replicas()
        max_replicas = max(max_replicas, n_rep)
        capacity = max(1, int(n_rep * service_rate * tick_s))
        used = 0
        while queue and used < capacity:
            r = queue.popleft()
            used += 1
            served += 1
            latency = max(0.0, now - r.arrival_t)
            lat_hist.observe(latency)
            reg.counter(M_COMPLETIONS, service=service).inc()
            if latency > slo_s:
                violations += 1
                reg.counter(M_SLO_VIOLATIONS, service=service).inc()
        reg.gauge(M_QUEUE_DEPTH, service=service).set(len(queue))
        reg.gauge(M_UTILIZATION, service=service).set(
            min(1.0, used / max(capacity, 1)))
        if on_tick is not None and now - last_report >= 1.0:
            last_report = now
            on_tick(now, n_rep, len(queue), lat_hist.quantile(0.95))
        time.sleep(tick_s)
    return DriveResult(served=served, violations=violations,
                       max_replicas=max_replicas)


def teardown_service(orch, scaler):
    """Quiesce the reconcile/scheduler threads, converge to one replica
    (real kill+delete scale-in), then remove whatever is still running."""
    orch.stop()
    scaler.scale_to(1)
    for cid, dep in list(orch.deployments.items()):
        if dep.status == "running":
            try:
                orch.scale_in(cid)
            except Exception:  # noqa: BLE001 - node may be gone
                pass
