"""Live-plane elastic-serving drive loop, shared by
``examples/elastic_serving.py`` and ``benchmarks/fig14_autoscale.py``.

The guest serve tasks decode continuously; request *termination* is modeled
here in the load-driver (each RUNNING replica retires ``service_rate``
requests/s) while every scaling action underneath is the real paper
machinery — checkpoint-clone replicate and kill+delete through node agents
and CRI.  The driver publishes the canonical service signals into the
orchestrator's registry; the orchestrator's autoscaler reconcile thread
consumes them.  Routing requests through the monitor queue per-request is a
ROADMAP item.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, List, Optional

from repro.scaling.autoscaler import (M_COMPLETIONS, M_LATENCY,
                                      M_QUEUE_DEPTH, M_REQUESTS,
                                      M_SLO_VIOLATIONS, M_UTILIZATION)
from repro.scaling.loadgen import Request


@dataclass
class DriveResult:
    served: int
    violations: int
    max_replicas: int

    @property
    def attainment(self) -> float:
        if not self.served:
            return float("nan")
        return (self.served - self.violations) / self.served


def wait_for_service(cluster, orch, cid: str, timeout_s: float = 120.0,
                     ) -> str:
    """Block until the service task is deployed AND its guest finished
    setup (first step taken); returns the node it landed on."""
    deadline = time.time() + timeout_s
    while time.time() < deadline:
        node = orch._sched_tasks[cid].node_id
        if node is not None and orch.deployments[cid].status == "running":
            rec = cluster.nodes[node].runtime.tasks.get(cid)
            if rec is not None and rec.guest_state.step > 0:
                return node
        time.sleep(0.1)
    raise TimeoutError(f"service {cid} failed to start in {timeout_s}s")


def drive_open_loop(orch, scaler, requests: List[Request], *,
                    duration_s: float, service_rate: float, slo_s: float,
                    service: str = "svc", latency_window_s: float = 3.0,
                    tick_s: float = 0.05,
                    on_tick: Optional[Callable] = None) -> DriveResult:
    """Replay an open-loop trace against the live cluster in wall time.

    ``on_tick(now, replicas, queue_len, p95)`` fires about once a second
    for progress reporting.
    """
    reg = orch.metrics
    lat_hist = reg.histogram(M_LATENCY, window_s=latency_window_s,
                             service=service)
    pending = deque(sorted(requests, key=lambda r: r.arrival_t))
    queue: deque = deque()
    t0 = time.time()
    served = violations = 0
    max_replicas = 1
    last_report = 0.0
    while True:
        now = time.time() - t0
        # drain arrivals before testing the exit so requests landing in
        # the final tick window are still admitted and counted; arrivals
        # enter requests_total here (completions at serve time), matching
        # the simulator's arrival/departure split
        while pending and pending[0].arrival_t <= now:
            queue.append(pending.popleft())
            reg.counter(M_REQUESTS, service=service).inc()
        if now > duration_s and not pending and not queue:
            break
        n_rep = scaler.current_replicas()
        max_replicas = max(max_replicas, n_rep)
        capacity = max(1, int(n_rep * service_rate * tick_s))
        used = 0
        while queue and used < capacity:
            r = queue.popleft()
            used += 1
            served += 1
            latency = max(0.0, now - r.arrival_t)
            lat_hist.observe(latency)
            reg.counter(M_COMPLETIONS, service=service).inc()
            if latency > slo_s:
                violations += 1
                reg.counter(M_SLO_VIOLATIONS, service=service).inc()
        reg.gauge(M_QUEUE_DEPTH, service=service).set(len(queue))
        reg.gauge(M_UTILIZATION, service=service).set(
            min(1.0, used / max(capacity, 1)))
        if on_tick is not None and now - last_report >= 1.0:
            last_report = now
            on_tick(now, n_rep, len(queue), lat_hist.quantile(0.95))
        time.sleep(tick_s)
    return DriveResult(served=served, violations=violations,
                       max_replicas=max_replicas)


def teardown_service(orch, scaler):
    """Quiesce the reconcile/scheduler threads, converge to one replica
    (real kill+delete scale-in), then remove whatever is still running."""
    orch.stop()
    scaler.scale_to(1)
    for cid, dep in list(orch.deployments.items()):
        if dep.status == "running":
            try:
                orch.scale_in(cid)
            except Exception:  # noqa: BLE001 - node may be gone
                pass
