"""Traffic generators for elastic-serving scenarios (paper §5.6 style
trace replay, applied to request streams instead of batch jobs).

Open-loop: a non-homogeneous Poisson process over a rate profile —
constant, diurnal (sinusoidal day/night), or burst/spike — sampled by
thinning, so offered load is independent of the system's state (the honest
way to measure SLO attainment; closed-loop generators hide overload by
backing off).

Closed-loop: N clients that each wait ``think_time_s`` after a completion
before issuing the next request — the feedback mode, driven by the serving
loop calling ``on_complete``.

Service demand per request is exponential around ``mean_service_s`` — the
M/M/n-ish baseline that makes policy comparisons interpretable.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

RateFn = Callable[[float], float]


@dataclass
class Request:
    rid: str
    arrival_t: float                # seconds from trace start
    service_s: float                # work one replica needs to serve it
    client: Optional[int] = None    # closed-loop issuer
    n_tokens: Optional[int] = None  # generation length (engine-served runs)


# ---------------------------------------------------------------------------
# Rate profiles (requests/second as a function of time)
# ---------------------------------------------------------------------------
def constant_rate(rate: float) -> RateFn:
    return lambda t: rate


def diurnal_rate(base: float, peak: float, period_s: float = 86400.0,
                 ) -> RateFn:
    """Sinusoid between ``base`` (trough) and ``peak`` (crest)."""
    mid = (base + peak) / 2.0
    amp = (peak - base) / 2.0
    return lambda t: mid + amp * math.sin(2 * math.pi * t / period_s)


def burst_rate(base: float, burst_mult: float, burst_start: float,
               burst_len: float) -> RateFn:
    """Flat ``base`` with a ``burst_mult``x spike in [start, start+len)."""
    def rate(t: float) -> float:
        if burst_start <= t < burst_start + burst_len:
            return base * burst_mult
        return base
    return rate


# ---------------------------------------------------------------------------
# Open loop
# ---------------------------------------------------------------------------
def open_loop(rate_fn: RateFn, horizon_s: float, *, seed: int = 0,
              mean_service_s: float = 0.2,
              tokens_range: Optional[tuple] = None,
              rate_cap: Optional[float] = None) -> List[Request]:
    """Sample a non-homogeneous Poisson arrival stream by thinning.

    ``tokens_range=(lo, hi)`` additionally draws a ragged generation
    length per request (uniform ints) for engine-served runs.
    """
    rng = np.random.Generator(np.random.Philox(seed))
    if rate_cap is None:
        # conservative envelope for the thinning proposal
        probe = [rate_fn(horizon_s * i / 1000.0) for i in range(1001)]
        rate_cap = max(probe) * 1.05 + 1e-9
    out: List[Request] = []
    t = 0.0
    i = 0
    while True:
        t += rng.exponential(1.0 / rate_cap)
        if t >= horizon_s:
            break
        if rng.uniform() * rate_cap <= rate_fn(t):
            out.append(Request(
                rid=f"req-{i:06d}", arrival_t=t,
                service_s=float(rng.exponential(mean_service_s)),
                n_tokens=(None if tokens_range is None
                          else int(rng.integers(*tokens_range)))))
            i += 1
    return out


# ---------------------------------------------------------------------------
# Closed loop
# ---------------------------------------------------------------------------
@dataclass
class ClosedLoopGen:
    """N clients; each issues, waits for completion + think time, repeats.

    The serving loop owns the clock: call ``initial()`` once, then
    ``on_complete(req, now)`` for each finished request to get the client's
    next one (or None past the horizon).  ``tokens_range=(lo, hi)``
    additionally draws a ragged generation length per request (uniform
    ints), matching the open-loop generator's engine-served mode — so
    closed-loop think-time scenarios can drive ``engine_service_model``
    service times too.
    """

    n_clients: int = 4
    think_time_s: float = 1.0
    mean_service_s: float = 0.2
    horizon_s: float = 60.0
    seed: int = 0
    tokens_range: Optional[tuple] = None
    _rng: np.random.Generator = field(init=False, repr=False)
    _issued: int = field(init=False, default=0)

    def __post_init__(self):
        self._rng = np.random.Generator(np.random.Philox(self.seed))

    @property
    def issued(self) -> int:
        """Requests handed out so far (conservation checks)."""
        return self._issued

    def _make(self, t: float, client: int) -> Request:
        r = Request(rid=f"creq-{self._issued:06d}", arrival_t=t,
                    service_s=float(
                        self._rng.exponential(self.mean_service_s)),
                    client=client,
                    n_tokens=(None if self.tokens_range is None
                              else int(self._rng.integers(
                                  *self.tokens_range))))
        self._issued += 1
        return r

    def initial(self) -> List[Request]:
        # stagger the first wave across one think time to avoid a lockstep
        return [self._make(float(self._rng.uniform(0, self.think_time_s)), c)
                for c in range(self.n_clients)]

    def on_complete(self, req: Request, now: float) -> Optional[Request]:
        if req.client is None:
            return None
        t = now + float(self._rng.exponential(self.think_time_s))
        if t >= self.horizon_s:
            return None
        return self._make(t, req.client)
