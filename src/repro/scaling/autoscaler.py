"""SLO-driven workload scaling — the paper's third orchestration service
(§3.5, Table 3), grown from the ``scale_horizontal`` / ``scale_vertical``
stubs into a reconcile loop.

A ``ScalingPolicy`` maps ``ScalingSignals`` (utilization, queue depth, tail
latency — read from a ``repro.scaling.metrics`` registry) to a desired
replica count.  The ``Autoscaler`` clamps that to [min, max], applies
hysteresis (a dead band around the current count) and per-direction
cooldowns, and hands the decision to a ``ReplicaTarget``:

* ``OrchestratorScaler`` — the live plane: scale-out replicates the service
  task onto a node with free vSlices (orchestrator -> node agent -> CRI
  ``replicate``), scale-in removes the youngest replica;
* the simulator's serving loop — the virtual plane (``ServingSimulator``),
  where provisioning delay models sandbox boot + reconfiguration.

Policies never talk to either plane directly; they are pure functions, so
Fig 14 can evaluate the same policy objects against traces and live runs.
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from typing import List, Optional, Protocol

from repro.scaling.metrics import MetricsRegistry

# Canonical service metric names (one schema across both planes).
M_REQUESTS = "requests_total"
M_COMPLETIONS = "completions_total"
M_SLO_VIOLATIONS = "slo_violations_total"
M_QUEUE_DEPTH = "queue_depth"
M_REPLICAS = "replicas"
M_UTILIZATION = "utilization"
M_LATENCY = "request_latency_seconds"
M_REPLICAS_SERIES = "replicas_ts"
# cache-memory occupancy (paged KV pool): fraction of pool pages in use,
# free page count, and OOM preemptions forced by pool exhaustion
M_KV_PAGES = "kv_pages_in_use_ratio"
M_KV_FREE_PAGES = "kv_free_pages"
M_PREEMPTIONS = "engine_oom_preemptions_total"
# speculative decode: accepted / offered draft tokens (0..1); per-engine
# from the live engine, folded to a service mean by the drive loop, and an
# input to the simulator's speculative service model
M_SPEC_ACCEPT_RATE = "spec_accept_rate"
# prefix cache: prompt tokens served from cached KV pages / total prompt
# tokens (0..1); per-engine from the live engine, folded to a service mean
# by the drive loop, and an input to the simulator's TTFT model
M_PREFIX_HIT_RATE = "prefix_hit_rate"


@dataclass
class ScalingSignals:
    """Inputs to a policy decision, all service-scoped."""
    replicas: int = 1
    utilization: float = 0.0        # busy replica fraction, 0..1
    queue_depth: float = 0.0        # requests waiting for a replica
    p95_latency_s: float = math.nan
    kv_pressure: float = 0.0        # KV pool pages in use, 0..1


def signals_from_registry(reg: MetricsRegistry, service: str,
                          ) -> ScalingSignals:
    return ScalingSignals(
        replicas=max(1, int(reg.gauge(M_REPLICAS, service=service).value)),
        utilization=reg.gauge(M_UTILIZATION, service=service).value,
        queue_depth=reg.gauge(M_QUEUE_DEPTH, service=service).value,
        p95_latency_s=reg.histogram(M_LATENCY, service=service)
        .quantile(0.95),
        kv_pressure=reg.gauge(M_KV_PAGES, service=service).value,
    )


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------
class ScalingPolicy:
    name = "base"

    def desired_replicas(self, s: ScalingSignals) -> int:
        raise NotImplementedError


@dataclass
class TargetUtilizationPolicy(ScalingPolicy):
    """Classic proportional control: keep busy fraction near ``target``."""
    target: float = 0.7
    name: str = "target-util"

    def desired_replicas(self, s: ScalingSignals) -> int:
        if s.utilization <= 0:
            return s.replicas if s.queue_depth > 0 else 1
        return max(1, math.ceil(s.replicas * s.utilization / self.target))


@dataclass
class QueueLengthPolicy(ScalingPolicy):
    """Bound waiting work: allow ``target_per_replica`` queued requests per
    replica (plus the in-service ones)."""
    target_per_replica: float = 2.0
    name: str = "queue-len"

    def desired_replicas(self, s: ScalingSignals) -> int:
        in_service = s.utilization * s.replicas
        outstanding = s.queue_depth + in_service
        return max(1, math.ceil(outstanding / (1 + self.target_per_replica)))


@dataclass
class LatencySLOPolicy(ScalingPolicy):
    """Scale on the tail: grow multiplicatively while p95 breaches the SLO,
    shrink one replica at a time when comfortably under it and idle-ish."""
    slo_p95_s: float = 0.5
    headroom: float = 0.5           # shrink only when p95 < headroom * SLO
    idle_utilization: float = 0.5   # ... and utilization below this
    growth: float = 1.5
    name: str = "latency-slo"

    def desired_replicas(self, s: ScalingSignals) -> int:
        p95 = s.p95_latency_s
        if not math.isnan(p95) and p95 > self.slo_p95_s:
            return max(s.replicas + 1, math.ceil(s.replicas * self.growth))
        under_slo = math.isnan(p95) or p95 < self.headroom * self.slo_p95_s
        if (under_slo and s.utilization < self.idle_utilization
                and s.queue_depth == 0):
            return max(1, s.replicas - 1)
        return s.replicas


@dataclass
class KVPressurePolicy(ScalingPolicy):
    """Compose any policy with cache-memory pressure: when the paged KV
    pool runs hot, add a replica even while latency/queue still look fine
    — pool exhaustion means OOM preemptions (wasted recomputation) are
    about to burn throughput.  Memory pressure is a *leading* indicator;
    tail latency only moves after the preemptions start."""
    inner: ScalingPolicy = field(default_factory=QueueLengthPolicy)
    high_watermark: float = 0.85
    name: str = "kv-pressure"

    def desired_replicas(self, s: ScalingSignals) -> int:
        desired = self.inner.desired_replicas(s)
        if s.kv_pressure > self.high_watermark:
            desired = max(desired, s.replicas + 1)
        return desired


# ---------------------------------------------------------------------------
# Disaggregated serving: per-role replica counts under one slice budget
# ---------------------------------------------------------------------------
@dataclass
class RoleMix:
    """A per-role replica plan: how many prefill / decode replicas, and
    the vertical size (``vfpga_num`` slices) each replica gets."""
    prefill: int = 1
    decode: int = 1
    prefill_vfpga: int = 1
    decode_vfpga: int = 1

    @property
    def total_slices(self) -> int:
        return (self.prefill * self.prefill_vfpga
                + self.decode * self.decode_vfpga)


@dataclass
class RoleMixPolicy:
    """Per-role replica counts for prefill/decode disaggregation.

    Prefill demand follows queue depth (prompts wait for a prefill
    slot); decode demand follows KV pressure (resident lanes hold pool
    pages).  When the plan exceeds ``slice_budget``, vertical size is
    shed first — trading ``vfpga_num`` against the role mix, the
    paper's vertical-scaling knob — and only then does the *less*
    pressured role lose replicas, floored at ``min_each`` so neither
    side of the pipeline ever disappears.
    """
    slice_budget: int = 8
    vfpga_num: int = 2              # preferred per-replica vertical size
    queue_per_prefill: float = 2.0  # queued prompts one prefill absorbs
    kv_high: float = 0.85           # decode grows above this pressure
    min_each: int = 1
    name: str = "role-mix"

    def desired_mix(self, s: ScalingSignals) -> RoleMix:
        prefill = max(self.min_each,
                      math.ceil(s.queue_depth
                                / max(self.queue_per_prefill, 1e-9)))
        decode = max(self.min_each,
                     math.ceil(s.replicas * s.kv_pressure / self.kv_high)
                     if s.kv_pressure > 0 else self.min_each)
        mix = RoleMix(prefill=prefill, decode=decode,
                      prefill_vfpga=self.vfpga_num,
                      decode_vfpga=self.vfpga_num)
        # normalized pressure decides which role shrinks when slices are
        # scarce: queue pressure protects prefill, KV pressure decode
        queue_pressure = s.queue_depth / max(self.queue_per_prefill, 1e-9)
        kv_pressure = s.kv_pressure / self.kv_high
        while mix.total_slices > self.slice_budget:
            if mix.prefill_vfpga > 1 or mix.decode_vfpga > 1:
                # vertical first: shrink the fatter role's replicas
                if mix.prefill_vfpga >= mix.decode_vfpga:
                    mix.prefill_vfpga -= 1
                else:
                    mix.decode_vfpga -= 1
                continue
            shrink_prefill = (queue_pressure <= kv_pressure
                              and mix.prefill > self.min_each)
            if shrink_prefill:
                mix.prefill -= 1
            elif mix.decode > self.min_each:
                mix.decode -= 1
            elif mix.prefill > self.min_each:
                mix.prefill -= 1
            else:
                break                   # floor reached on both roles
        return mix


# ---------------------------------------------------------------------------
# Reconciler
# ---------------------------------------------------------------------------
class ReplicaTarget(Protocol):
    def current_replicas(self) -> int: ...
    def scale_to(self, n: int) -> None: ...


@dataclass
class ScalingDecision:
    t: float
    current: int
    desired: int
    applied: bool
    reason: str = ""


class Autoscaler:
    """Policy + bounds + hysteresis/cooldown; emits replica targets.

    ``reconcile`` is plane-agnostic: the orchestrator's background thread
    calls it with wall time, the serving simulator with virtual time.
    """

    def __init__(self, policy: ScalingPolicy, *, min_replicas: int = 1,
                 max_replicas: int = 8, scale_up_cooldown_s: float = 0.0,
                 scale_down_cooldown_s: float = 30.0,
                 tolerance: float = 0.0):
        if min_replicas < 1 or max_replicas < min_replicas:
            raise ValueError("need 1 <= min_replicas <= max_replicas")
        self.policy = policy
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.scale_up_cooldown_s = scale_up_cooldown_s
        self.scale_down_cooldown_s = scale_down_cooldown_s
        self.tolerance = tolerance
        self._last_scale_up = -math.inf
        self._last_scale_down = -math.inf
        self.decisions: List[ScalingDecision] = []

    def reconcile(self, signals: ScalingSignals, now: float,
                  ) -> Optional[int]:
        """Return the replica count to converge to, or None to hold."""
        current = signals.replicas
        desired = self.policy.desired_replicas(signals)
        desired = max(self.min_replicas, min(self.max_replicas, desired))

        if desired != current and self.tolerance > 0:
            # dead band: ignore small relative drifts (anti-flap)
            if abs(desired - current) / max(current, 1) <= self.tolerance:
                desired = current

        if desired == current:
            self.decisions.append(ScalingDecision(now, current, desired,
                                                  False, "steady"))
            return None
        if desired > current:
            if now - self._last_scale_up < self.scale_up_cooldown_s:
                self.decisions.append(ScalingDecision(
                    now, current, desired, False, "up-cooldown"))
                return None
            self._last_scale_up = now
            # growing re-arms the shrink guard: a flapping workload should
            # not shrink immediately after a burst ends
            self._last_scale_down = now
        else:
            if now - self._last_scale_down < self.scale_down_cooldown_s:
                self.decisions.append(ScalingDecision(
                    now, current, desired, False, "down-cooldown"))
                return None
            self._last_scale_down = now
        self.decisions.append(ScalingDecision(now, current, desired, True,
                                              "scale"))
        return desired


# ---------------------------------------------------------------------------
# Live-plane target: replica set over the orchestrator
# ---------------------------------------------------------------------------
class OrchestratorScaler:
    """ReplicaTarget driving ``Orchestrator.scale_horizontal`` /
    ``scale_in`` for one service (a base task plus clones).

    Scale-out clones the base task's live snapshot onto the node the
    orchestrator's ``PlacementPolicy`` scores best (free vSlices first,
    then warm program caches, spread across failure domains — the paper's
    replicate command, placement-aware); scale-in removes the youngest
    replica, never the base —
    draining it first (``drain_timeout_s``) so in-flight sequences finish
    at their request boundary instead of being requeued and recomputed.
    """

    def __init__(self, orch, base_cid: str, service: str = "svc",
                 drain_timeout_s: float = 10.0):
        self.orch = orch
        self.base_cid = base_cid
        self.service = service
        self.drain_timeout_s = drain_timeout_s
        self.replica_cids: List[str] = []
        self._lock = threading.Lock()   # serializes scale_to convergence

    def current_replicas(self) -> int:
        """Lock-free snapshot read: the serving loop polls this every tick
        and must never block behind an in-flight multi-second scale_to
        (each replicate is a live checkpoint-clone)."""
        alive = 0
        for c in [self.base_cid] + list(self.replica_cids):
            dep = self.orch.deployments.get(c)
            if dep is not None and dep.status == "running":
                alive += 1
        return max(1, alive)

    def scale_to(self, n: int) -> None:
        with self._lock:
            while self.current_replicas() < n:
                # scale-out placement goes through the scheduler's unified
                # PlacementPolicy: warm program-cache affinity + failure-
                # domain anti-affinity against the service's live replicas
                node = self.orch.place_replica(self.base_cid)
                if node is None:
                    break               # cluster full: partial convergence
                new_cid = self.orch.scale_horizontal(self.base_cid, node)
                self.replica_cids.append(new_cid)
            # pick scale-in victims under the lock, but drain+remove them
            # outside it: a drain blocks for up to drain_timeout_s and must
            # not stall a concurrent scale-out decision behind the lock.
            # A popped victim no longer counts toward current_replicas()
            victims = []
            while self.current_replicas() > n and self.replica_cids:
                victims.append(self.replica_cids.pop())
        for victim in victims:
            self.orch.scale_in(victim, drain_s=self.drain_timeout_s)
        with self._lock:
            now_n = self.current_replicas()
            self.orch.metrics.gauge(
                M_REPLICAS, service=self.service).set(now_n)
            self.orch.metrics.series(
                M_REPLICAS_SERIES, service=self.service).record(now_n)
