"""Telemetry + SLO-driven workload scaling (paper §3.5 third service).

``metrics``     dependency-free registry shared by live runtime + simulator
``autoscaler``  scaling policies, hysteresis/cooldown reconciler, live target
``loadgen``     open/closed-loop traffic (Poisson, diurnal, burst) for
                elastic-serving scenarios
``serving``     live-plane drive loop for elastic-serving demos/benchmarks
"""

from repro.scaling.autoscaler import (Autoscaler, KVPressurePolicy,
                                      LatencySLOPolicy, OrchestratorScaler,
                                      QueueLengthPolicy, ScalingDecision,
                                      ScalingPolicy, ScalingSignals,
                                      TargetUtilizationPolicy,
                                      signals_from_registry)
from repro.scaling.loadgen import (ClosedLoopGen, Request, burst_rate,
                                   constant_rate, diurnal_rate, open_loop)
from repro.scaling.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                                   TimeSeries, metric_key)
from repro.scaling.serving import (DriveResult, RequestRouter,
                                   drive_engine_open_loop, drive_open_loop,
                                   get_router, reset_router,
                                   teardown_service, wait_for_service)

__all__ = [
    "Autoscaler", "ClosedLoopGen", "Counter", "DriveResult", "Gauge",
    "Histogram", "KVPressurePolicy", "LatencySLOPolicy", "MetricsRegistry",
    "OrchestratorScaler",
    "QueueLengthPolicy", "Request", "RequestRouter", "ScalingDecision",
    "ScalingPolicy",
    "ScalingSignals", "TargetUtilizationPolicy", "TimeSeries", "burst_rate",
    "constant_rate", "diurnal_rate", "drive_engine_open_loop",
    "drive_open_loop", "get_router", "metric_key",
    "open_loop", "reset_router", "signals_from_registry",
    "teardown_service", "wait_for_service",
]
