"""Attention: GQA (+ qk-norm, sliding window, partial RoPE) and MLA.

Three scaled-dot-product implementations:

* ``naive``      — materializes (Sq, Skv) scores; fine for training at 4k with
                   remat (scores are recomputed in backward).
* ``blockwise``  — FlashAttention expressed in XLA: ``lax.scan`` over KV chunks
                   with an online-softmax carry.  O(Sq * chunk) live memory;
                   the default for prefill.
* ``pallas``     — the TPU kernel in ``repro.kernels.flash_attention`` (ops.py
                   wrapper); numerically validated against ``naive`` in tests.

MLA (DeepSeek-V3) keeps a *compressed* KV cache (kv_lora + rope dims per
token).  Decode supports two paths: ``absorb=False`` decompresses the cache
every step (faithful to the algebraic definition — our paper-faithful
baseline) and ``absorb=True`` folds the decompression matrices into the query
and output projections (the optimized path; see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import cdtype, rmsnorm_1d, rope_fwd

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Scaled dot-product attention cores
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, kv_pos, *, causal: bool, window: int):
    """(…, Sq, Skv) additive bias in f32."""
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]
    keep = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        keep &= kp <= qp
    if window:
        keep &= qp - kp < window
    return jnp.where(keep, 0.0, NEG_INF).astype(jnp.float32)


def sdpa_naive(q, k, v, *, causal=True, window=0, q_pos=None, kv_pos=None,
               softcap: float = 0.0):
    """q: (B,Sq,Hq,hd); k: (B,Skv,Hkv,hd); v: (B,Skv,Hkv,hd_v).

    hd_v may differ from hd (MLA). Returns (B,Sq,Hq,hd_v).
    """
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    hd_v = v.shape[-1]
    G = Hq // Hkv
    if q_pos is None:
        q_pos = jnp.arange(Sq)
    if kv_pos is None:
        kv_pos = jnp.arange(k.shape[1])
    qg = q.reshape(B, Sq, Hkv, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores *= hd ** -0.5
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    scores += _mask_bias(q_pos, kv_pos, causal=causal, window=window)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, Hq, hd_v)


def sdpa_blockwise(q, k, v, *, causal=True, window=0, q_pos=None, kv_pos=None,
                   chunk: int = 1024, softcap: float = 0.0):
    """Online-softmax attention, scanning KV in chunks (flash in XLA)."""
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    G = Hq // Hkv
    chunk = min(chunk, Skv)
    assert Skv % chunk == 0, (Skv, chunk)
    nc = Skv // chunk
    if q_pos is None:
        q_pos = jnp.arange(Sq)
    if kv_pos is None:
        kv_pos = jnp.arange(Skv)

    qg = qf = q.reshape(B, Sq, Hkv, G, hd)
    ks = k.reshape(B, nc, chunk, Hkv, hd).swapaxes(0, 1)
    vs = v.reshape(B, nc, chunk, Hkv, hd_v).swapaxes(0, 1)
    kps = kv_pos.reshape(nc, chunk)

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, G, hd_v), jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, kp = inp
        s = jnp.einsum("bqkgh,bckh->bkgqc", qf, kc).astype(jnp.float32)
        s *= hd ** -0.5
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        s += _mask_bias(q_pos, kp, causal=causal, window=window)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqc,bckh->bqkgh", p.astype(q.dtype), vc).astype(jnp.float32)
        acc_new = acc * scale.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, kps))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.astype(q.dtype).reshape(B, Sq, Hq, hd_v)


def sdpa(q, k, v, *, impl="naive", **kw):
    if impl == "blockwise":
        return sdpa_blockwise(q, k, v, **kw)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        kw.pop("chunk", None)
        return fa_ops.flash_attention(q, k, v, **kw)
    kw.pop("chunk", None)
    return sdpa_naive(q, k, v, **kw)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def init_gqa(cfg: ModelConfig, key: jax.Array) -> dict:
    dt = cdtype(cfg)
    hd = cfg.head_dim_
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = cfg.d_model ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (cfg.d_model, cfg.num_heads, hd)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (cfg.d_model, cfg.num_kv_heads, hd)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (cfg.d_model, cfg.num_kv_heads, hd)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (cfg.num_heads, hd, cfg.d_model))
               * (cfg.num_heads * hd) ** -0.5).astype(dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm_1d(q, p["q_norm"])
        k = rmsnorm_1d(k, p["k_norm"])
    q = rope_fwd(q, positions, cfg.rope_theta, cfg.rope_pct)
    k = rope_fwd(k, positions, cfg.rope_theta, cfg.rope_pct)
    return q, k, v


def gqa_fwd(cfg: ModelConfig, p: dict, x: jax.Array, *, window: int | None = None,
            causal: bool = True, impl: str = "naive", positions=None,
            chunk: int = 1024) -> jax.Array:
    """Full-sequence attention (train / prefill). x: (B, S, D)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = _project_qkv(cfg, p, x, positions)
    w = cfg.sliding_window if window is None else window
    out = sdpa(q, k, v, impl=impl, causal=causal, window=w,
               q_pos=positions, kv_pos=positions, chunk=chunk,
               softcap=cfg.attn_logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


_INVALID_POS = jnp.int32(2**30)  # masked-out slot sentinel (kv_pos > q_pos)


def _ring_cache_from_prefill(entries: dict, S: int, cap: int) -> dict:
    """Place prefill entries at ring slots ``pos % cap`` so subsequent decode
    writes (slot = pos % cap) evict oldest-first; unfilled slots get the
    INVALID sentinel."""
    n = min(S, cap)
    pos = jnp.arange(S - n, S, dtype=jnp.int32)
    idx = pos % cap
    out = {}
    for name, arr in entries.items():
        buf = jnp.zeros((arr.shape[0], cap) + arr.shape[2:], arr.dtype)
        out[name] = buf.at[:, idx].set(arr[:, S - n:])
    out["kv_pos"] = jnp.full((cap,), _INVALID_POS, jnp.int32).at[idx].set(pos)
    return out


def gqa_prefill(cfg: ModelConfig, p: dict, x: jax.Array, *, window: int | None = None,
                impl: str = "blockwise", chunk: int = 1024, margin: int = 0):
    """Prefill: returns (out, cache).

    The cache is a *ring buffer* of capacity ``min(S + margin, window or inf)``
    holding post-RoPE k/v plus the absolute position of each slot
    (``kv_pos``) — sliding-window layers therefore decode 500k-token contexts
    with O(window) memory.  ``margin`` reserves headroom so decode extends the
    context instead of immediately evicting the oldest prefill entries.
    """
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q, k, v = _project_qkv(cfg, p, x, positions)
    w = cfg.sliding_window if window is None else window
    out = sdpa(q, k, v, impl=impl, causal=True, window=w,
               q_pos=positions, kv_pos=positions, chunk=chunk,
               softcap=cfg.attn_logit_softcap)
    cap = min(S + margin, w) if w else S + margin
    cache = _ring_cache_from_prefill({"k": k, "v": v}, S, cap)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache


def gqa_decode(cfg: ModelConfig, p: dict, x: jax.Array, pos: jax.Array,
               cache: dict, *, window: int | None = None, impl: str = "xla"):
    """One-token decode. x: (B, 1, D); cache k/v: (B, cap, Hkv, hd).

    Writes the new k/v at ring slot ``pos % cap`` and attends over cached
    absolute positions <= pos (within the sliding window, if any).
    """
    cap = cache["k"].shape[1]
    positions = jnp.full((1,), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    slot = jnp.asarray(pos, jnp.int32) % cap
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    kv_pos = jax.lax.dynamic_update_slice(
        cache["kv_pos"], positions, (slot,))
    w = cfg.sliding_window if window is None else window
    if impl == "pallas":
        from repro.kernels.decode_attention import ops as da_ops

        out = da_ops.decode_attention(q, k, v, pos, kv_pos=kv_pos, window=w,
                                      softcap=cfg.attn_logit_softcap)
    else:
        out = sdpa_naive(q, k, v, causal=True, window=w,
                         q_pos=positions, kv_pos=kv_pos,
                         softcap=cfg.attn_logit_softcap)
    new_cache = {"k": k, "v": v, "kv_pos": kv_pos}
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def gqa_cache_spec(cfg: ModelConfig, batch: int, max_len: int, *,
                   window: int | None = None) -> dict:
    """Abstract cache shapes (window-bounded for sliding-window layers)."""
    w = cfg.sliding_window if window is None else window
    cap = min(max_len, w) if w else max_len
    shp = (batch, cap, cfg.num_kv_heads, cfg.head_dim_)
    dt = cdtype(cfg)
    return {
        "k": jax.ShapeDtypeStruct(shp, dt),
        "v": jax.ShapeDtypeStruct(shp, dt),
        "kv_pos": jax.ShapeDtypeStruct((cap,), jnp.int32),
    }


def gqa_cache_init(cfg: ModelConfig, batch: int, max_len: int, *,
                   window: int | None = None) -> dict:
    spec = gqa_cache_spec(cfg, batch, max_len, window=window)
    out = {k: jnp.zeros(v.shape, v.dtype) for k, v in spec.items()}
    out["kv_pos"] = jnp.full(spec["kv_pos"].shape, _INVALID_POS, jnp.int32)
    return out


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V3)
# ---------------------------------------------------------------------------

def init_mla(cfg: ModelConfig, key: jax.Array) -> dict:
    m = cfg.mla
    dt = cdtype(cfg)
    ks = jax.random.split(key, 6)
    D, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    s = D ** -0.5
    return {
        "wq_a": (jax.random.normal(ks[0], (D, m.q_lora_rank)) * s).astype(dt),
        "q_norm": jnp.ones((m.q_lora_rank,), dt),
        "wq_b": (jax.random.normal(ks[1], (m.q_lora_rank, H, qk))
                 * m.q_lora_rank ** -0.5).astype(dt),
        "wkv_a": (jax.random.normal(ks[2], (D, m.kv_lora_rank + m.qk_rope_head_dim))
                  * s).astype(dt),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dt),
        "wk_b": (jax.random.normal(ks[3], (m.kv_lora_rank, H, m.qk_nope_head_dim))
                 * m.kv_lora_rank ** -0.5).astype(dt),
        "wv_b": (jax.random.normal(ks[4], (m.kv_lora_rank, H, m.v_head_dim))
                 * m.kv_lora_rank ** -0.5).astype(dt),
        "wo": (jax.random.normal(ks[5], (H, m.v_head_dim, D))
               * (H * m.v_head_dim) ** -0.5).astype(dt),
    }


def _mla_q(cfg: ModelConfig, p: dict, x, positions):
    m = cfg.mla
    cq = rmsnorm_1d(x @ p["wq_a"], p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_pe = rope_fwd(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_pe


def _mla_latent(cfg: ModelConfig, p: dict, x, positions):
    m = cfg.mla
    ckv_full = x @ p["wkv_a"]
    ckv = rmsnorm_1d(ckv_full[..., : m.kv_lora_rank], p["kv_norm"])
    k_pe = ckv_full[..., m.kv_lora_rank:][:, :, None, :]  # single rope "head"
    k_pe = rope_fwd(k_pe, positions, cfg.rope_theta)[:, :, 0, :]
    return ckv, k_pe


def mla_fwd(cfg: ModelConfig, p: dict, x: jax.Array, *, positions=None,
            impl: str = "naive", chunk: int = 1024) -> jax.Array:
    """Full-sequence MLA (train / prefill math, decompressed form)."""
    m = cfg.mla
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q_nope, q_pe = _mla_q(cfg, p, x, positions)
    ckv, k_pe = _mla_latent(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["wv_b"])
    k_pe_h = jnp.broadcast_to(k_pe[:, :, None, :], q_pe.shape[:1] + (S,) + q_pe.shape[2:])
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate([k_nope, k_pe_h], axis=-1)
    # sdpa scales by k.shape[-1] ** -0.5 == (qk_nope + qk_rope) ** -0.5 already.
    out = sdpa(q, k, v, impl=impl, causal=True, window=0,
               q_pos=positions, kv_pos=positions, chunk=chunk)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def mla_prefill(cfg: ModelConfig, p: dict, x: jax.Array, *, impl="blockwise",
                chunk: int = 1024, margin: int = 0):
    B, S, _ = x.shape
    positions = jnp.arange(S)
    out = mla_fwd(cfg, p, x, positions=positions, impl=impl, chunk=chunk)
    ckv, k_pe = _mla_latent(cfg, p, x, positions)
    cache = _ring_cache_from_prefill({"ckv": ckv, "k_pe": k_pe}, S, S + margin)
    return out, cache


def mla_decode(cfg: ModelConfig, p: dict, x: jax.Array, pos: jax.Array,
               cache: dict, *, absorb: bool = True):
    """One-token MLA decode over the compressed cache.

    cache: {"ckv": (B, Smax, kv_lora), "k_pe": (B, Smax, rope_dim)}.
    ``absorb=False`` decompresses the whole cache each step (baseline);
    ``absorb=True`` runs attention in latent space (optimized).
    """
    m = cfg.mla
    B = x.shape[0]
    cap = cache["ckv"].shape[1]
    positions = jnp.full((1,), pos, jnp.int32)
    q_nope, q_pe = _mla_q(cfg, p, x, positions)          # (B,1,H,*)
    ckv_new, k_pe_new = _mla_latent(cfg, p, x, positions)
    slot = jnp.asarray(pos, jnp.int32) % cap
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new, (0, slot, 0))
    k_pe = jax.lax.dynamic_update_slice(cache["k_pe"], k_pe_new, (0, slot, 0))
    kv_pos = jax.lax.dynamic_update_slice(cache["kv_pos"], positions, (slot,))
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    mask = jnp.where(kv_pos <= pos, 0.0, NEG_INF)[None, None, :]

    if absorb:
        # score = (q_nope Wk_b^T) . ckv + q_pe . k_pe  — never decompress.
        q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["wk_b"])
        s = (jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv)
             + jnp.einsum("bqhk,bsk->bhqs", q_pe, k_pe)).astype(jnp.float32)
        s = s[:, :, 0, :] * scale + mask
        probs = jax.nn.softmax(s, axis=-1).astype(x.dtype)       # (B,H,S)
        o_lat = jnp.einsum("bhs,bsr->bhr", probs, ckv)
        out = jnp.einsum("bhr,rhk->bhk", o_lat, p["wv_b"])[:, None]
    else:
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wk_b"])
        v = jnp.einsum("bsr,rhk->bshk", ckv, p["wv_b"])
        s = (jnp.einsum("bqhk,bshk->bhqs", q_nope, k_nope)
             + jnp.einsum("bqhk,bsk->bhqs", q_pe, k_pe)).astype(jnp.float32)
        s = s[:, :, 0, :] * scale + mask
        probs = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhs,bshk->bhk", probs, v)[:, None]
    new_cache = {"ckv": ckv, "k_pe": k_pe, "kv_pos": kv_pos}
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"]), new_cache


def mla_cache_spec(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    m = cfg.mla
    dt = cdtype(cfg)
    return {
        "ckv": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), dt),
        "k_pe": jax.ShapeDtypeStruct((batch, max_len, m.qk_rope_head_dim), dt),
        "kv_pos": jax.ShapeDtypeStruct((max_len,), jnp.int32),
    }


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    spec = mla_cache_spec(cfg, batch, max_len)
    out = {k: jnp.zeros(v.shape, v.dtype) for k, v in spec.items()}
    out["kv_pos"] = jnp.full((max_len,), _INVALID_POS, jnp.int32)
    return out
