"""Encoder-decoder transformer (seamless-m4t backbone).

The speech/text modality frontend is a stub per the assignment: the encoder
consumes precomputed frame embeddings ``src_emb`` (B, S_src, d_model)
directly.  The decoder is a causal transformer with cross-attention into the
encoder memory; serve-decode keeps a ring-buffer self-attention cache of
capacity ``seq_len`` plus constant cross-attention k/v.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (cdtype, cross_entropy, embed_fwd, init_embed,
                                 init_mlp, init_norm, lm_head_fwd, mlp_fwd,
                                 norm_fwd)


# ---------------------------------------------------------------------------
# Cross attention
# ---------------------------------------------------------------------------

def init_cross_attn(cfg: ModelConfig, key: jax.Array) -> dict:
    return attn.init_gqa(cfg, key)


def cross_kv(cfg: ModelConfig, p: dict, memory: jax.Array):
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
    return k, v


def cross_attn_fwd(cfg: ModelConfig, p: dict, x: jax.Array, k, v, *,
                   impl: str = "naive", chunk: int = 1024):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    Sq, Skv = q.shape[1], k.shape[1]
    out = attn.sdpa(q, k, v, impl=impl, causal=False, window=0,
                    q_pos=jnp.arange(Sq), kv_pos=jnp.arange(Skv), chunk=chunk)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------

def _init_enc_layer(cfg: ModelConfig, key: jax.Array) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": init_norm(cfg, cfg.d_model),
        "attn": attn.init_gqa(cfg, k1),
        "norm2": init_norm(cfg, cfg.d_model),
        "mlp": init_mlp(cfg, k2, cfg.d_model, cfg.d_ff),
    }


def _init_dec_layer(cfg: ModelConfig, key: jax.Array) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": init_norm(cfg, cfg.d_model),
        "self_attn": attn.init_gqa(cfg, k1),
        "norm_x": init_norm(cfg, cfg.d_model),
        "cross_attn": init_cross_attn(cfg, k2),
        "norm2": init_norm(cfg, cfg.d_model),
        "mlp": init_mlp(cfg, k3, cfg.d_model, cfg.d_ff),
    }


def _enc_layer(cfg, p, x, *, impl):
    h = norm_fwd(cfg, p["norm1"], x)
    x = x + attn.gqa_fwd(cfg, p["attn"], h, causal=False, impl=impl)
    h = norm_fwd(cfg, p["norm2"], x)
    return x + mlp_fwd(cfg, p["mlp"], h)


def _dec_layer(cfg, p, x, memory_kv, *, mode, cache, pos, impl, chunk,
               cache_margin=0):
    new_cache = None
    h = norm_fwd(cfg, p["norm1"], x)
    if mode == "train":
        x = x + attn.gqa_fwd(cfg, p["self_attn"], h, impl=impl)
    elif mode == "prefill":
        mix, self_cache = attn.gqa_prefill(cfg, p["self_attn"], h, impl=impl,
                                           chunk=chunk, margin=cache_margin)
        x = x + mix
    else:
        mix, self_cache = attn.gqa_decode(cfg, p["self_attn"], h, pos,
                                          cache["self"])
        x = x + mix
    h = norm_fwd(cfg, p["norm_x"], x)
    ck, cv = memory_kv
    x = x + cross_attn_fwd(cfg, p["cross_attn"], h, ck, cv,
                           impl=impl if mode != "decode" else "naive",
                           chunk=chunk)
    h = norm_fwd(cfg, p["norm2"], x)
    x = x + mlp_fwd(cfg, p["mlp"], h)
    if mode in ("prefill", "decode"):
        new_cache = {"self": self_cache, "cross_k": ck, "cross_v": cv}
    return x, new_cache


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_encdec(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 4)
    enc = [_init_enc_layer(cfg, k) for k in jax.random.split(ks[0], cfg.encoder_layers)]
    dec = [_init_dec_layer(cfg, k) for k in jax.random.split(ks[1], cfg.num_layers)]
    return {
        "embed": init_embed(cfg, ks[2]),
        "enc_stack": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "enc_final_norm": init_norm(cfg, cfg.d_model),
        "dec_stack": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "dec_final_norm": init_norm(cfg, cfg.d_model),
    }


def encode(cfg: ModelConfig, params: dict, src_emb: jax.Array, *,
           impl="naive", remat="none", scan_unroll=False):
    def body(x, p):
        return _enc_layer(cfg, p, x, impl=impl), None

    if remat != "none":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, src_emb.astype(cdtype(cfg)),
                        params["enc_stack"],
                        unroll=cfg.encoder_layers if scan_unroll else 1)
    return norm_fwd(cfg, params["enc_final_norm"], h)


def encdec_loss(cfg: ModelConfig, params: dict, batch: dict, *, mesh=None,
                impl="naive", dp_axes=("data",), remat="none",
                scan_unroll=False):
    """batch: src_emb (B,S_src,D), tgt_tokens (B,S_tgt), tgt_targets."""
    memory = encode(cfg, params, batch["src_emb"], impl=impl, remat=remat,
                    scan_unroll=scan_unroll)
    x = embed_fwd(cfg, params["embed"], batch["tgt_tokens"])

    def body(x, p):
        kv = cross_kv(cfg, p["cross_attn"], memory)
        x, _ = _dec_layer(cfg, p, x, kv, mode="train", cache=None, pos=None,
                          impl=impl, chunk=1024)
        return x, None

    if remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_stack"],
                        unroll=cfg.num_layers if scan_unroll else 1)
    x = norm_fwd(cfg, params["dec_final_norm"], x)
    logits = lm_head_fwd(cfg, params["embed"], x)
    from repro.models.layers import shard_logits

    logits = shard_logits(logits, mesh, dp_axes)
    loss = cross_entropy(logits, batch["tgt_targets"], batch.get("loss_mask"))
    return loss, {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32)}


def encdec_prefill(cfg: ModelConfig, params: dict, batch: dict, *, mesh=None,
                   impl="blockwise", prefill_chunk=1024, dp_axes=("data",),
                   scan_unroll=False, cache_margin=0):
    """Encode src, prefill the decoder over the target prompt."""
    memory = encode(cfg, params, batch["src_emb"], impl=impl,
                    scan_unroll=scan_unroll)
    x = embed_fwd(cfg, params["embed"], batch["tgt_tokens"])

    def body(x, p):
        kv = cross_kv(cfg, p["cross_attn"], memory)
        x, cache = _dec_layer(cfg, p, x, kv, mode="prefill", cache=None,
                              pos=None, impl=impl, chunk=prefill_chunk,
                              cache_margin=cache_margin)
        return x, cache

    x, caches = jax.lax.scan(body, x, params["dec_stack"],
                             unroll=cfg.num_layers if scan_unroll else 1)
    x = norm_fwd(cfg, params["dec_final_norm"], x)
    logits = lm_head_fwd(cfg, params["embed"], x[:, -1:, :])
    return logits[:, 0, :], caches


def encdec_decode(cfg: ModelConfig, params: dict, token: jax.Array,
                  pos: jax.Array, caches, *, mesh=None, mla_absorb=True,
                  dp_axes=("data",), scan_unroll=False):
    x = embed_fwd(cfg, params["embed"], token[:, None])

    def body(x, inp):
        p, cache = inp
        kv = (cache["cross_k"], cache["cross_v"])
        x, new_cache = _dec_layer(cfg, p, x, kv, mode="decode", cache=cache,
                                  pos=pos, impl="naive", chunk=1024)
        return x, new_cache

    x, caches = jax.lax.scan(body, x, (params["dec_stack"], caches),
                             unroll=cfg.num_layers if scan_unroll else 1)
    x = norm_fwd(cfg, params["dec_final_norm"], x)
    logits = lm_head_fwd(cfg, params["embed"], x)
    return logits[:, 0, :], caches


def encdec_cache_specs(cfg: ModelConfig, batch: int, self_len: int,
                       src_len: int):
    """Stacked decode cache specs: self ring cache + constant cross k/v."""
    dt = cdtype(cfg)
    L = cfg.num_layers
    self_spec = attn.gqa_cache_spec(cfg, batch, self_len, window=0)
    kv_shape = (L, batch, src_len, cfg.num_kv_heads, cfg.head_dim_)
    return {
        "self": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((L,) + s.shape, s.dtype), self_spec),
        "cross_k": jax.ShapeDtypeStruct(kv_shape, dt),
        "cross_v": jax.ShapeDtypeStruct(kv_shape, dt),
    }
