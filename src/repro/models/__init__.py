from repro.models.model_zoo import (ModelBundle, analytic_param_count,
                                    build_model, input_specs)

__all__ = ["ModelBundle", "analytic_param_count", "build_model", "input_specs"]
