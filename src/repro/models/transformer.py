"""Decoder-only LM composer.

Every architecture is described as a list of **segments**; a segment is
``count`` repetitions of a short tuple of **block specs** (one scan unit).
Examples:

    yi-9b:            [48 x ("attn+mlp",)]
    deepseek-v3:      [3 x ("mla+mlp",), 58 x ("mla+moe",)]
    mamba2:           [48 x ("ssm",)]
    recurrentgemma:   [12 x ("rec+mlp","rec+mlp","attn+mlp"), 1 x ("rec+mlp","rec+mlp")]

Each segment's parameters are stacked along a leading ``count`` axis and the
segment is applied with ``jax.lax.scan`` — HLO stays O(1 layer), which keeps
multi-billion-parameter dry-run compiles fast.  Remat (``jax.checkpoint``) is
applied to the scan body; policy is configurable.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import rglru, ssm
from repro.models.layers import (cross_entropy, embed_fwd, init_embed,
                                 init_mlp, init_norm, lm_head_fwd, mlp_fwd,
                                 norm_fwd)
from repro.models.moe import init_moe, moe_fwd


@dataclass(frozen=True)
class BlockSpec:
    mixer: str            # attn | mla | rec | ssm
    ffn: str = "mlp"      # mlp | moe | none
    window: int = 0       # sliding-window for attn mixers (0 = full)
    d_ff: int = 0         # mlp hidden size


@dataclass(frozen=True)
class Segment:
    count: int
    blocks: Tuple[BlockSpec, ...]


def plan_segments(cfg: ModelConfig) -> list[Segment]:
    L = cfg.num_layers
    if cfg.family == "ssm":
        return [Segment(L, (BlockSpec("ssm", "none"),))]
    if cfg.family == "hybrid":
        pat = tuple(
            BlockSpec("rec", "mlp", d_ff=cfg.d_ff) if c == "r"
            else BlockSpec("attn", "mlp", window=cfg.sliding_window, d_ff=cfg.d_ff)
            for c in cfg.rec.block_pattern)
        reps, rem = divmod(L, len(pat))
        segs = [Segment(reps, pat)]
        if rem:
            segs.append(Segment(1, pat[:rem]))
        return segs
    if cfg.moe.enabled:
        mixer = "mla" if cfg.mla.enabled else "attn"
        segs = []
        nd = cfg.moe.n_dense_layers
        if nd:
            segs.append(Segment(nd, (BlockSpec(mixer, "mlp", d_ff=cfg.moe.dense_d_ff),)))
        segs.append(Segment(L - nd, (BlockSpec(mixer, "moe"),)))
        return segs
    # dense / vlm (and the per-stack halves of encdec reuse "attn" blocks)
    return [Segment(L, (BlockSpec("attn", "mlp", window=cfg.sliding_window,
                                  d_ff=cfg.d_ff),))]


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------

def init_block(cfg: ModelConfig, key: jax.Array, spec: BlockSpec) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict = {"norm1": init_norm(cfg, cfg.d_model)}
    if spec.mixer == "attn":
        p["attn"] = attn.init_gqa(cfg, k1)
    elif spec.mixer == "mla":
        p["attn"] = attn.init_mla(cfg, k1)
    elif spec.mixer == "rec":
        p["rec"] = rglru.init_rec_block(cfg, k1)
    elif spec.mixer == "ssm":
        p["ssm"] = ssm.init_ssm_block(cfg, k1)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != "none":
        p["norm2"] = init_norm(cfg, cfg.d_model)
        if spec.ffn == "mlp":
            p["mlp"] = init_mlp(cfg, k2, cfg.d_model, spec.d_ff)
        elif spec.ffn == "moe":
            p["moe"] = init_moe(cfg, k3)
        else:
            raise ValueError(spec.ffn)
    return p


def block_apply(cfg: ModelConfig, p: dict, spec: BlockSpec, x: jax.Array, *,
                mode: str, cache: Optional[dict], pos, mesh, impl: str,
                prefill_chunk: int, mla_absorb: bool,
                dp_axes: Tuple[str, ...], cache_margin: int = 0):
    """mode: train | prefill | decode. Returns (x, aux_loss, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    h = norm_fwd(cfg, p["norm1"], x)
    new_cache = None
    if spec.mixer == "attn":
        if mode == "train":
            mix = attn.gqa_fwd(cfg, p["attn"], h, window=spec.window, impl=impl)
        elif mode == "prefill":
            mix, new_cache = attn.gqa_prefill(
                cfg, p["attn"], h, window=spec.window, impl=impl,
                chunk=prefill_chunk, margin=cache_margin)
        else:
            mix, new_cache = attn.gqa_decode(
                cfg, p["attn"], h, pos, cache, window=spec.window)
    elif spec.mixer == "mla":
        if mode == "train":
            mix = attn.mla_fwd(cfg, p["attn"], h, impl=impl)
        elif mode == "prefill":
            mix, new_cache = attn.mla_prefill(
                cfg, p["attn"], h, impl=impl, chunk=prefill_chunk,
                margin=cache_margin)
        else:
            mix, new_cache = attn.mla_decode(
                cfg, p["attn"], h, pos, cache, absorb=mla_absorb)
    elif spec.mixer == "rec":
        if mode == "train":
            mix = rglru.rec_block_fwd(cfg, p["rec"], h, impl=impl)
        elif mode == "prefill":
            mix, new_cache = rglru.rec_block_prefill(cfg, p["rec"], h)
        else:
            mix, new_cache = rglru.rec_block_step(cfg, p["rec"], h, cache)
    elif spec.mixer == "ssm":
        if mode == "train":
            mix = ssm.ssm_block_fwd(cfg, p["ssm"], h, impl=impl)
        elif mode == "prefill":
            mix, new_cache = ssm.ssm_block_prefill(cfg, p["ssm"], h, impl=impl)
        else:
            mix, new_cache = ssm.ssm_block_step(cfg, p["ssm"], h, cache)
    else:
        raise ValueError(spec.mixer)
    x = x + mix
    if spec.ffn != "none":
        h2 = norm_fwd(cfg, p["norm2"], x)
        if spec.ffn == "mlp":
            x = x + mlp_fwd(cfg, p["mlp"], h2)
        else:
            out, aux = moe_fwd(cfg, p["moe"], h2, mesh=mesh, dp_axes=dp_axes,
                               dispatch=cfg.moe_dispatch)
            x = x + out
    return x, aux, new_cache


def block_cache_spec(cfg: ModelConfig, spec: BlockSpec, batch: int,
                     max_len: int):
    if spec.mixer == "attn":
        return attn.gqa_cache_spec(cfg, batch, max_len, window=spec.window)
    if spec.mixer == "mla":
        return attn.mla_cache_spec(cfg, batch, max_len)
    if spec.mixer == "rec":
        return rglru.rec_cache_spec(cfg, batch)
    if spec.mixer == "ssm":
        return ssm.ssm_cache_spec(cfg, batch)
    raise ValueError(spec.mixer)


# ---------------------------------------------------------------------------
# Segments (scan over stacked layer params)
# ---------------------------------------------------------------------------

def init_segment(cfg: ModelConfig, key: jax.Array, seg: Segment) -> dict:
    reps = []
    for k in jax.random.split(key, seg.count):
        bkeys = jax.random.split(k, len(seg.blocks))
        reps.append({"blocks": tuple(
            init_block(cfg, bk, spec) for bk, spec in zip(bkeys, seg.blocks))})
    return jax.tree.map(lambda *xs: jnp.stack(xs), *reps)


_POLICIES = {
    "none": None,
    "full": None,  # jax.checkpoint default: save nothing
    "dots": "dots_with_no_batch_dims_saveable",
}


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    policy = getattr(jax.checkpoint_policies, _POLICIES[remat])
    return jax.checkpoint(fn, policy=policy)


def segment_apply(cfg: ModelConfig, p_stacked: dict, seg: Segment,
                  x: jax.Array, *, mode: str, caches=None, pos=None, mesh,
                  impl: str, prefill_chunk: int, mla_absorb: bool,
                  dp_axes: Tuple[str, ...], remat: str,
                  scan_unroll: bool = False, cache_margin: int = 0):
    unroll = seg.count if scan_unroll else 1
    kw = dict(mode=mode, pos=pos, mesh=mesh, impl=impl,
              prefill_chunk=prefill_chunk, mla_absorb=mla_absorb,
              dp_axes=dp_axes, cache_margin=cache_margin)

    if mode == "train":
        def body(carry, rep_p):
            x, aux = carry
            for i, spec in enumerate(seg.blocks):
                x, a, _ = block_apply(cfg, rep_p["blocks"][i], spec, x,
                                      cache=None, **kw)
                aux = aux + a
            return (x, aux), None

        body = _maybe_remat(body, remat)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   p_stacked, unroll=unroll)
        return x, aux, None

    if mode == "prefill":
        def body(x, rep_p):
            caches_out = []
            for i, spec in enumerate(seg.blocks):
                x, _, c = block_apply(cfg, rep_p["blocks"][i], spec, x,
                                      cache=None, **kw)
                caches_out.append(c)
            return x, tuple(caches_out)

        x, caches_out = jax.lax.scan(body, x, p_stacked, unroll=unroll)
        return x, jnp.zeros((), jnp.float32), caches_out

    # decode
    def body(x, inp):
        rep_p, rep_cache = inp
        caches_out = []
        for i, spec in enumerate(seg.blocks):
            x, _, c = block_apply(cfg, rep_p["blocks"][i], spec, x,
                                  cache=rep_cache[i], **kw)
            caches_out.append(c)
        return x, tuple(caches_out)

    x, caches_out = jax.lax.scan(body, x, (p_stacked, caches),
                                 unroll=unroll)
    return x, jnp.zeros((), jnp.float32), caches_out


def segment_cache_specs(cfg: ModelConfig, seg: Segment, batch: int,
                        max_len: int):
    per_block = tuple(block_cache_spec(cfg, spec, batch, max_len)
                      for spec in seg.blocks)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((seg.count,) + s.shape, s.dtype),
        per_block)


# ---------------------------------------------------------------------------
# Full decoder-only LM
# ---------------------------------------------------------------------------

def init_lm(cfg: ModelConfig, key: jax.Array) -> dict:
    segs = plan_segments(cfg)
    keys = jax.random.split(key, len(segs) + 2)
    return {
        "embed": init_embed(cfg, keys[0]),
        "segments": tuple(init_segment(cfg, k, s) for k, s in zip(keys[1:], segs)),
        "final_norm": init_norm(cfg, cfg.d_model),
    }


def lm_backbone(cfg: ModelConfig, params: dict, h: jax.Array, *, mode: str,
                caches=None, pos=None, mesh=None, impl="naive",
                prefill_chunk=1024, mla_absorb=True, dp_axes=("data",),
                remat="none", scan_unroll=False, cache_margin=0):
    """Run all segments over input embeddings h. Returns (h, aux, caches)."""
    from repro.models.layers import shard_batch_dim

    h = shard_batch_dim(h, mesh, dp_axes)
    segs = plan_segments(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    caches_out = []
    for i, seg in enumerate(segs):
        seg_cache = caches[i] if caches is not None else None
        h, aux, c = segment_apply(
            cfg, params["segments"][i], seg, h, mode=mode, caches=seg_cache,
            pos=pos, mesh=mesh, impl=impl, prefill_chunk=prefill_chunk,
            mla_absorb=mla_absorb, dp_axes=dp_axes, remat=remat,
            scan_unroll=scan_unroll, cache_margin=cache_margin)
        aux_total = aux_total + aux
        caches_out.append(c)
    h = norm_fwd(cfg, params["final_norm"], h)
    return h, aux_total, tuple(caches_out) if mode != "train" else None


def lm_loss(cfg: ModelConfig, params: dict, batch: dict, *, mesh=None,
            impl="naive", dp_axes=("data",), remat="none",
            scan_unroll=False):
    """batch: tokens (B,S) int32, targets (B,S) int32, optional loss_mask,
    optional img_emb (B,N,D) spliced in front (VLM)."""
    h = embed_fwd(cfg, params["embed"], batch["tokens"])
    mask = batch.get("loss_mask")
    if cfg.num_image_tokens and "img_emb" in batch:
        img = batch["img_emb"].astype(h.dtype)
        h = jnp.concatenate([img, h], axis=1)
    h, aux, _ = lm_backbone(cfg, params, h, mode="train", mesh=mesh,
                            impl=impl, dp_axes=dp_axes, remat=remat,
                            scan_unroll=scan_unroll)
    if cfg.num_image_tokens and "img_emb" in batch:
        h = h[:, cfg.num_image_tokens:, :]      # loss over text positions only
    logits = lm_head_fwd(cfg, params["embed"], h)
    from repro.models.layers import shard_logits

    logits = shard_logits(logits, mesh, dp_axes)
    loss = cross_entropy(logits, batch["targets"], mask)
    total = loss + cfg.moe.router_aux_weight * aux
    return total, {"loss": loss, "aux_loss": aux}


def lm_prefill(cfg: ModelConfig, params: dict, batch: dict, *, mesh=None,
               impl="blockwise", prefill_chunk=1024, dp_axes=("data",),
               scan_unroll=False, cache_margin=0):
    """Returns (last-token logits, caches)."""
    h = embed_fwd(cfg, params["embed"], batch["tokens"])
    if cfg.num_image_tokens and "img_emb" in batch:
        h = jnp.concatenate([batch["img_emb"].astype(h.dtype), h], axis=1)
    h, _, caches = lm_backbone(cfg, params, h, mode="prefill", mesh=mesh,
                               impl=impl, prefill_chunk=prefill_chunk,
                               dp_axes=dp_axes, scan_unroll=scan_unroll,
                               cache_margin=cache_margin)
    logits = lm_head_fwd(cfg, params["embed"], h[:, -1:, :])
    return logits[:, 0, :], caches


def lm_decode(cfg: ModelConfig, params: dict, token: jax.Array,
              pos: jax.Array, caches, *, mesh=None, mla_absorb=True,
              dp_axes=("data",), scan_unroll=False):
    """token: (B,) int32; pos: scalar int32. Returns (logits, caches)."""
    h = embed_fwd(cfg, params["embed"], token[:, None])
    h, _, caches = lm_backbone(cfg, params, h, mode="decode", caches=caches,
                               pos=pos, mesh=mesh, mla_absorb=mla_absorb,
                               dp_axes=dp_axes, scan_unroll=scan_unroll)
    logits = lm_head_fwd(cfg, params["embed"], h)
    return logits[:, 0, :], caches


def lm_cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    return tuple(segment_cache_specs(cfg, seg, batch, max_len)
                 for seg in plan_segments(cfg))
