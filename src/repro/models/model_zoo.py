"""Model zoo: one ``build_model`` entry point for all 10 assigned archs.

``ModelBundle`` packages the functional API the rest of the framework uses:

    init(rng)                      -> params pytree
    loss_fn(params, batch)         -> (loss, metrics)      [train shapes]
    prefill_fn(params, batch)      -> (logits, caches)     [prefill shapes]
    decode_fn(params, tok, pos, caches) -> (logits, caches) [decode shapes]

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of a given (arch x shape) cell — the dry-run lowers against these
without allocating anything.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as _encdec
from repro.models import transformer as _tf
from repro.models.layers import cdtype


@dataclass
class ModelBundle:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    loss_fn: Callable[..., Any]
    prefill_fn: Callable[..., Any]
    decode_fn: Callable[..., Any]
    cache_specs: Callable[[int, int], Any]


def _default_dp_axes(mesh) -> tuple[str, ...]:
    if mesh is None:
        return ("data",)
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _trivial_mesh():
    from repro.launch.mesh import compat_make_mesh

    n = jax.device_count()
    return compat_make_mesh((1, n), ("data", "model"))


def build_model(cfg: ModelConfig, *, mesh=None, impl: str = "naive",
                prefill_impl: str = "blockwise", remat: str = "none",
                dp_axes: tuple[str, ...] | None = None,
                mla_absorb: bool = True, prefill_chunk: int = 1024,
                scan_unroll: bool = False,
                cache_margin: int = 128) -> ModelBundle:
    if mesh is None and cfg.moe.enabled:
        mesh = _trivial_mesh()
    if dp_axes is None:
        dp_axes = _default_dp_axes(mesh)

    if cfg.family == "encdec":
        return ModelBundle(
            cfg=cfg,
            init=partial(_encdec.init_encdec, cfg),
            loss_fn=partial(_encdec.encdec_loss, cfg, mesh=mesh, impl=impl,
                            dp_axes=dp_axes, remat=remat,
                            scan_unroll=scan_unroll),
            prefill_fn=partial(_encdec.encdec_prefill, cfg, mesh=mesh,
                               impl=prefill_impl, prefill_chunk=prefill_chunk,
                               dp_axes=dp_axes, scan_unroll=scan_unroll,
                               cache_margin=cache_margin),
            decode_fn=partial(_encdec.encdec_decode, cfg, mesh=mesh,
                              dp_axes=dp_axes, scan_unroll=scan_unroll),
            cache_specs=lambda b, s: _encdec.encdec_cache_specs(cfg, b, s, s),
        )

    return ModelBundle(
        cfg=cfg,
        init=partial(_tf.init_lm, cfg),
        loss_fn=partial(_tf.lm_loss, cfg, mesh=mesh, impl=impl,
                        dp_axes=dp_axes, remat=remat,
                        scan_unroll=scan_unroll),
        prefill_fn=partial(_tf.lm_prefill, cfg, mesh=mesh, impl=prefill_impl,
                           prefill_chunk=prefill_chunk, dp_axes=dp_axes,
                           scan_unroll=scan_unroll,
                           cache_margin=cache_margin),
        decode_fn=partial(_tf.lm_decode, cfg, mesh=mesh,
                          mla_absorb=mla_absorb, dp_axes=dp_axes,
                          scan_unroll=scan_unroll),
        cache_specs=partial(_tf.lm_cache_specs, cfg),
    )


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs) per (arch x shape) cell
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract inputs for the step function selected by ``shape.kind``.

    train   -> {"batch": {...}}
    prefill -> {"batch": {...}}
    decode  -> {"token", "pos", "caches"}
    """
    B, S = shape.global_batch, shape.seq_len
    tok = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    emb = lambda *s: jax.ShapeDtypeStruct(s, cdtype(cfg))

    if shape.kind == "train":
        if cfg.family == "encdec":
            T = int(S * cfg.tgt_ratio)
            batch = {"src_emb": emb(B, S, cfg.d_model),
                     "tgt_tokens": tok(B, T), "tgt_targets": tok(B, T)}
        elif cfg.family == "vlm":
            Stext = S - cfg.num_image_tokens
            batch = {"tokens": tok(B, Stext), "targets": tok(B, Stext),
                     "img_emb": emb(B, cfg.num_image_tokens, cfg.d_model)}
        else:
            batch = {"tokens": tok(B, S), "targets": tok(B, S)}
        return {"batch": batch}

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            T = int(S * cfg.tgt_ratio)
            batch = {"src_emb": emb(B, S, cfg.d_model), "tgt_tokens": tok(B, T)}
        elif cfg.family == "vlm":
            batch = {"tokens": tok(B, S - cfg.num_image_tokens),
                     "img_emb": emb(B, cfg.num_image_tokens, cfg.d_model)}
        else:
            batch = {"tokens": tok(B, S)}
        return {"batch": batch}

    # decode: one new token against caches of capacity seq_len
    bundle_specs = (_encdec.encdec_cache_specs(cfg, B, S, S)
                    if cfg.family == "encdec"
                    else _tf.lm_cache_specs(cfg, B, S))
    return {
        "token": tok(B),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "caches": bundle_specs,
    }


# ---------------------------------------------------------------------------
# Analytic parameter counts (exact: derived from the abstract param pytree)
# ---------------------------------------------------------------------------

def analytic_param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    bundle = build_model(cfg, mesh=_trivial_mesh() if cfg.moe.enabled else None)
    shapes = jax.eval_shape(bundle.init, jax.random.key(0))
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    if active_only and cfg.moe.enabled:
        n_moe_layers = cfg.num_layers - cfg.moe.n_dense_layers
        inactive = (cfg.moe.num_experts - cfg.moe.top_k)
        per_expert = 3 * cfg.d_model * cfg.moe.d_ff
        total -= n_moe_layers * inactive * per_expert
    return total


def embedding_param_count(cfg: ModelConfig) -> int:
    n = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        n *= 2
    return n
