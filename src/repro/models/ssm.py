"""Mamba2 block: SSD (state-space duality) with the chunked algorithm.

The sequence is split into chunks of ``cfg.ssm.chunk_size``:
  * intra-chunk outputs use the quadratic "attention-like" form,
  * chunk boundary states are passed through a (cheap) sequential scan,
  * a single-token step function serves decode.

``ssd_chunked`` here is the pure-jnp oracle; ``repro.kernels.ssd_scan`` holds
the Pallas TPU kernel validated against it.

Tensor-parallel layout: the input projections are kept *separate* (w_z, w_x,
w_B, w_C, w_dt) instead of one fused in_proj so that the inner dimension
(d_inner, head-aligned) shards cleanly over the "model" axis while the shared
B/C state projections stay replicated — a fused projection would shard across
segment boundaries and force a reshard at the split.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import causal_conv1d, causal_conv1d_step, cdtype


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    return d_inner, nheads, s.d_state, s.head_dim


# ---------------------------------------------------------------------------
# Core SSD math (shared by ref path and decode)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int, init_state=None):
    """Chunked SSD scan.

    x: (B, S, H, P), dt: (B, S, H), A: (H,), Bm/Cm: (B, S, N) (1 group).
    Returns (y, final_state) with y: (B, S, H, P), state: (B, H, P, N).
    Sequences are zero-padded to a chunk multiple (dt=0 => decay 1,
    contribution 0: state passes through untouched).
    """
    Bsz, S0, H, Pd = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S0)
    pad = (-S0) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S = S0 + pad
    nc = S // chunk

    xc = x.reshape(Bsz, nc, chunk, H, Pd)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    dA = dtc * A.astype(jnp.float32)                      # (B,nc,cs,H) <= 0
    dA_cs = jnp.cumsum(dA, axis=2)

    # --- intra-chunk (quadratic within chunk) ------------------------------
    # L[b,c,h,i,j] = exp(dA_cs[i] - dA_cs[j]) for i >= j else 0
    diff = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]   # (B,nc,i,j,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))
    xdt = xc.astype(jnp.float32) * dtc[..., None]              # (B,nc,cs,H,P)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, L, xdt)

    # --- chunk states -------------------------------------------------------
    decay_last = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)          # (B,nc,cs,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc.astype(jnp.float32),
                        decay_last, xdt)                       # (B,nc,H,P,N)

    # --- inter-chunk recurrence (sequential over nc) ------------------------
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                  # (B,nc,H)
    st0 = (jnp.zeros((Bsz, H, Pd, N), jnp.float32) if init_state is None
           else init_state.astype(jnp.float32))

    def body(st, inp):
        s_c, dec_c = inp
        return st * dec_c[:, :, None, None] + s_c, st

    (st_final, prev_states) = jax.lax.scan(
        body, st0,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                   # (B,nc,H,P,N)

    # --- off-diagonal contribution -----------------------------------------
    y_off = jnp.einsum("bcin,bcih,bchpn->bcihp", Cc.astype(jnp.float32),
                       jnp.exp(dA_cs), prev_states)

    y = (y_diag + y_off).reshape(Bsz, S, H, Pd)[:, :S0]
    return y.astype(x.dtype), st_final


def ssd_step(x, dt, A, Bm, Cm, state):
    """Single-token SSD update.

    x: (B, H, P), dt: (B, H), Bm/Cm: (B, N), state: (B, H, P, N).
    """
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A.astype(jnp.float32))                  # (B,H)
    xdt = x.astype(jnp.float32) * dtf[..., None]               # (B,H,P)
    state = (state.astype(jnp.float32) * dA[..., None, None]
             + jnp.einsum("bhp,bn->bhpn", xdt, Bm.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def init_ssm_block(cfg: ModelConfig, key: jax.Array) -> dict:
    di, H, N, Pd = ssm_dims(cfg)
    dt_ = cdtype(cfg)
    D = cfg.d_model
    K = cfg.ssm.d_conv
    ks = jax.random.split(key, 9)
    s = D ** -0.5
    return {
        "w_z": (jax.random.normal(ks[0], (D, di)) * s).astype(dt_),
        "w_x": (jax.random.normal(ks[1], (D, di)) * s).astype(dt_),
        "w_B": (jax.random.normal(ks[2], (D, N)) * s).astype(dt_),
        "w_C": (jax.random.normal(ks[3], (D, N)) * s).astype(dt_),
        "w_dt": (jax.random.normal(ks[4], (D, H)) * s).astype(dt_),
        "conv_x": (jax.random.normal(ks[5], (K, di)) * 0.2).astype(dt_),
        "conv_B": (jax.random.normal(ks[6], (K, N)) * 0.2).astype(dt_),
        "conv_C": (jax.random.normal(ks[7], (K, N)) * 0.2).astype(dt_),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),                 # A = -exp(A_log) = -1
        "D_skip": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((di,), dt_),
        "out_proj": (jax.random.normal(ks[8], (di, D)) * di ** -0.5).astype(dt_),
    }


def _gated_norm(y, z, scale):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + 1e-6) * scale.astype(jnp.float32)).astype(y.dtype)


def _ssm_proj_conv(cfg, p, x, conv_states=None):
    """Projections + causal convs; returns (z, xs, Bm, Cm, dt, new_conv_states)."""
    z = x @ p["w_z"]
    xs = x @ p["w_x"]
    Bm = x @ p["w_B"]
    Cm = x @ p["w_C"]
    dt_raw = x @ p["w_dt"]
    if conv_states is None:
        xs, cx = causal_conv1d(xs, p["conv_x"])
        Bm, cb = causal_conv1d(Bm, p["conv_B"])
        Cm, cc = causal_conv1d(Cm, p["conv_C"])
    else:
        xs, cx = causal_conv1d_step(xs, p["conv_x"], conv_states["x"])
        Bm, cb = causal_conv1d_step(Bm, p["conv_B"], conv_states["B"])
        Cm, cc = causal_conv1d_step(Cm, p["conv_C"], conv_states["C"])
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    return z, xs, Bm, Cm, dt, {"x": cx, "B": cb, "C": cc}


def ssm_block_fwd(cfg: ModelConfig, p: dict, x: jax.Array, *, impl: str = "xla"):
    """Full-sequence Mamba2 block. x: (B, S, D) -> (B, S, D)."""
    di, H, N, Pd = ssm_dims(cfg)
    B, S, _ = x.shape
    z, xs, Bm, Cm, dt, _ = _ssm_proj_conv(cfg, p, x)
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, S, H, Pd)
    if impl == "pallas":
        from repro.kernels.ssd_scan import ops as ssd_ops

        y, _ = ssd_ops.ssd_scan(xh, dt, A, Bm, Cm, chunk=cfg.ssm.chunk_size)
    else:
        y, _ = ssd_chunked(xh, dt, A, Bm, Cm, chunk=cfg.ssm.chunk_size)
    y = y + xh * p["D_skip"][:, None].astype(y.dtype)
    y = y.reshape(B, S, di)
    y = _gated_norm(y, z, p["norm_scale"])
    return y @ p["out_proj"]


def ssm_block_prefill(cfg: ModelConfig, p: dict, x: jax.Array, *, impl="xla"):
    """Prefill: also returns decode cache {ssm_state, conv_*}."""
    di, H, N, Pd = ssm_dims(cfg)
    B, S, _ = x.shape
    z, xs, Bm, Cm, dt, conv_states = _ssm_proj_conv(cfg, p, x)
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, S, H, Pd)
    y, st = ssd_chunked(xh, dt, A, Bm, Cm, chunk=cfg.ssm.chunk_size)
    y = y + xh * p["D_skip"][:, None].astype(y.dtype)
    y = y.reshape(B, S, di)
    y = _gated_norm(y, z, p["norm_scale"])
    cache = {"ssm_state": st.astype(jnp.float32), "conv": conv_states}
    return y @ p["out_proj"], cache


def ssm_block_step(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict):
    """One-token decode. x: (B, 1, D)."""
    di, H, N, Pd = ssm_dims(cfg)
    B = x.shape[0]
    z, xs, Bm, Cm, dt, conv_states = _ssm_proj_conv(
        cfg, p, x[:, 0, :], conv_states=cache["conv"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, H, Pd)
    y, st = ssd_step(xh, dt, A, Bm, Cm, cache["ssm_state"])
    y = y + xh * p["D_skip"][:, None].astype(y.dtype)
    y = _gated_norm(y.reshape(B, di), z, p["norm_scale"])
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"ssm_state": st, "conv": conv_states}


def ssm_cache_spec(cfg: ModelConfig, batch: int) -> dict:
    di, H, N, Pd = ssm_dims(cfg)
    dt = cdtype(cfg)
    K = cfg.ssm.d_conv
    return {
        "ssm_state": jax.ShapeDtypeStruct((batch, H, Pd, N), jnp.float32),
        "conv": {
            "x": jax.ShapeDtypeStruct((batch, K - 1, di), dt),
            "B": jax.ShapeDtypeStruct((batch, K - 1, N), dt),
            "C": jax.ShapeDtypeStruct((batch, K - 1, N), dt),
        },
    }
