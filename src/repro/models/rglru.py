"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Recurrence (per channel):
    r_t = sigmoid(u_t W_a + b_a)             # recurrence gate
    i_t = sigmoid(u_t W_x + b_x)             # input gate
    a_t = exp(-c * softplus(Lambda) * r_t)   # c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training uses ``jax.lax.associative_scan`` (O(log S) depth); decode is a
single-step update.  The Pallas chunked-scan kernel lives in
``repro.kernels.rglru_scan`` and is validated against ``rglru_ref``.

Gate weights are *block-diagonal* (``_N_BLOCKS`` diagonal blocks), as in the
Griffin reference implementation — this also aligns them with tensor
parallelism: each "model"-axis shard owns whole blocks, so the recurrence
needs no cross-shard collective.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import causal_conv1d, causal_conv1d_step, cdtype

_C = 8.0
_N_BLOCKS = 16


def _block_matmul(u: jax.Array, w: jax.Array) -> jax.Array:
    """u: (..., W) x block-diagonal w: (nb, W/nb, W/nb) -> (..., W)."""
    nb, bs, _ = w.shape
    un = u.reshape(u.shape[:-1] + (nb, bs))
    out = jnp.einsum("...nk,nkj->...nj", un, w)
    return out.reshape(u.shape)


def _gates(p: dict, u: jax.Array):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_matmul(uf, p["w_a"].astype(jnp.float32)) + p["b_a"])
    i = jax.nn.sigmoid(_block_matmul(uf, p["w_x"].astype(jnp.float32)) + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lambda_p"]) * r          # (B, S, W) f32
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def rglru_ref(p: dict, u: jax.Array, h0: jax.Array | None = None):
    """Full-sequence RG-LRU. u: (B, S, W) -> (y, h_final)."""
    a, b = _gates(p, u)
    if h0 is not None:
        # Fold the initial state into the first step.
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(u.dtype), h[:, -1, :]


def rglru_step(p: dict, u: jax.Array, h: jax.Array):
    """One-token update. u: (B, W), h: (B, W) f32."""
    a, b = _gates(p, u[:, None, :])
    h_new = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
    return h_new.astype(u.dtype), h_new


# ---------------------------------------------------------------------------
# Griffin recurrent block: proj -> conv -> RG-LRU -> gated output
# ---------------------------------------------------------------------------

def init_rec_block(cfg: ModelConfig, key: jax.Array) -> dict:
    W = cfg.rec.lru_width
    D = cfg.d_model
    nb = min(_N_BLOCKS, W)
    bs = W // nb
    dt = cdtype(cfg)
    ks = jax.random.split(key, 6)
    s = D ** -0.5
    sb = bs ** -0.5
    return {
        "w_in": (jax.random.normal(ks[0], (D, W)) * s).astype(dt),
        "w_gate": (jax.random.normal(ks[1], (D, W)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (cfg.rec.conv_width, W)) * 0.2).astype(dt),
        "w_a": (jax.random.normal(ks[3], (nb, bs, bs)) * sb).astype(dt),
        "b_a": jnp.zeros((W,), jnp.float32),
        "w_x": (jax.random.normal(ks[4], (nb, bs, bs)) * sb).astype(dt),
        "b_x": jnp.zeros((W,), jnp.float32),
        # softplus(lambda_p) ~ 0.7 -> a ~ exp(-5.6 r); standard-ish init
        "lambda_p": jnp.full((W,), 0.5, jnp.float32),
        "w_out": (jax.random.normal(ks[5], (W, D)) * sb).astype(dt),
    }


def rec_block_fwd(cfg: ModelConfig, p: dict, x: jax.Array, *, impl: str = "xla"):
    """x: (B, S, D) -> (B, S, D)."""
    u = x @ p["w_in"]
    u, _ = causal_conv1d(u, p["conv_w"])
    if impl == "pallas":
        from repro.kernels.rglru_scan import ops as rg_ops

        a, b = _gates(p, u)
        h, _ = rg_ops.rglru_scan(a, b)
        h = h.astype(u.dtype)
    else:
        h, _ = rglru_ref(p, u)
    gate = jax.nn.gelu(x @ p["w_gate"])
    return (h * gate) @ p["w_out"]


def rec_block_prefill(cfg: ModelConfig, p: dict, x: jax.Array):
    u = x @ p["w_in"]
    u, conv_state = causal_conv1d(u, p["conv_w"])
    h, h_last = rglru_ref(p, u)
    gate = jax.nn.gelu(x @ p["w_gate"])
    out = (h * gate) @ p["w_out"]
    return out, {"h": h_last.astype(jnp.float32), "conv_state": conv_state}


def rec_block_step(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict):
    """x: (B, 1, D)."""
    u = x[:, 0, :] @ p["w_in"]
    u, conv_state = causal_conv1d_step(u, p["conv_w"], cache["conv_state"])
    h, h_new = rglru_step(p, u, cache["h"])
    gate = jax.nn.gelu(x[:, 0, :] @ p["w_gate"])
    out = ((h * gate) @ p["w_out"])[:, None, :]
    return out, {"h": h_new, "conv_state": conv_state}


def rec_cache_spec(cfg: ModelConfig, batch: int) -> dict:
    W = cfg.rec.lru_width
    return {
        "h": jax.ShapeDtypeStruct((batch, W), jnp.float32),
        "conv_state": jax.ShapeDtypeStruct(
            (batch, cfg.rec.conv_width - 1, W), cdtype(cfg)),
    }
