"""Common layers: norms, rotary embeddings, MLPs, token embeddings.

All modules are functional: ``init_*`` builds a params pytree (nested dicts of
jnp arrays), ``*_fwd`` applies it.  Norms and softmax run in float32; matmuls
run in the config compute dtype (bfloat16 for the full-size configs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: int) -> dict:
    if cfg.norm_kind == "layernorm":
        return {"scale": jnp.ones((d,), cdtype(cfg)), "bias": jnp.zeros((d,), cdtype(cfg))}
    return {"scale": jnp.ones((d,), cdtype(cfg))}


def norm_fwd(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_kind == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + 1e-5)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def rmsnorm_1d(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over the last axis with an explicit scale (qk-norm etc.)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_fwd(x: jax.Array, positions: jax.Array, theta: float,
             rope_pct: float = 1.0) -> jax.Array:
    """Apply RoPE.

    x: (..., S, H, hd), positions: broadcastable to (..., S).
    ``rope_pct`` < 1 rotates only the leading fraction of head dims
    (stablelm-style partial rotary).
    """
    hd = x.shape[-1]
    rot = int(hd * rope_pct)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    freqs = theta ** (-jnp.arange(0, rot, 2, dtype=jnp.float32) / rot)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    angles = angles[..., None, :]                              # (..., S, 1, rot/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key: jax.Array, d_in: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d_in ** -0.5
    scale_ff = d_ff ** -0.5
    dt = cdtype(cfg)
    p = {
        "w_up": (jax.random.normal(k1, (d_in, d_ff)) * scale_in).astype(dt),
        "w_down": (jax.random.normal(k2, (d_ff, d_in)) * scale_ff).astype(dt),
    }
    if cfg.mlp_kind in ("silu_glu", "geglu"):
        p["w_gate"] = (jax.random.normal(k3, (d_in, d_ff)) * scale_in).astype(dt)
    return p


def mlp_fwd(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    up = x @ p["w_up"]
    if cfg.mlp_kind == "silu_glu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    elif cfg.mlp_kind == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * up
    else:  # plain gelu
        h = jax.nn.gelu(up)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------

def init_embed(cfg: ModelConfig, key: jax.Array) -> dict:
    dt = cdtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {"embedding": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(k2, (cfg.d_model, cfg.vocab_size))
                        * cfg.d_model ** -0.5).astype(dt)
    return p


def embed_fwd(cfg: ModelConfig, p: dict, tokens: jax.Array) -> jax.Array:
    return p["embedding"][tokens]


def lm_head_fwd(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ p["embedding"].T
    return x @ p["lm_head"]


def shard_logits(logits: jax.Array, mesh, dp_axes) -> jax.Array:
    """Keep (B, S, V) logits vocab-sharded over the model axis.

    Without this constraint GSPMD tends to all-gather the full-vocab logits
    before the loss (a multi-GB f32 temp at 150k vocab); with it, the loss
    below reduces shard-locally + small all-reduces.
    """
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return logits
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    V = logits.shape[-1]
    bdim = (tuple(dp_axes)
            if dp_axes and logits.shape[0] % _axsize(mesh, dp_axes) == 0
            else None)
    tp = ("model" if V % mesh.shape["model"] == 0
          and "model" not in (bdim or ()) else None)
    spec = P(bdim, *([None] * (logits.ndim - 2)), tp)
    return jax.lax.with_sharding_constraint(
        logits, NamedSharding(mesh, spec))


def _axsize(mesh, axes) -> int:
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def shard_batch_dim(x: jax.Array, mesh, dp_axes) -> jax.Array:
    """Constrain dim0 (batch) over the data axes — anchors propagation so
    activations never silently replicate across data shards."""
    if mesh is None or not dp_axes:
        return x
    if x.shape[0] % _axsize(mesh, dp_axes) != 0:
        return x
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    spec = P(dp_axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean token cross-entropy, f32 accumulation, sharding-friendly.

    Formulated as elementwise ops + reductions over the vocab axis only —
    every op preserves a vocab-sharded layout (the gold-logit gather is a
    masked sum, not take_along_axis, so GSPMD never materializes full-vocab
    f32 logits per device).
    """
    V = logits.shape[-1]
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - m).astype(jnp.float32)
    sumexp = jnp.sum(jnp.exp(shifted), axis=-1)
    lse = jnp.log(sumexp)
    vocab = jnp.arange(V, dtype=targets.dtype)
    gold = jnp.sum(jnp.where(targets[..., None] == vocab, shifted, 0.0),
                   axis=-1)
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Causal conv (SSM / RG-LRU input convolutions)
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal 1-D convolution.

    x: (B, S, C), w: (K, C).  Returns (y, new_state) where state carries the
    last K-1 inputs for single-step decoding.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return y, new_state


def causal_conv1d_step(x: jax.Array, w: jax.Array, state: jax.Array):
    """One-token update. x: (B, C), state: (B, K-1, C) -> (y, new_state)."""
    k = w.shape[0]
    xp = jnp.concatenate([state.astype(x.dtype), x[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", xp, w.astype(x.dtype))
    return y, xp[:, 1:, :]
