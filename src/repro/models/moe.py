"""Mixture-of-experts layer with expert parallelism over the "model" axis.

Dispatch strategy (DeepSeek-style fine-grained MoE, 64–256 experts):

GShard's dense one-hot dispatch tensor (tokens × experts × capacity) is
infeasible at this scale (it would be ~10^13 bytes for deepseek-v3 at
train_4k), so we use a *sort-based capacity dispatch* inside ``shard_map``:

1. router top-k per token (gates renormalized over the selected experts);
2. flatten (token, k) pairs, ``argsort`` by expert id;
3. position-within-expert via ``searchsorted``; pairs beyond the static
   per-expert capacity ``C = ceil(T*k/E * capacity_factor)`` are dropped
   (classic capacity-based routing);
4. every model-axis shard owns ``E/ep`` experts: it scatters *slot → token
   index* (cheap int ops), gathers only its local ``(E_local*C, D)`` activation
   block, runs the per-expert MLPs as one batched einsum, and scatter-adds the
   gated outputs back to token positions;
5. ``psum`` over the model axis combines contributions — the same all-reduce a
   tensor-parallel FFN would need, so EP costs no extra collective phase.

Activations enter replicated over "model" (standard TP layout), so no
all-to-all is required.  The router and its aux load-balancing loss are
computed identically on every shard.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import compat_shard_map
from repro.models.layers import cdtype, init_mlp, mlp_fwd


def init_moe(cfg: ModelConfig, key: jax.Array) -> dict:
    m = cfg.moe
    dt = cdtype(cfg)
    D, E, F = cfg.d_model, m.num_experts, m.d_ff
    ks = jax.random.split(key, 5)
    s_in, s_ff = D ** -0.5, F ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (D, E)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, D, F)) * s_in).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, D, F)) * s_in).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, F, D)) * s_ff).astype(dt),
    }
    if m.num_shared_experts:
        import dataclasses

        shared_cfg = dataclasses.replace(cfg, mlp_kind="silu_glu")
        p["shared"] = init_mlp(shared_cfg, ks[4], D, F * m.num_shared_experts)
    return p


def _capacity(tokens: int, k: int, num_experts: int, cf: float) -> int:
    return max(8, int(math.ceil(tokens * k / num_experts * cf)))


def _expert_shard(x2d, router_w, wg, wu, wd, *, top_k: int, num_experts: int,
                  capacity: int, ep_axis: str, dp_axes: tuple[str, ...]):
    """Body run per model-axis shard. x2d: (T, D) replicated over ep_axis."""
    T, D = x2d.shape
    E_local = wg.shape[0]
    r = jax.lax.axis_index(ep_axis)
    e0 = r * E_local

    logits = (x2d.astype(jnp.float32) @ router_w)              # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)                  # (T, k)
    gates = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                                  # (T*k,)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e)                                 # stable
    sorted_e = flat_e[order]
    sorted_tok = order // top_k
    sorted_g = flat_g[order]
    pos = jnp.arange(T * top_k) - jnp.searchsorted(sorted_e, sorted_e, side="left")

    local = (sorted_e >= e0) & (sorted_e < e0 + E_local) & (pos < capacity)
    slot = jnp.where(local, (sorted_e - e0) * capacity + pos, E_local * capacity)

    # slot -> token routing tables (int scatters; tiny).
    tok_for_slot = jnp.full((E_local * capacity + 1,), T, jnp.int32)
    tok_for_slot = tok_for_slot.at[slot].set(sorted_tok.astype(jnp.int32), mode="drop")
    gate_for_slot = jnp.zeros((E_local * capacity + 1,), jnp.float32)
    gate_for_slot = gate_for_slot.at[slot].set(sorted_g, mode="drop")
    tok_for_slot = tok_for_slot[:-1]
    gate_for_slot = gate_for_slot[:-1]

    # Gather local expert inputs: (E_local * C, D); OOB sentinel row = 0.
    x_pad = jnp.concatenate([x2d, jnp.zeros((1, D), x2d.dtype)], axis=0)
    xs = x_pad[tok_for_slot].reshape(E_local, capacity, D)

    h = jnp.einsum("ecd,edf->ecf", xs, wg)
    u = jnp.einsum("ecd,edf->ecf", xs, wu)
    ys = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, wd)     # (E_local,C,D)

    contrib = ys.reshape(E_local * capacity, D) * gate_for_slot[:, None].astype(ys.dtype)
    out = jnp.zeros((T, D), ys.dtype).at[tok_for_slot].add(contrib, mode="drop")
    out = jax.lax.psum(out, ep_axis)

    # Aux load-balancing loss (replicated — identical on all shards).
    f = jnp.zeros((num_experts,), jnp.float32).at[flat_e].add(1.0) / (T * top_k)
    p_mean = jnp.mean(probs, axis=0)
    aux = num_experts * jnp.sum(f * p_mean)
    aux = jax.lax.pmean(aux, dp_axes)   # replicate across data shards too
    return out, aux


def _expert_shard_a2a(x2d, router_w, wg, wu, wd, *, top_k: int,
                      num_experts: int, capacity: int,
                      ep_axes: tuple[str, ...]):
    """2D expert parallelism: tokens travel, weights stay resident.

    Runs with tokens sharded over *all* of ``ep_axes`` and ``E/n_ep`` experts
    resident per device.  Dispatch: sort-by-expert into an (E, C, D) buffer,
    ``all_to_all`` it so each device receives every source's slice for its
    own experts, run the local expert MLPs, reverse the all_to_all, combine.
    Unlike the weight-gathered path, expert *gradients* are complete on the
    owning device — no cross-shard gradient reduction for expert weights.
    """
    T, D = x2d.shape
    E_local = wg.shape[0]
    n_ep = num_experts // E_local
    r = jax.lax.axis_index(ep_axes)

    logits = (x2d.astype(jnp.float32) @ router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)
    gates = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    sorted_tok = order // top_k
    sorted_g = flat_g[order]
    pos = jnp.arange(T * top_k) - jnp.searchsorted(sorted_e, sorted_e,
                                                   side="left")
    keep = pos < capacity
    slot = jnp.where(keep, sorted_e * capacity + pos,
                     num_experts * capacity)

    tok_for_slot = jnp.full((num_experts * capacity + 1,), T, jnp.int32)
    tok_for_slot = tok_for_slot.at[slot].set(sorted_tok.astype(jnp.int32))
    gate_for_slot = jnp.zeros((num_experts * capacity + 1,), jnp.float32)
    gate_for_slot = gate_for_slot.at[slot].set(sorted_g)
    tok_for_slot = tok_for_slot[:-1]
    gate_for_slot = gate_for_slot[:-1]

    x_pad = jnp.concatenate([x2d, jnp.zeros((1, D), x2d.dtype)], axis=0)
    send = x_pad[tok_for_slot]                        # (E*C, D)
    send = send.reshape(num_experts, capacity, D)

    # tokens -> expert owners: each device receives (n_ep src, E_local, C, D)
    recv = jax.lax.all_to_all(
        send.reshape(n_ep, E_local, capacity, D), ep_axes, 0, 0, tiled=False)
    xs = recv.transpose(1, 0, 2, 3).reshape(E_local, n_ep * capacity, D)

    h = jnp.einsum("ecd,edf->ecf", xs, wg)
    u = jnp.einsum("ecd,edf->ecf", xs, wu)
    ys = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, wd)

    back = ys.reshape(E_local, n_ep, capacity, D).transpose(1, 0, 2, 3)
    ret = jax.lax.all_to_all(back, ep_axes, 0, 0, tiled=False)
    ret = ret.reshape(num_experts * capacity, D)

    contrib = ret * gate_for_slot[:, None].astype(ys.dtype)
    out = jnp.zeros((T, D), ys.dtype).at[tok_for_slot].add(
        contrib, mode="drop")

    f = jnp.zeros((num_experts,), jnp.float32).at[flat_e].add(1.0) / (T * top_k)
    p_mean = jnp.mean(probs, axis=0)
    aux = num_experts * jnp.sum(f * p_mean)
    aux = jax.lax.pmean(aux, ep_axes)
    return out, aux


def a2a_axes_for(cfg: ModelConfig, mesh) -> tuple[str, ...]:
    """Largest ep-axis set the expert count supports."""
    E = cfg.moe.num_experts
    for axes in (("data", "model"), ("model",)):
        if all(a in mesh.axis_names for a in axes):
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if E % n == 0:
                return axes
    return ()


def moe_fwd(cfg: ModelConfig, p: dict, x: jax.Array, *, mesh,
            dp_axes: tuple[str, ...] = ("data",), ep_axis: str = "model",
            dispatch: str = "local"):
    """x: (B, S, D) -> (out, aux_loss).

    dispatch="local": EP over the model axis, activations replicated there
    (no all-to-all; expert weights ZeRO-gathered if fsdp policy).
    dispatch="a2a":   2D EP — experts resident, tokens all-to-all'd.
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    x2d = x.reshape(T, D)

    ep_axes = a2a_axes_for(cfg, mesh) if dispatch == "a2a" else ()
    if dispatch == "a2a" and ep_axes:
        n_ep = 1
        for a in ep_axes:
            n_ep *= mesh.shape[a]
        tok_axes = tuple(dict.fromkeys(
            [a for a in dp_axes if a != "model"] + list(ep_axes)))
        n_tok = 1
        for a in tok_axes:
            n_tok *= mesh.shape[a]
        if T % n_tok == 0:
            local_T = T // n_tok
            capacity = _capacity(local_T, m.top_k, m.num_experts,
                                 m.capacity_factor)
            body = partial(_expert_shard_a2a, top_k=m.top_k,
                           num_experts=m.num_experts, capacity=capacity,
                           ep_axes=ep_axes)
            out2d, aux = compat_shard_map(
                body,
                mesh=mesh,
                in_specs=(P(tok_axes, None), P(None, None),
                          P(ep_axes, None, None), P(ep_axes, None, None),
                          P(ep_axes, None, None)),
                out_specs=(P(tok_axes, None), P()),
                check_vma=False,
            )(x2d, p["router"], p["w_gate"], p["w_up"], p["w_down"])
            out = out2d.reshape(B, S, D)
            if m.num_shared_experts:
                import dataclasses

                shared_cfg = dataclasses.replace(cfg, mlp_kind="silu_glu")
                out = out + mlp_fwd(shared_cfg, p["shared"], x)
            return out, aux
        # fall through to local dispatch when tokens don't divide

    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    local_T = T // dp
    capacity = _capacity(local_T, m.top_k, m.num_experts, m.capacity_factor)

    body = partial(
        _expert_shard, top_k=m.top_k, num_experts=m.num_experts,
        capacity=capacity, ep_axis=ep_axis, dp_axes=dp_axes)

    out2d, aux = compat_shard_map(
        body,
        mesh=mesh,
        in_specs=(P(dp_axes, None), P(None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None), P(ep_axis, None, None)),
        out_specs=(P(dp_axes, None), P()),
        check_vma=False,
    )(x2d, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    out = out2d.reshape(B, S, D)

    if m.num_shared_experts:
        import dataclasses

        shared_cfg = dataclasses.replace(cfg, mlp_kind="silu_glu")
        out = out + mlp_fwd(shared_cfg, p["shared"], x)
    return out, aux
