"""Quickstart: train a small LM end-to-end under the Funky runtime.

Everything the task does — buffer allocation, data transfers, train-step
launches, synchronization — flows through the FunkyCL API into the per-task
monitor, so the job is preemptible/checkpointable from step one.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
import time

sys.path.insert(0, "src")

from repro.core import TaskImage, TaskStatus, make_cluster  # noqa: E402
from repro.train import OptConfig  # noqa: E402


def main():
    image = TaskImage(
        name="quickstart", kind="train", arch="yi-9b-smoke",
        seq_len=64, global_batch=8, total_steps=100, chunks=4,
        opt=OptConfig(peak_lr=3e-3, warmup_steps=10, decay_steps=100),
    )
    cluster = make_cluster(num_nodes=1, slices_per_node=1,
                           images={"quickstart": image})
    runtime = cluster.nodes["node0"].runtime

    print("deploying training task (unikernel boot + program compile)...")
    runtime.create("demo", image)
    runtime.start("demo")
    t0 = time.perf_counter()
    rec = runtime.tasks["demo"]
    while rec.status not in (TaskStatus.DONE, TaskStatus.FAILED):
        time.sleep(1.0)
        print(f"  step {rec.guest_state.step}/{image.total_steps} "
              f"(EXECUTEs: {int(rec.monitor.metrics['n_EXECUTE'])})")
    assert rec.status is TaskStatus.DONE, rec.error
    print(f"finished {image.total_steps} steps in "
          f"{time.perf_counter() - t0:.1f}s; "
          f"final loss {rec.guest_state.user['final_loss']:.4f}")
    print(f"monitor stats: reconfig={rec.monitor.metrics['reconfig_seconds']:.2f}s "
          f"transfers={int(rec.monitor.metrics['n_TRANSFER'])}")


if __name__ == "__main__":
    main()
