"""Elastic serving, end to end (paper §3.5 workload scaling, grown up).

A live serving task is driven by a bursty open-loop trace.  The load
driver publishes the canonical service signals (queue depth, utilization,
request latency) into the cluster's telemetry registry; the orchestrator's
autoscaler reconcile thread reads them, and scales the service out
(checkpoint-clone replicate onto a node with free vSlices) and back in
(kill + delete) through node agents -> CRI.  The same policy object drives
the trace simulator in benchmarks/fig14_autoscale.py.

    PYTHONPATH=src python examples/elastic_serving.py
"""

import os
import sys

sys.path.insert(0, "src")

from repro.core import TaskImage, make_cluster              # noqa: E402
from repro.scaling import (Autoscaler, LatencySLOPolicy,    # noqa: E402
                           OrchestratorScaler, burst_rate, drive_open_loop,
                           open_loop, teardown_service, wait_for_service)

IMAGE = TaskImage(name="svc", kind="serve", arch="yi-9b-smoke",
                  prompt_len=16, global_batch=2, total_steps=100000,
                  tokens_per_step=2)

SLO_S = 1.0
SERVICE_RATE = 40.0      # requests/s one replica can terminate
DURATION_S = 9.0


def main():
    cluster = make_cluster(num_nodes=4, slices_per_node=1,
                           images={"svc": IMAGE})
    orch = cluster.orchestrator

    cid = orch.submit("svc", priority=5)
    orch.start(tick_interval=0.02)
    print("waiting for the service task to boot (program compilation)...")
    node = wait_for_service(cluster, orch, cid)
    print(f"  {cid} serving on {node}")

    scaler = OrchestratorScaler(orch, cid, service="svc")
    autoscaler = Autoscaler(LatencySLOPolicy(slo_p95_s=0.6, growth=2.0),
                            min_replicas=1, max_replicas=4,
                            scale_down_cooldown_s=2.0)
    orch.attach_autoscaler(autoscaler, scaler, service="svc",
                           interval_s=0.2)
    print("autoscaler attached: latency-SLO policy, 1..4 replicas")

    # bursty open-loop traffic; the middle third runs at 6x the base rate
    reqs = open_loop(
        burst_rate(0.6 * SERVICE_RATE, 6.0, DURATION_S / 3, DURATION_S / 3),
        DURATION_S, seed=7, mean_service_s=1.0 / SERVICE_RATE)
    print(f"replaying {len(reqs)} requests over {DURATION_S:.0f}s "
          f"(burst in the middle third)...")

    def report(now, replicas, queue_len, p95):
        print(f"  t={now:4.1f}s replicas={replicas} queue={queue_len:4d} "
              f"p95={p95 if p95 == p95 else 0:.2f}s")

    res = drive_open_loop(orch, scaler, reqs, duration_s=DURATION_S,
                          service_rate=SERVICE_RATE, slo_s=SLO_S,
                          service="svc", on_tick=report)

    print("burst over; stopping the reconcile loop and draining to 1...")
    teardown_service(orch, scaler)
    print(f"served {res.served} requests, "
          f"SLO attainment {res.attainment:.3f}")
    print("scaling events:",
          [e[1] for e in orch.events if e[1] in ("replicate", "scale_in",
                                                 "autoscale")])
    snap = cluster.metrics.snapshot()
    print("telemetry counters:", {k: int(v)
                                  for k, v in snap["counters"].items()
                                  if "{service=svc}" in k})
    for d in autoscaler.decisions[-5:]:
        print(f"  decision {d.current}->{d.desired} ({d.reason})")
    cluster.stop()
    sys.stdout.flush()
    # XLA worker threads of killed guest tasks can abort CPython teardown
    # ("terminate called without an active exception"); everything is
    # reported by now, so skip destructor-time teardown entirely.
    os._exit(0)


if __name__ == "__main__":
    main()
