"""Elastic serving, end to end (paper §3.5 workload scaling, grown up).

A live serving *service* is driven by a bursty open-loop trace on the
per-request path: arrivals land on the service's ``RequestRouter``; every
replica is an ``EngineServeTask`` — a continuous-batching engine that pulls
admissible requests into its decode slots and dispatches each iteration as
an EXECUTE request through its monitor, so request termination (and every
TTFT/TBT/latency sample) is measured on-device.  The orchestrator's
autoscaler reconcile thread reads the canonical service signals from the
cluster's telemetry registry and scales the service out (checkpoint-clone
replicate onto a node with free vSlices) and back in (kill + delete, with
in-flight sequences requeued) through node agents -> CRI.  The same policy
object drives the trace simulator in benchmarks/fig14_autoscale.py.

    PYTHONPATH=src python examples/elastic_serving.py
"""

import os
import sys

sys.path.insert(0, "src")

from repro.core import TaskImage, make_cluster              # noqa: E402
from repro.scaling import (Autoscaler, LatencySLOPolicy,    # noqa: E402
                           OrchestratorScaler, burst_rate,
                           drive_engine_open_loop, open_loop, reset_router,
                           teardown_service, wait_for_service)

SLOTS = 2
TOKENS_RANGE = (3, 9)
IMAGE = TaskImage(name="svc", kind="engine-serve", arch="yi-9b-smoke",
                  prompt_len=8, global_batch=SLOTS, total_steps=10 ** 9,
                  max_new_tokens=TOKENS_RANGE[1])

SLO_S = 1.0
# a 2-slot smoke replica terminates roughly 300 req/s of (3,9)-token
# requests; the 6x burst pushes the offered rate past that so the
# latency-SLO policy has something to do
REQUEST_RATE = 75.0      # base req/s knob (burst = 3.6x this)
DURATION_S = 9.0


def main():
    cluster = make_cluster(num_nodes=4, slices_per_node=1,
                           images={"svc": IMAGE})
    orch = cluster.orchestrator
    router = reset_router("svc")
    router.registry = orch.metrics

    cid = orch.submit("svc", priority=5)
    orch.start(tick_interval=0.02)
    print("waiting for the service task to boot (program compilation)...")
    node = wait_for_service(cluster, orch, cid)
    print(f"  {cid} serving on {node} "
          f"({SLOTS} decode slots, continuous batching)")

    scaler = OrchestratorScaler(orch, cid, service="svc")
    autoscaler = Autoscaler(LatencySLOPolicy(slo_p95_s=0.6, growth=2.0),
                            min_replicas=1, max_replicas=4,
                            scale_down_cooldown_s=2.0)
    orch.attach_autoscaler(autoscaler, scaler, service="svc",
                           interval_s=0.2)
    print("autoscaler attached: latency-SLO policy, 1..4 replicas")

    # bursty open-loop traffic; the middle third runs at 6x the base rate
    reqs = open_loop(
        burst_rate(0.6 * REQUEST_RATE, 6.0, DURATION_S / 3, DURATION_S / 3),
        DURATION_S, seed=7, mean_service_s=1.0 / REQUEST_RATE,
        tokens_range=TOKENS_RANGE)
    print(f"replaying {len(reqs)} requests over {DURATION_S:.0f}s "
          f"(burst in the middle third)...")

    def report(now, replicas, queue_len, p95):
        print(f"  t={now:4.1f}s replicas={replicas} queue={queue_len:4d} "
              f"p95={p95 if p95 == p95 else 0:.2f}s")

    res = drive_engine_open_loop(
        orch, scaler, reqs, duration_s=DURATION_S, slo_s=SLO_S,
        service="svc", prompt_len=IMAGE.prompt_len,
        slots_per_replica=SLOTS, drain_timeout_s=20.0, on_tick=report)

    print("burst over; stopping the reconcile loop and draining to 1...")
    teardown_service(orch, scaler)
    print(f"served {res.served} requests on-device, "
          f"SLO attainment {res.attainment:.3f}")
    print("scaling events:",
          [e[1] for e in orch.events if e[1] in ("replicate", "scale_in",
                                                 "autoscale")])
    snap = cluster.metrics.snapshot()
    print("telemetry counters:", {k: int(v)
                                  for k, v in snap["counters"].items()
                                  if "{service=svc}" in k})
    for name in ("request_ttft_seconds", "request_tbt_seconds",
                 "request_latency_seconds"):
        h = snap["histograms"].get(f"{name}{{service=svc}}")
        if h and h["window_count"]:
            print(f"  {name}: n={h['count']} p50={h['p50'] * 1e3:.1f}ms "
                  f"p99={h['p99'] * 1e3:.1f}ms")
        elif h:
            print(f"  {name}: n={h['count']} (window drained)")
    for d in autoscaler.decisions[-5:]:
        print(f"  decision {d.current}->{d.desired} ({d.reason})")
    print("flight recorder tail:",
          [e[1] for e in cluster.metrics.flight_record()["events"][-8:]])
    cluster.stop()
    sys.stdout.flush()
    # XLA worker threads of killed guest tasks can abort CPython teardown
    # ("terminate called without an active exception"); everything is
    # reported by now, so skip destructor-time teardown entirely.
    os._exit(0)


if __name__ == "__main__":
    main()
