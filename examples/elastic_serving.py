"""Workload-scaling demo (paper §3.5): a serving task is scaled horizontally
(replicated to a second node from a live snapshot) and vertically
(vfpga_num update), while continuously decoding batched requests.

    PYTHONPATH=src python examples/elastic_serving.py
"""

import sys
import time

sys.path.insert(0, "src")

from repro.core import TaskImage, TaskStatus, make_cluster  # noqa: E402

IMAGE = TaskImage(name="svc", kind="serve", arch="qwen3-8b-smoke",
                  prompt_len=16, global_batch=4, total_steps=12,
                  tokens_per_step=4)


def main():
    cluster = make_cluster(num_nodes=2, slices_per_node=1,
                           images={"svc": IMAGE})
    orch = cluster.orchestrator
    orch.start(tick_interval=0.02)

    cid = orch.submit("svc", priority=5)
    time.sleep(3.0)

    print("horizontal scaling: replicating the live service to node1...")
    src_node = orch._sched_tasks[cid].node_id
    target = "node1" if src_node == "node0" else "node0"
    rep_cid = orch.scale_horizontal(cid, target)
    print(f"  replica {rep_cid} deployed on {target} "
          f"(cloned from a live snapshot — warmed caches included)")

    print("vertical scaling: raising the replica's vSlice allowance to 2...")
    orch.scale_vertical(rep_cid, 2)

    assert orch.wait_all(timeout=3600)
    for c in (cid, rep_cid):
        d = orch.deployments[c]
        print(f"{c}: {d.status}")
        for n, nd in cluster.nodes.items():
            rec = nd.runtime.tasks.get(c)
            if rec is not None and rec.status is TaskStatus.DONE:
                print(f"   on {n}: decoded through step {rec.guest_state.step}"
                      f", last tokens {rec.guest_state.user.get('last_token')}")
    orch.stop()
    cluster.stop()


if __name__ == "__main__":
    main()
