"""Preemptive scheduling demo (paper Fig 10): a high-priority task evicts a
low-priority one on a fully-occupied 2-node cluster; the evicted task is
later migrated to a freed slot and completes with its state intact.

    PYTHONPATH=src python examples/preemptive_cluster.py
"""

import sys
import time

sys.path.insert(0, "src")

from repro.core import Policy, TaskImage, make_cluster  # noqa: E402

IMAGES = {
    "batch-job": TaskImage(name="batch-job", kind="train", arch="yi-9b-smoke",
                           seq_len=32, global_batch=4, total_steps=20,
                           chunks=2),
    "prod-job": TaskImage(name="prod-job", kind="train", arch="qwen3-8b-smoke",
                          seq_len=32, global_batch=4, total_steps=4,
                          chunks=2),
}


def main():
    cluster = make_cluster(num_nodes=2, slices_per_node=1, images=IMAGES,
                           policy=Policy.PRE_MG)
    orch = cluster.orchestrator
    orch.start(tick_interval=0.02)

    print("submitting 2 low-priority batch jobs (fill the cluster)...")
    low = [orch.submit("batch-job", priority=0) for _ in range(2)]
    time.sleep(2.0)
    print("submitting a high-priority prod job -> should evict a batch job")
    high = orch.submit("prod-job", priority=10)

    assert orch.wait_all(timeout=3600)
    print("\nevent log (orchestrator):")
    for ts, ev, kw in orch.events:
        if ev in ("evict", "resume", "migrate", "deploy", "done"):
            print(f"  {ev:8s} {kw}")
    for cid in low + [high]:
        d = orch.deployments[cid]
        print(f"{cid}: {d.status}, latency {d.end_time - d.submit_time:.1f}s")
    evicted = [1 for _, e, _ in orch.events if e == "evict"]
    print(f"\npreemptions: {len(evicted)} "
          f"(the batch job resumed with its training state intact)")
    orch.stop()
    cluster.stop()


if __name__ == "__main__":
    main()
