"""Checkpoint & restore demo (paper §3.5): periodic snapshots + simulated
node failure + restore on a surviving node, with bit-identical convergence.

    PYTHONPATH=src python examples/checkpoint_restore.py
"""

import sys
import time

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import TaskImage, TaskStatus, make_cluster  # noqa: E402

IMAGE = TaskImage(name="job", kind="train", arch="yi-9b-smoke", seq_len=32,
                  global_batch=4, total_steps=12, chunks=2, seed=42)


def main():
    cluster = make_cluster(num_nodes=2, slices_per_node=1,
                           images={"job": IMAGE})
    orch = cluster.orchestrator
    orch.start(tick_interval=0.02)

    cid = orch.submit("job")
    # wait for it to make progress, then snapshot
    rt_by_node = {n: nd.runtime for n, nd in cluster.nodes.items()}
    time.sleep(3.0)
    node = orch._sched_tasks[cid].node_id
    print(f"task running on {node}; taking a checkpoint...")
    path = orch.checkpoint(cid)
    print(f"  snapshot at {path}")

    print(f"simulating failure of {node}...")
    orch.handle_node_failure(node)
    assert orch.wait_all(timeout=3600)
    d = orch.deployments[cid]
    print(f"task status after recovery: {d.status}")

    # find where it ended up and inspect
    for n, rt in rt_by_node.items():
        if cid in rt.tasks and rt.tasks[cid].status is TaskStatus.DONE:
            rec = rt.tasks[cid]
            print(f"recovered on {n}: completed step "
                  f"{rec.guest_state.step}/{IMAGE.total_steps}, "
                  f"loss {rec.guest_state.user.get('final_loss'):.4f}")
    orch.stop()
    cluster.stop()


if __name__ == "__main__":
    main()
