"""Checkpoint substrate: exact roundtrips (incl. bfloat16), incremental
reuse, async saves, resharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (AsyncCheckpointer, CheckpointCorruptError,
                        load_latest_good, load_snapshot, reshard_params,
                        save_snapshot, snapshot_candidates)
from repro.core.state import GuestState, TaskSnapshot


def _snap(step=0, versions=None, val=1.0):
    buffers = {
        "params": {"w": np.full((4, 4), val, np.float32),
                   "b": jnp.ones((3,), jnp.bfloat16) * val},
        "opt_state": {"m": (np.zeros(2, np.int64),)},
    }
    return TaskSnapshot(task_id="t", guest_state=GuestState(step=step),
                        buffers=buffers, step=step,
                        versions=versions or {"params": 1, "opt_state": 1})


def test_roundtrip_exact(tmp_path):
    p = str(tmp_path / "ck")
    save_snapshot(p, _snap(step=5))
    snap, image = load_snapshot(p)
    assert snap.step == 5
    assert snap.guest_state.step == 5
    np.testing.assert_array_equal(snap.buffers["params"]["w"],
                                  np.full((4, 4), 1.0))
    b = snap.buffers["params"]["b"]
    assert b.dtype == jnp.bfloat16                # dtype survives npz
    np.testing.assert_array_equal(np.asarray(b, np.float32), np.ones(3))
    assert isinstance(snap.buffers["opt_state"]["m"], tuple)  # structure


def test_incremental_reuses_unchanged_buffers(tmp_path):
    p1 = str(tmp_path / "c1")
    p2 = str(tmp_path / "c2")
    s1 = _snap(step=1, versions={"params": 3, "opt_state": 3})
    stats1 = save_snapshot(p1, s1)
    assert stats1["reused_buffers"] == 0
    # params changed (version bump), opt_state unchanged
    s2 = _snap(step=2, versions={"params": 4, "opt_state": 3}, val=2.0)
    stats2 = save_snapshot(p2, s2, prev_path=p1)
    assert stats2["reused_buffers"] == 1
    assert stats2["written_bytes"] < stats1["written_bytes"]
    snap, _ = load_snapshot(p2)
    np.testing.assert_array_equal(snap.buffers["params"]["w"],
                                  np.full((4, 4), 2.0))


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer()
    p = str(tmp_path / "a1")
    ck.save(p, _snap(step=9))
    stats = ck.wait()
    assert stats["written_bytes"] > 0
    snap, _ = load_snapshot(p)
    assert snap.step == 9


def test_reshard_params_roundtrip():
    from repro.configs import get_arch
    from repro.models import build_model

    cfg = get_arch("yi-9b-smoke")
    b = build_model(cfg)
    params = b.init(jax.random.PRNGKey(0))
    host = jax.tree.map(lambda x: np.asarray(x), params)
    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((1, 1), ("data", "model"))
    new = reshard_params(cfg, host, mesh)
    for a, c in zip(jax.tree.leaves(new), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_versions_persisted(tmp_path):
    p = str(tmp_path / "v")
    save_snapshot(p, _snap(versions={"params": 42, "opt_state": 7}))
    snap, _ = load_snapshot(p)
    assert snap.versions == {"params": 42, "opt_state": 7}


# ---------------------------------------------------------------------------
# Crash consistency & integrity (on-disk format v2)
# ---------------------------------------------------------------------------
def test_torn_write_never_discoverable(tmp_path):
    """A crash mid-save publishes nothing: no snapshot dir, no manifest,
    and discovery never sees the hidden write debris."""
    from repro.chaos import FaultPlan, FaultSpec, InjectedCrash

    p = str(tmp_path / "t-step3")
    plan = FaultPlan([FaultSpec(site="ckpt.save", kind="torn", at=1)])
    with pytest.raises(InjectedCrash):
        save_snapshot(p, _snap(step=3), chaos=plan)
    assert not os.path.exists(p)
    assert snapshot_candidates(str(tmp_path), "t") == []
    debris = os.listdir(tmp_path)
    assert debris and all(d.startswith(".tmp-") for d in debris)
    with pytest.raises(CheckpointCorruptError, match="manifest.json missing"):
        load_snapshot(p)


def test_torn_manifest_write_never_discoverable(tmp_path):
    """Same, crashing after all buffers but before the manifest."""
    from repro.chaos import FaultPlan, FaultSpec, InjectedCrash

    p = str(tmp_path / "t-step4")
    plan = FaultPlan([FaultSpec(site="ckpt.save", kind="torn", at=1,
                                match="manifest")])
    with pytest.raises(InjectedCrash):
        save_snapshot(p, _snap(step=4), chaos=plan)
    assert not os.path.exists(p)
    assert snapshot_candidates(str(tmp_path), "t") == []


def test_bitflip_detected_and_names_buffer(tmp_path):
    p = str(tmp_path / "bf")
    save_snapshot(p, _snap(step=1))
    f = os.path.join(p, "params.npz")
    with open(f, "r+b") as fh:
        fh.seek(os.path.getsize(f) // 2)
        b = fh.read(1)
        fh.seek(-1, os.SEEK_CUR)
        fh.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(CheckpointCorruptError, match="'params'"):
        load_snapshot(p)


def test_truncation_detected(tmp_path):
    p = str(tmp_path / "tr")
    save_snapshot(p, _snap(step=1))
    f = os.path.join(p, "opt_state.npz")
    with open(f, "r+b") as fh:
        fh.truncate(os.path.getsize(f) // 2)
    with pytest.raises(CheckpointCorruptError, match="'opt_state'"):
        load_snapshot(p)


def test_missing_incremental_parent_buffer_named(tmp_path):
    """An incremental snapshot whose reused buffer rotted away in the
    *previous* directory fails loudly, naming the buffer."""
    p1, p2 = str(tmp_path / "c1"), str(tmp_path / "c2")
    save_snapshot(p1, _snap(step=1, versions={"params": 3, "opt_state": 3}))
    save_snapshot(p2, _snap(step=2, versions={"params": 4, "opt_state": 3},
                            val=2.0), prev_path=p1)
    os.remove(os.path.join(p1, "opt_state.npz"))
    with pytest.raises(CheckpointCorruptError, match="'opt_state'"):
        load_snapshot(p2)


def test_load_latest_good_walks_chain(tmp_path):
    """Corrupting the newest snapshot falls back along prev_path to the
    last ancestor that verifies, reporting what was skipped."""
    p1, p2 = str(tmp_path / "c1"), str(tmp_path / "c2")
    save_snapshot(p1, _snap(step=1, versions={"params": 3, "opt_state": 3}))
    save_snapshot(p2, _snap(step=2, versions={"params": 4, "opt_state": 3},
                            val=2.0), prev_path=p1)
    os.remove(os.path.join(p2, "params.npz"))
    snap, _, used, skipped = load_latest_good(p2)
    assert used == os.path.abspath(p1) and snap.step == 1
    assert len(skipped) == 1 and skipped[0][0] == p2
    np.testing.assert_array_equal(snap.buffers["params"]["w"],
                                  np.full((4, 4), 1.0))
    # whole chain rotten -> loud failure listing everything tried
    os.remove(os.path.join(p1, "manifest.json"))
    with pytest.raises(CheckpointCorruptError, match="no restorable"):
        load_latest_good(p2)


def test_legacy_manifest_without_digests_loads(tmp_path):
    """Format-1 snapshots (no digest fields) still restore, unverified."""
    import json

    p = str(tmp_path / "v1")
    save_snapshot(p, _snap(step=6))
    mpath = os.path.join(p, "manifest.json")
    with open(mpath) as f:
        m = json.load(f)
    for k in ("digests", "file_digests", "prev_path", "format"):
        m.pop(k, None)
    with open(mpath, "w") as f:
        json.dump(m, f)
    snap, _ = load_snapshot(p)
    assert snap.step == 6


def test_snapshot_candidates_numeric_order(tmp_path):
    """step10 sorts after step9 (numeric, not lexicographic) and write
    debris / foreign dirs are never candidates."""
    for step in (2, 9, 10):
        save_snapshot(str(tmp_path / f"c-step{step}"), _snap(step=step))
    os.makedirs(tmp_path / ".tmp-c-step11-x")
    os.makedirs(tmp_path / "c-stepNaN")
    got = snapshot_candidates([str(tmp_path)], "c")
    assert [os.path.basename(p) for p in got] == \
        ["c-step10", "c-step9", "c-step2"]
