"""Checkpoint substrate: exact roundtrips (incl. bfloat16), incremental
reuse, async saves, resharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (AsyncCheckpointer, load_snapshot, reshard_params,
                        save_snapshot)
from repro.core.state import GuestState, TaskSnapshot


def _snap(step=0, versions=None, val=1.0):
    buffers = {
        "params": {"w": np.full((4, 4), val, np.float32),
                   "b": jnp.ones((3,), jnp.bfloat16) * val},
        "opt_state": {"m": (np.zeros(2, np.int64),)},
    }
    return TaskSnapshot(task_id="t", guest_state=GuestState(step=step),
                        buffers=buffers, step=step,
                        versions=versions or {"params": 1, "opt_state": 1})


def test_roundtrip_exact(tmp_path):
    p = str(tmp_path / "ck")
    save_snapshot(p, _snap(step=5))
    snap, image = load_snapshot(p)
    assert snap.step == 5
    assert snap.guest_state.step == 5
    np.testing.assert_array_equal(snap.buffers["params"]["w"],
                                  np.full((4, 4), 1.0))
    b = snap.buffers["params"]["b"]
    assert b.dtype == jnp.bfloat16                # dtype survives npz
    np.testing.assert_array_equal(np.asarray(b, np.float32), np.ones(3))
    assert isinstance(snap.buffers["opt_state"]["m"], tuple)  # structure


def test_incremental_reuses_unchanged_buffers(tmp_path):
    p1 = str(tmp_path / "c1")
    p2 = str(tmp_path / "c2")
    s1 = _snap(step=1, versions={"params": 3, "opt_state": 3})
    stats1 = save_snapshot(p1, s1)
    assert stats1["reused_buffers"] == 0
    # params changed (version bump), opt_state unchanged
    s2 = _snap(step=2, versions={"params": 4, "opt_state": 3}, val=2.0)
    stats2 = save_snapshot(p2, s2, prev_path=p1)
    assert stats2["reused_buffers"] == 1
    assert stats2["written_bytes"] < stats1["written_bytes"]
    snap, _ = load_snapshot(p2)
    np.testing.assert_array_equal(snap.buffers["params"]["w"],
                                  np.full((4, 4), 2.0))


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer()
    p = str(tmp_path / "a1")
    ck.save(p, _snap(step=9))
    stats = ck.wait()
    assert stats["written_bytes"] > 0
    snap, _ = load_snapshot(p)
    assert snap.step == 9


def test_reshard_params_roundtrip():
    from repro.configs import get_arch
    from repro.models import build_model

    cfg = get_arch("yi-9b-smoke")
    b = build_model(cfg)
    params = b.init(jax.random.PRNGKey(0))
    host = jax.tree.map(lambda x: np.asarray(x), params)
    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((1, 1), ("data", "model"))
    new = reshard_params(cfg, host, mesh)
    for a, c in zip(jax.tree.leaves(new), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_versions_persisted(tmp_path):
    p = str(tmp_path / "v")
    save_snapshot(p, _snap(versions={"params": 42, "opt_state": 7}))
    snap, _ = load_snapshot(p)
    assert snap.versions == {"params": 42, "opt_state": 7}
