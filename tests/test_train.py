"""Training substrate: convergence, microbatch equivalence (the paper's
request-splitting), chunked == fused, data determinism."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import bundle_for, params_for
from repro.configs import SHAPES, get_arch
from repro.train import (DataConfig, OptConfig, make_batch,
                         make_chunked_train_fns, make_train_state,
                         make_train_step)

SHAPE = dataclasses.replace(SHAPES["train_4k"], seq_len=32, global_batch=8)
OC = OptConfig(warmup_steps=2, decay_steps=50, moment_dtype="float32")


def test_loss_decreases():
    cfg = get_arch("yi-9b-smoke")
    b = bundle_for("yi-9b-smoke")
    params, opt = make_train_state(b, OC, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(b, OC))
    first = last = None
    for i in range(12):
        batch = make_batch(cfg, SHAPE, i % 2)   # reuse 2 batches -> must fit
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first


def _tree_close(a, b, tol):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        d = np.max(np.abs(np.asarray(x, np.float32)
                          - np.asarray(y, np.float32)))
        assert d <= tol, d


def test_microbatch_split_equivalence():
    """mb=1 vs mb=4 must produce (nearly) identical updates — the paper's
    claim that chunk splitting costs no accuracy (<0.1% overhead, Fig 9)."""
    cfg = get_arch("yi-9b-smoke")
    b = bundle_for("yi-9b-smoke")
    params, opt = make_train_state(b, OC, jax.random.PRNGKey(1))
    batch = make_batch(cfg, SHAPE, 0)
    s1 = jax.jit(make_train_step(b, OC, num_microbatches=1))
    s4 = jax.jit(make_train_step(b, OC, num_microbatches=4))
    p1, o1, m1 = s1(params, opt, batch)
    p4, o4, m4 = s4(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-2
    _tree_close(p1, p4, 2e-2)   # bf16 params, f32 accum


def test_chunked_fns_match_fused_step():
    cfg = get_arch("yi-9b-smoke")
    b = bundle_for("yi-9b-smoke")
    params, opt = make_train_state(b, OC, jax.random.PRNGKey(2))
    batch = make_batch(cfg, SHAPE, 3)
    fused = jax.jit(make_train_step(b, OC, num_microbatches=2))
    p_f, o_f, _ = fused(params, opt, batch)

    grad_init, grad_step, apply_step = make_chunked_train_fns(b, OC)
    gi = jax.jit(grad_init)
    gs = jax.jit(grad_step)
    ap = jax.jit(apply_step, static_argnums=3)
    acc = gi(params)
    mb = jax.tree.map(lambda x: x.reshape(2, 4, *x.shape[1:]), batch)
    for c in range(2):
        acc, loss = gs(params, acc, jax.tree.map(lambda x: x[c], mb))
    p_c, o_c, _ = ap(params, opt, acc, 2)
    _tree_close(p_f, p_c, 1e-6)


def test_data_pipeline_deterministic():
    cfg = get_arch("qwen3-8b-smoke")
    b1 = make_batch(cfg, SHAPE, 5, DataConfig(seed=3))
    b2 = make_batch(cfg, SHAPE, 5, DataConfig(seed=3))
    b3 = make_batch(cfg, SHAPE, 6, DataConfig(seed=3))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_families():
    for arch in ("seamless-m4t-large-v2-smoke", "llava-next-mistral-7b-smoke",
                 "mamba2-1.3b-smoke"):
        cfg = get_arch(arch)
        b = make_batch(cfg, SHAPE, 0)
        if cfg.family == "encdec":
            assert "src_emb" in b and "tgt_tokens" in b
        elif cfg.family == "vlm":
            assert "img_emb" in b
            assert b["img_emb"].shape[1] == cfg.num_image_tokens
        else:
            assert b["tokens"].shape == (8, 32)


def test_prefetching_loader():
    from repro.train import PrefetchingLoader

    cfg = get_arch("yi-9b-smoke")
    loader = PrefetchingLoader(cfg, SHAPE, DataConfig(seed=1), depth=2)
    b0 = next(loader)
    b1 = next(loader)
    loader.close()
    ref0 = make_batch(cfg, SHAPE, 0, DataConfig(seed=1))
    np.testing.assert_array_equal(b0["tokens"], ref0["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_lr_schedule():
    from repro.train import lr_at

    oc = OptConfig(peak_lr=1e-3, min_lr=1e-4, warmup_steps=10,
                   decay_steps=100)
    assert float(lr_at(oc, jnp.int32(0))) == 0.0
    assert abs(float(lr_at(oc, jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr_at(oc, jnp.int32(100))) <= 1.1e-4


def test_int8_adam_converges_like_f32():
    """8-bit Adam (log-quantized v) must track f32 Adam on a regression."""
    import numpy as np

    from repro.train.optimizer import apply_updates, init_opt_state

    W_true = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    X = jax.random.normal(jax.random.PRNGKey(1), (128, 32))
    Y = X @ W_true

    def loss_fn(p):
        return jnp.mean((X @ p["w"] - Y) ** 2)

    final = {}
    for mdt in ("float32", "int8"):
        oc = OptConfig(peak_lr=5e-2, warmup_steps=5, decay_steps=200,
                       weight_decay=0.0, moment_dtype=mdt)
        params = {"w": jnp.zeros((32, 16))}
        st = init_opt_state(oc, params)
        step = jax.jit(
            lambda p, s: apply_updates(oc, p, jax.grad(loss_fn)(p), s))
        for _ in range(200):
            params, st, _ = step(params, st)
        final[mdt] = float(loss_fn(params))
    assert final["int8"] < max(final["float32"] * 10, 1e-3)
    # state is genuinely 8-bit + scales
    oc = OptConfig(moment_dtype="int8")
    st = init_opt_state(oc, {"w": jnp.zeros((8, 4))})
    assert st["m"]["w"].dtype == jnp.int8
    assert st["v_scale"]["w"].shape == (8, 2)


def test_moe_a2a_matches_local_dispatch():
    """2D-EP all-to-all dispatch == local dispatch (degenerate 1-dev mesh)."""
    import dataclasses

    from conftest import tiny_batch
    from repro.models import build_model

    cfg = get_arch("deepseek-moe-16b-smoke")
    cfg_hi = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    b1 = build_model(cfg_hi)
    params = b1.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg_hi)
    l1, _ = b1.loss_fn(params, batch)
    b2 = build_model(dataclasses.replace(cfg_hi, moe_dispatch="a2a"))
    l2, _ = b2.loss_fn(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-4
