"""Continuous-batching engine: slot admission/backfill ordering, mid-batch
preemption (evict -> resume resumes every in-flight sequence bit-exactly),
per-request latency metrics, and router integration."""

import numpy as np
import pytest

from repro.core import FunkyCL, Monitor, SliceAllocator
from repro.scaling.metrics import MetricsRegistry
from repro.scaling.serving import RequestRouter
from repro.serve.engine import (M_TBT, M_TTFT, ContinuousBatchingEngine,
                                ServeRequest)

ARCH = "yi-9b-smoke"
PROMPT_LEN = 8


def make_engine(slots=2, max_new=8, registry=None):
    reg = registry if registry is not None else MetricsRegistry()
    mon = Monitor("eng-test", SliceAllocator("n0", 1), telemetry=reg)
    eng = ContinuousBatchingEngine(ARCH, FunkyCL(mon), slots=slots,
                                   prompt_len=PROMPT_LEN,
                                   max_new_tokens=max_new, registry=reg)
    eng.setup()
    return mon, eng, reg


def make_requests(spec, seed=0):
    """spec: list of max_new_tokens; prompts drawn deterministically."""
    rng = np.random.Generator(np.random.Philox(seed))
    return [ServeRequest(rid=f"r{i}", prompt=rng.integers(0, 100, PROMPT_LEN),
                         max_new_tokens=n)
            for i, n in enumerate(spec)]


@pytest.fixture(scope="module")
def engine_run():
    """One shared engine run: 5 ragged requests over 2 slots."""
    mon, eng, reg = make_engine(slots=2, max_new=8)
    spec = [2, 6, 2, 3, 2]
    for r in make_requests(spec):
        eng.submit(r)
    eng.run_until_drained()
    mon.vfpga_exit()
    return eng, reg, spec


def test_all_requests_complete(engine_run):
    eng, _, spec = engine_run
    assert sorted(eng.completed) == [f"r{i}" for i in range(len(spec))]
    for i, n in enumerate(spec):
        assert len(eng.completed[f"r{i}"].tokens) == n


def test_admission_fifo_and_backfill(engine_run):
    """Admissions happen in arrival order; a freed slot is backfilled by the
    next pending request while the rest of the batch keeps decoding."""
    eng, reg, _ = engine_run
    events = [(e[1], e[2]) for e in reg.flight_record()["events"]
              if e[1] in ("engine_admit", "engine_retire")]
    admits = [f for k, f in events if k == "engine_admit"]
    assert [a["rid"] for a in admits] == ["r0", "r1", "r2", "r3", "r4"]
    # r0 (2 tokens) retires before r1 (6 tokens); r2 backfills r0's slot
    order = [(k, f["rid"]) for k, f in events]
    assert order.index(("engine_retire", "r0")) \
        < order.index(("engine_admit", "r2"))
    slot_of = {a["rid"]: a["slot"] for a in admits}
    retired_first = next(f for k, f in events if k == "engine_retire")
    assert slot_of["r2"] == retired_first["slot"]
    # r1 was never interrupted: it retired after every backfill admission
    assert order.index(("engine_retire", "r1")) \
        > order.index(("engine_admit", "r3"))


def test_latency_metrics_schema(engine_run):
    """Per-request TTFT/TBT/e2e land in the shared registry schema."""
    eng, reg, spec = engine_run
    snap = reg.snapshot()
    n, total = len(spec), sum(spec)
    assert snap["histograms"][f"{M_TTFT}{{service=svc}}"]["count"] == n
    assert (snap["histograms"][f"{M_TBT}{{service=svc}}"]["count"]
            == total - n)
    assert (snap["histograms"]["request_latency_seconds{service=svc}"]
            ["count"] == n)
    assert snap["counters"]["completions_total{service=svc}"] == n
    assert snap["counters"]["engine_tokens_total{service=svc}"] == total
    for rec in eng.completed.values():
        assert rec.ttft_s >= 0 and rec.e2e_s >= rec.ttft_s
        assert len(rec.tbts) == len(rec.tokens) - 1


def test_decode_and_admit_are_donated(engine_run):
    """The KV-cache update path compiles with buffer donation (in-place
    cache update, no per-token copy)."""
    eng, _, _ = engine_run
    mon_keys = [(pid, d) for (pid, _, d) in
                eng.cl._monitor.programs._compiled.keys()]
    assert ("decode_step", (1, 2, 3)) in mon_keys
    assert ("admit_slot", (0, 1, 2)) in mon_keys


def test_preemption_mid_batch_resumes_identically():
    """evict -> resume mid-batch: every in-flight sequence continues with
    identical tokens (greedy decode + DIRTY-buffer snapshot/restore)."""
    spec = [3, 6, 4, 5]

    mon_a, eng_a, _ = make_engine(slots=2, max_new=8)
    for r in make_requests(spec, seed=3):
        eng_a.submit(r)
    eng_a.run_until_drained()
    ref = {rid: rec.tokens for rid, rec in eng_a.completed.items()}
    mon_a.vfpga_exit()

    mon_b, eng_b, _ = make_engine(slots=2, max_new=8)
    for r in make_requests(spec, seed=3):
        eng_b.submit(r)
    for _ in range(2):
        eng_b.step()
    assert eng_b.active_count > 0          # genuinely mid-batch
    stats = mon_b.evict()
    assert stats["n_dirty"] > 0
    mon_b.resume()
    eng_b.run_until_drained()
    got = {rid: rec.tokens for rid, rec in eng_b.completed.items()}
    mon_b.vfpga_exit()
    assert got == ref


def test_router_pump_and_requeue():
    """pump() pulls only what free slots allow; requeue puts killed work
    back at the head with in-flight accounting intact."""
    router = RequestRouter("svc")
    for r in make_requests([2, 2, 2, 2], seed=5):
        router.submit(r)
    assert router.pending_count() == 4
    popped = router.pop(2)
    assert [r.rid for r in popped] == ["r0", "r1"]
    assert router.in_flight == 2 and router.outstanding() == 4
    router.requeue(popped)
    assert router.in_flight == 0
    assert [r.rid for r in router.pop(4)] == ["r0", "r1", "r2", "r3"]

    mon, eng, reg = make_engine(slots=2, max_new=4)
    router2 = RequestRouter("svc", registry=reg)
    for r in make_requests([2, 3, 2], seed=6):
        router2.submit(r)
    while router2.outstanding() > 0:
        if not eng.pump(router2):
            break
    assert len(router2.completed) == 3
    assert router2.in_flight == 0
    mon.vfpga_exit()
