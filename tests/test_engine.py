"""Continuous-batching engine over paged KV memory: slot admission/backfill
ordering, paged-vs-reserved bit-exactness, mid-batch preemption (evict ->
resume resumes every in-flight sequence bit-exactly, serializing only dirty
pages), pool-exhaustion OOM preemption, block-table reuse without
stale-page leakage, compaction, prompt buckets, draining, per-request
latency metrics, and router integration."""

import numpy as np
import pytest

from repro.core import FunkyCL, Monitor, SliceAllocator
from repro.scaling.autoscaler import M_KV_FREE_PAGES
from repro.scaling.metrics import MetricsRegistry
from repro.scaling.serving import RequestRouter
from repro.serve.engine import (M_TBT, M_TTFT, ContinuousBatchingEngine,
                                ServeRequest)

ARCH = "yi-9b-smoke"
PROMPT_LEN = 8
PAGE = 4


def make_engine(slots=2, max_new=8, registry=None, **kw):
    reg = registry if registry is not None else MetricsRegistry()
    mon = Monitor("eng-test", SliceAllocator("n0", 1), telemetry=reg)
    eng = ContinuousBatchingEngine(ARCH, FunkyCL(mon), slots=slots,
                                   prompt_len=PROMPT_LEN,
                                   max_new_tokens=max_new, registry=reg,
                                   page_size=PAGE, **kw)
    eng.setup()
    return mon, eng, reg


def make_requests(spec, seed=0):
    """spec: list of max_new_tokens; prompts drawn deterministically."""
    rng = np.random.Generator(np.random.Philox(seed))
    return [ServeRequest(rid=f"r{i}", prompt=rng.integers(0, 100, PROMPT_LEN),
                         max_new_tokens=n)
            for i, n in enumerate(spec)]


@pytest.fixture(scope="module")
def engine_run():
    """One shared engine run: 5 ragged requests over 2 slots."""
    mon, eng, reg = make_engine(slots=2, max_new=8)
    spec = [2, 6, 2, 3, 2]
    for r in make_requests(spec):
        eng.submit(r)
    eng.run_until_drained()
    mon.vfpga_exit()
    return eng, reg, spec


def test_all_requests_complete(engine_run):
    eng, _, spec = engine_run
    assert sorted(eng.completed) == [f"r{i}" for i in range(len(spec))]
    for i, n in enumerate(spec):
        assert len(eng.completed[f"r{i}"].tokens) == n


def test_admission_fifo_and_backfill(engine_run):
    """Admissions happen in arrival order; a freed slot is backfilled by the
    next pending request while the rest of the batch keeps decoding."""
    eng, reg, _ = engine_run
    events = [(e[1], e[2]) for e in reg.flight_record()["events"]
              if e[1] in ("engine_admit", "engine_retire")]
    admits = [f for k, f in events if k == "engine_admit"]
    assert [a["rid"] for a in admits] == ["r0", "r1", "r2", "r3", "r4"]
    # r0 (2 tokens) retires before r1 (6 tokens); r2 backfills r0's slot
    order = [(k, f["rid"]) for k, f in events]
    assert order.index(("engine_retire", "r0")) \
        < order.index(("engine_admit", "r2"))
    slot_of = {a["rid"]: a["slot"] for a in admits}
    retired_first = next(f for k, f in events if k == "engine_retire")
    assert slot_of["r2"] == retired_first["slot"]
    # r1 was never interrupted: it retired after every backfill admission
    assert order.index(("engine_retire", "r1")) \
        > order.index(("engine_admit", "r3"))


def test_latency_metrics_schema(engine_run):
    """Per-request TTFT/TBT/e2e land in the shared registry schema."""
    eng, reg, spec = engine_run
    snap = reg.snapshot()
    n, total = len(spec), sum(spec)
    assert snap["histograms"][f"{M_TTFT}{{service=svc}}"]["count"] == n
    assert (snap["histograms"][f"{M_TBT}{{service=svc}}"]["count"]
            == total - n)
    assert (snap["histograms"]["request_latency_seconds{service=svc}"]
            ["count"] == n)
    assert snap["counters"]["completions_total{service=svc}"] == n
    assert snap["counters"]["engine_tokens_total{service=svc}"] == total
    for rec in eng.completed.values():
        assert rec.ttft_s >= 0 and rec.e2e_s >= rec.ttft_s
        assert len(rec.tbts) == len(rec.tokens) - 1


def test_decode_and_admit_are_donated(engine_run):
    """The paged KV update path compiles with buffer donation (in-place
    pool update, no per-token copy of the pool)."""
    eng, _, _ = engine_run
    mon_keys = [(pid, d) for (pid, _, d) in
                eng.cl._monitor.programs._compiled.keys()]
    assert ("decode_step", (1, 2, 4)) in mon_keys          # toks, pos, pool
    assert (f"prefill_admit_{PROMPT_LEN}", (1, 2, 3)) in mon_keys
    assert ("scrub", (0,)) in mon_keys


SPEC = [3, 6, 4, 5]


@pytest.fixture(scope="module")
def dense_ref():
    """Worst-case-reservation (non-paged) reference tokens for SPEC."""
    mon, eng, _ = make_engine(slots=2, max_new=8, paged=False)
    for r in make_requests(SPEC, seed=3):
        eng.submit(r)
    eng.run_until_drained()
    ref = {rid: rec.tokens for rid, rec in eng.completed.items()}
    mon.vfpga_exit()
    return ref


def test_paged_evict_resume_bit_exact_vs_dense(dense_ref):
    """Mid-batch evict -> resume of the paged engine: every in-flight
    ragged sequence continues bit-exactly vs the dense baseline, and the
    second evict serializes only the pages dirtied since the first."""
    mon, eng, _ = make_engine(slots=2, max_new=8)
    for r in make_requests(SPEC, seed=3):
        eng.submit(r)
    for _ in range(2):
        eng.step()
    assert eng.active_count > 0            # genuinely mid-batch
    stats = mon.evict()
    assert stats["n_dirty"] > 0
    # first evict has no prior host copy of the pool: full save
    assert stats["paged_saved_pages"] == stats["paged_total_pages"] > 0
    mon.resume()
    eng.step()
    assert eng.active_count > 0
    stats2 = mon.evict()
    # page-granular dirtiness: one iteration touches at most one page per
    # active lane (plus appends), nowhere near the whole pool
    assert 0 < stats2["paged_saved_pages"] < stats2["paged_total_pages"]
    mon.resume()
    eng.run_until_drained()
    got = {rid: rec.tokens for rid, rec in eng.completed.items()}
    mon.vfpga_exit()
    assert got == dense_ref


def test_oom_preemption_compaction_and_resume(dense_ref):
    """A pool too small for every lane's worst case forces OOM preemption;
    preempted requests recompute deterministically, compaction mid-flight
    is invisible, and a mid-run evict/resume still lands bit-exactly."""
    mon, eng, _ = make_engine(slots=2, max_new=8, pool_pages=6,
                              reserve_pages=1)
    for r in make_requests(SPEC, seed=3):
        eng.submit(r)
    for _ in range(3):
        eng.step()
    eng.compact()
    eng.pool.check_invariants()
    stats = mon.evict()
    assert stats["n_dirty"] > 0
    mon.resume()
    eng.run_until_drained()
    got = {rid: rec.tokens for rid, rec in eng.completed.items()}
    assert eng.preemptions > 0             # the pool genuinely exhausted
    eng.pool.check_invariants()
    mon.vfpga_exit()
    assert got == dense_ref


def test_block_table_reuse_no_stale_page_leakage(dense_ref):
    """Pages freed by one wave of requests are reused by the next; the
    scrub-on-alloc rule means the new owners never attend to the previous
    wave's tokens (their results match a fresh dense engine)."""
    mon, eng, _ = make_engine(slots=2, max_new=8)
    for r in make_requests([8, 7, 8, 6], seed=11):      # wave A: fill pool
        eng.submit(r)
    eng.run_until_drained()
    assert eng.pool.used_count() == 0      # everything freed at retirement
    wave_b = make_requests(SPEC, seed=3)
    for r in wave_b:
        r.rid = "b-" + r.rid
        eng.submit(r)
    eng.run_until_drained()
    got = {rid[2:]: rec.tokens for rid, rec in eng.completed.items()
           if rid.startswith("b-")}
    mon.vfpga_exit()
    assert got == dense_ref


def test_memory_based_admission_and_watermark():
    """Admission is gated on free pages, not lane count: with a pool that
    holds one prompt (plus reserve), only one of two free lanes admits."""
    mon, eng, _ = make_engine(slots=2, max_new=8, pool_pages=4,
                              reserve_pages=1)
    for r in make_requests([4, 4], seed=5):
        eng.submit(r)
    out = eng.step()
    # prompt needs 2 pages; after one admission 2 free - 2 < 1 reserve
    assert out["admitted"] == 1 and out["pending"] == 1
    eng.run_until_drained()
    assert len(eng.completed) == 2
    mon.vfpga_exit()


def test_prompt_buckets_route_admissions():
    """Ragged prompts route to the smallest fitting prefill bucket instead
    of all padding to one prompt_len."""
    mon, eng, _ = make_engine(slots=2, max_new=6, prompt_buckets=(4, 8))
    assert eng._pick_bucket(3) == 4
    assert eng._pick_bucket(4) == 4
    assert eng._pick_bucket(5) == 8
    assert eng._pick_bucket(99) == 8       # over-long prompts truncate
    mon_keys = [pid for (pid, _, _) in
                eng.cl._monitor.programs._compiled.keys()]
    assert {"prefill_admit_4", "prefill_admit_8"} <= set(mon_keys)
    rng = np.random.Generator(np.random.Philox(9))
    eng.submit(ServeRequest(rid="short", prompt=rng.integers(0, 100, 3),
                            max_new_tokens=5))
    eng.submit(ServeRequest(rid="long", prompt=rng.integers(0, 100, 8),
                            max_new_tokens=4))
    # an over-cap ask is clamped to the engine's provisioned cap instead
    # of walking past the block table (cache is sized for max_new_tokens)
    eng.submit(ServeRequest(rid="over", prompt=rng.integers(0, 100, 8),
                            max_new_tokens=99))
    eng.run_until_drained()
    assert len(eng.completed["short"].tokens) == 5
    assert len(eng.completed["long"].tokens) == 4
    assert len(eng.completed["over"].tokens) == 6
    # the short request was admitted at bucket width 4: its lane freed
    # ceil((4 + 5) / PAGE) pages at retirement, not the bucket-8 worst case
    admits = [e for e in eng.registry.flight_record()["events"]
              if e[1] == "engine_admit"]
    assert {a[2]["rid"] for a in admits} == {"short", "long", "over"}
    mon.vfpga_exit()


def test_drain_before_kill_live_replica():
    """Scale-in prelude through the runtime: ``drain`` flips the replica
    into its draining state, the driver finishes the held sequences and
    exits at the request boundary — nothing is requeued for recomputation."""
    import time

    from repro.core import TaskImage, TaskStatus, make_cluster
    from repro.scaling.serving import reset_router

    img = TaskImage(name="drain-svc", kind="engine-serve", arch=ARCH,
                    prompt_len=PROMPT_LEN, global_batch=2,
                    total_steps=10 ** 9, max_new_tokens=6, page_size=PAGE)
    cluster = make_cluster(num_nodes=1, slices_per_node=1,
                           images={"drain-svc": img})
    router = reset_router("drain-svc")
    try:
        rt = cluster.nodes["node0"].runtime
        rt.create("d1", img)
        rt.start("d1")
        for r in make_requests([4, 4, 4], seed=13):
            router.submit(r)
        deadline = time.time() + 300
        while not router.in_flight and time.time() < deadline:
            time.sleep(0.01)               # wait until the engine has work
        stats = rt.drain("d1", timeout_s=300)
        assert stats["drained"]
        assert rt.wait("d1", timeout=60) == TaskStatus.DONE
        assert router.in_flight == 0       # held work finished, not requeued
        assert len(router.completed) + router.pending_count() == 3
        assert len(router.completed) >= 2
        rt.kill("d1")                      # the follow-up remove is a no-op
        assert router.pending_count() + len(router.completed) == 3
    finally:
        cluster.stop()


def test_drain_stops_admissions_and_finishes_lanes():
    """pump(admit=False): a draining replica pulls nothing new from the
    router and retires what it already holds (drain-before-kill)."""
    mon, eng, reg = make_engine(slots=2, max_new=4)
    router = RequestRouter("svc", registry=reg)
    for r in make_requests([3, 3, 3, 3], seed=7):
        router.submit(r)
    eng.pump(router)                       # pulls 2 into the lanes
    assert eng.active_count == 2 and router.pending_count() == 2
    while eng.pump(router, admit=False):
        pass
    assert eng.idle and len(eng.completed) == 2
    assert router.pending_count() == 2     # untouched by the drained engine
    assert router.in_flight == 0           # completions reported back
    mon.vfpga_exit()


def test_router_pump_and_requeue():
    """pump() pulls only what free slots allow; requeue puts killed work
    back at the head with in-flight accounting intact."""
    router = RequestRouter("svc")
    for r in make_requests([2, 2, 2, 2], seed=5):
        router.submit(r)
    assert router.pending_count() == 4
    popped = router.pop(2)
    assert [r.rid for r in popped] == ["r0", "r1"]
    assert router.in_flight == 2 and router.outstanding() == 4
    router.requeue(popped)
    assert router.in_flight == 0
    assert [r.rid for r in router.pop(4)] == ["r0", "r1", "r2", "r3"]

    mon, eng, reg = make_engine(slots=2, max_new=4)
    router2 = RequestRouter("svc", registry=reg)
    for r in make_requests([2, 3, 2], seed=6):
        router2.submit(r)
    while router2.outstanding() > 0:
        if not eng.pump(router2):
            break
    assert len(router2.completed) == 3
    assert router2.in_flight == 0
    mon.vfpga_exit()


# ---------------------------------------------------------------------------
# KV-aware routing (per-engine kv_free_pages gauges, synthetic)
# ---------------------------------------------------------------------------
def _routing_setup(free_a, free_b, n_req=3):
    reg = MetricsRegistry()
    router = RequestRouter("svc", registry=reg)
    reg.gauge(M_KV_FREE_PAGES, service="svc", engine="eA").set(free_a)
    reg.gauge(M_KV_FREE_PAGES, service="svc", engine="eB").set(free_b)
    for r in make_requests([2] * n_req, seed=21):
        router.submit(r)
    return reg, router


def test_kv_aware_routing_prefers_max_free_pages():
    """The replica with the most free KV pages is served first; a
    non-preferred replica is held back for exactly one pop."""
    _, router = _routing_setup(free_a=10, free_b=2)
    assert router.pop(2, engine_id="eB") == []          # deferred once
    assert [r.rid for r in router.pop(2, engine_id="eA")] == ["r0", "r1"]
    # liveness: the deferred replica is served on its next pop even while
    # still non-preferred — preference is a head start, not starvation
    assert [r.rid for r in router.pop(2, engine_id="eB")] == ["r2"]


def test_kv_aware_routing_ties_round_robin():
    """Equal free pages: every replica is preferred, so pops alternate in
    pump order (round-robin) with no deferrals."""
    _, router = _routing_setup(free_a=5, free_b=5)
    assert [r.rid for r in router.pop(1, engine_id="eB")] == ["r0"]
    assert [r.rid for r in router.pop(1, engine_id="eA")] == ["r1"]
    assert [r.rid for r in router.pop(1, engine_id="eB")] == ["r2"]


def test_killed_replica_never_captures_routing_preference():
    """evacuate() (the kill path) advertises 0 free pages — a dead
    replica's immortal gauge must not outrank loaded live replicas."""
    mon, eng, reg = make_engine(slots=2, max_new=8)
    for r in make_requests([3, 3], seed=33):
        eng.submit(r)
    eng.step()                             # publishes engine0 gauges
    assert reg.gauge(M_KV_FREE_PAGES, service="svc",
                     engine="engine0").value > 0
    eng.evacuate()
    assert reg.gauge(M_KV_FREE_PAGES, service="svc",
                     engine="engine0").value == 0.0
    # a live replica holding pages (low but nonzero free count) is still
    # preferred over the dead engine: its first pop succeeds
    reg.gauge(M_KV_FREE_PAGES, service="svc", engine="live").set(1.0)
    router = RequestRouter("svc", registry=reg)
    for r in make_requests([2], seed=34):
        router.submit(r)
    assert [r.rid for r in router.pop(1, engine_id="live")] == ["r0"]
    mon.vfpga_exit()


def test_prefix_aware_routing_prefers_warmest_replica():
    """With warmth probes registered, the replica whose prefix tree
    matches the head request wins the pop; a cold replica is held back
    exactly once (the single-deferral liveness rule still applies)."""
    _, router = _routing_setup(free_a=10, free_b=10)
    router.register_prefix_probe("eA", lambda p: 0)
    router.register_prefix_probe("eB", lambda p: 8)
    assert router.pop(1, engine_id="eA") == []          # cold: deferred
    assert [r.rid for r in router.pop(1, engine_id="eB")] == ["r0"]
    # liveness: the deferred cold replica is served on its next pop even
    # though eB is still warmer — preference is a head start, never
    # starvation
    assert [r.rid for r in router.pop(1, engine_id="eA")] == ["r1"]


def test_prefix_preference_capped_by_free_page_headroom():
    """Hit-skew starvation fix: a warm replica whose free pages fell
    below half the best replica's loses its preference, and routing
    falls back to the free-page load balance."""
    _, router = _routing_setup(free_a=10, free_b=4)
    router.register_prefix_probe("eA", lambda p: 0)
    router.register_prefix_probe("eB", lambda p: 8)     # warm but starving
    # eB is below the headroom bar (10/2): kv preference rules, eA wins
    assert [r.rid for r in router.pop(1, engine_id="eA")] == ["r0"]
    assert router.pop(1, engine_id="eB") == []          # deferred once
    assert [r.rid for r in router.pop(1, engine_id="eB")] == ["r1"]
    # with headroom restored (>= half the best), warmth wins again even
    # though eB still has fewer free pages than eA
    router.registry.gauge(M_KV_FREE_PAGES, service="svc",
                          engine="eB").set(6)
    assert router.pop(1, engine_id="eA") == []
    assert [r.rid for r in router.pop(1, engine_id="eB")] == ["r2"]


def test_failed_engine_probe_dropped():
    """A crashed replica's warmth probe must not keep attracting traffic
    (mirrors the NaN gauge tombstone rule)."""
    _, router = _routing_setup(free_a=10, free_b=10)
    router.register_prefix_probe("eB", lambda p: 8)
    router.fail_engine("eB")
    assert [r.rid for r in router.pop(1, engine_id="eA")] == ["r0"]


def test_engine_pump_registers_prefix_probe():
    """A prefix-cache engine advertises its warmth probe through pump();
    repeat prompts then route back to the replica that cached them."""
    mon, eng, reg = make_engine(slots=2, max_new=4, prefix_cache=True)
    router = RequestRouter("svc", registry=reg)
    rng = np.random.Generator(np.random.Philox(41))
    prompt = rng.integers(0, 100, PROMPT_LEN)
    router.submit(ServeRequest(rid="w0", prompt=prompt, max_new_tokens=2))
    while router.outstanding() or not eng.idle:
        if not eng.pump(router):
            break
    assert eng.engine_id in router._prefix_probes
    # the served prompt's pages are in the tree: the probe reports warmth
    assert router._prefix_probes[eng.engine_id](prompt) > 0
    mon.vfpga_exit()


def test_kv_aware_routing_untagged_and_unknown_pops_unaffected():
    """Pops without an engine tag (or from engines with no gauge yet) are
    never deferred; kv_aware=False disables the preference entirely."""
    _, router = _routing_setup(free_a=10, free_b=2)
    assert [r.rid for r in router.pop(1)] == ["r0"]
    assert [r.rid for r in router.pop(1, engine_id="newcomer")] == ["r1"]
    reg, router2 = _routing_setup(free_a=10, free_b=2)
    router2.kv_aware = False
    assert [r.rid for r in router2.pop(1, engine_id="eB")] == ["r0"]


# ---------------------------------------------------------------------------
# Auto-compaction (threshold-triggered, iteration-boundary only)
# ---------------------------------------------------------------------------
def test_auto_compaction_fires_at_threshold():
    """Fragmentation (1 - used/span) at/above the threshold triggers
    compact() at the top of the next iteration; below it, never."""
    mon, eng, _ = make_engine(slots=2, max_new=8, pool_pages=12,
                              auto_compact_frag=0.5,
                              auto_compact_min_pages=4)
    a = eng.pool.alloc(4)
    eng.pool.alloc(4)
    eng.pool.free(a)                   # used {4..7}: span 8, frag 0.5
    eng._maybe_auto_compact()
    assert eng.auto_compactions == 1
    assert eng.pool.used_span() == eng.pool.used_count() == 4
    eng.pool.check_invariants()
    events = [e for e in eng.registry.flight_record()["events"]
              if e[1] == "engine_auto_compact"]
    assert len(events) == 1
    eng._maybe_auto_compact()          # frag now 0: no refire
    assert eng.auto_compactions == 1
    mon.vfpga_exit()


def test_auto_compaction_respects_min_gap_and_threshold():
    mon, eng, _ = make_engine(slots=2, max_new=8, pool_pages=12,
                              auto_compact_frag=0.5,
                              auto_compact_min_pages=4)
    a = eng.pool.alloc(2)
    eng.pool.alloc(4)
    eng.pool.free(a)                   # gap 2 < min_pages 4
    eng._maybe_auto_compact()
    assert eng.auto_compactions == 0
    mon.vfpga_exit()
    mon, eng, _ = make_engine(slots=2, max_new=8, pool_pages=12,
                              auto_compact_frag=0.9,
                              auto_compact_min_pages=1)
    a = eng.pool.alloc(4)
    eng.pool.alloc(4)
    eng.pool.free(a)                   # frag 0.5 < threshold 0.9
    eng._maybe_auto_compact()
    assert eng.auto_compactions == 0
    mon.vfpga_exit()


def test_auto_compaction_live_churn_is_invisible(dense_ref):
    """Under retirement churn with an aggressive threshold the engine
    auto-compacts mid-workload and the token streams are untouched."""
    mon, eng, _ = make_engine(slots=2, max_new=8, auto_compact_frag=0.2,
                              auto_compact_min_pages=1)
    for r in make_requests([8, 7, 8, 6], seed=11):      # churn wave
        eng.submit(r)
    eng.run_until_drained()
    wave_b = make_requests(SPEC, seed=3)
    for r in wave_b:
        r.rid = "b-" + r.rid
        eng.submit(r)
    eng.run_until_drained()
    got = {rid[2:]: rec.tokens for rid, rec in eng.completed.items()
           if rid.startswith("b-")}
    assert eng.auto_compactions > 0
    eng.pool.check_invariants()
    mon.vfpga_exit()
    assert got == dense_ref


def test_compact_refuses_while_pages_in_flight():
    """compact() is only legal between iterations — with an iteration's
    EXECUTEs holding physical page ids it must refuse."""
    mon, eng, _ = make_engine(slots=2, max_new=4)
    eng._mid_step = True
    with pytest.raises(RuntimeError, match="in flight"):
        eng.compact()
    eng._mid_step = False
    eng.compact()                      # boundary: fine
    mon.vfpga_exit()


# ---------------------------------------------------------------------------
# Host-out-of-the-loop decode: fused multi-step EXECUTEs + async pipelining
# ---------------------------------------------------------------------------
def _fused_factory(**kw):
    def make():
        mon, eng, _ = make_engine(slots=2, max_new=8, **kw)
        return mon, eng
    return make


def _ragged_requests(spec=(6, 8, 4, 7, 5, 8), seed=2):
    def make():
        return make_requests(list(spec), seed=seed)
    return make


def test_fused_decode_bit_exact_vs_single_step():
    """k decode steps fused into one EXECUTE commit the same tokens the
    one-step-per-EXECUTE engine commits, and the block table is updated
    through on-device delta EXECUTEs, not full host rewrites."""
    from repro.serve.equivalence import check_equivalence

    eng, base = check_equivalence(
        _fused_factory(fuse_steps=4, async_depth=1), _fused_factory(),
        _ragged_requests(), context="fused vs single-step")
    assert eng.bt_delta_execs > 0
    # steady-state block-table maintenance is delta-driven; the only full
    # rewrites allowed are resync paths (evict/resume, delta overflow)
    assert eng.bt_full_writes == 0
    # k-step fusion must actually shrink EXECUTE count per token
    assert eng.host_device_split()["execs"] < \
        base.host_device_split()["execs"]


def test_fused_decode_evict_resume_mid_span():
    """Monitor-level evict/resume between iterations — with fused spans in
    flight the resumed device state must continue bit-exactly."""
    from repro.serve.equivalence import check_equivalence, evict_resume_every

    check_equivalence(
        _fused_factory(fuse_steps=4, async_depth=1), _fused_factory(),
        _ragged_requests(), step_hook=evict_resume_every(3),
        context="fused + evict/resume")


def test_fused_decode_oom_preemption_mid_span():
    """A pool too small for every lane's k-step lookahead span: lanes are
    preempted mid-span, recomputed, and the stream stays bit-exact."""
    from repro.serve.equivalence import check_equivalence

    eng, _ = check_equivalence(
        _fused_factory(fuse_steps=4, async_depth=1, pool_pages=6),
        _fused_factory(), _ragged_requests(),
        context="fused + OOM preemption")
    assert eng.preemptions > 0, "pool was not tight enough to preempt"
    eng.pool.check_invariants()


def test_async_pipeline_without_fusion_bit_exact():
    """async_depth alone (k=1): iteration N+1's EXECUTE is submitted
    before N's tokens are read back, and commits are unchanged."""
    from repro.serve.equivalence import check_equivalence

    eng, _ = check_equivalence(
        _fused_factory(fuse_steps=1, async_depth=2), _fused_factory(),
        _ragged_requests(), context="async pipeline")
    assert eng.bt_delta_execs > 0


def test_fused_decode_invalid_configs_rejected():
    reg = MetricsRegistry()
    mon = Monitor("fused-bad", SliceAllocator("n0", 1), telemetry=reg)
    cl = FunkyCL(mon)
    mk = lambda **kw: ContinuousBatchingEngine(
        ARCH, cl, slots=2, prompt_len=PROMPT_LEN, max_new_tokens=4,
        registry=reg, page_size=PAGE, **kw)
    with pytest.raises(ValueError):
        mk(fuse_steps=0)
    with pytest.raises(ValueError):
        mk(async_depth=-1)
    with pytest.raises(ValueError):
        mk(paged=False, fuse_steps=4)
    from repro.serve.engine import SpecConfig
    with pytest.raises(ValueError):
        mk(fuse_steps=4, spec=SpecConfig(k=2))
    mon.vfpga_exit()


def test_fused_decode_with_compaction_drains_pipeline():
    """compact() remaps physical pages, so it must first drain in-flight
    fused EXECUTEs that hold the old ids; compacting every iteration of a
    pipelined run stays bit-exact."""
    from repro.serve.equivalence import check_equivalence

    def hook(eng, mon, i):
        eng.compact()

    eng, _ = check_equivalence(
        _fused_factory(fuse_steps=4, async_depth=1), _fused_factory(),
        _ragged_requests(), step_hook=hook,
        context="fused + compaction")
    eng.pool.check_invariants()
