"""Cross-request KV prefix cache: radix-tree units (match/insert/dedup/
LRU eviction/remap over a refcounted BlockPool) and the engine-level
bit-exactness gates — a prefix-hit request streams tokens identical to a
cold run of the same engine config, including across evict/resume and
OOM preemption mid-shared-prefix, with copy-on-write protecting shared
pages from divergent writes."""

import numpy as np
import pytest

from repro.core import FunkyCL, Monitor, SliceAllocator
from repro.scaling.autoscaler import M_PREFIX_HIT_RATE
from repro.scaling.metrics import MetricsRegistry
from repro.serve.engine import ContinuousBatchingEngine, ServeRequest
from repro.serve.equivalence import (assert_transcripts_equal,
                                     evict_resume_every, run_transcript)
from repro.serve.kvcache import BlockPool
from repro.serve.prefix_cache import PrefixCache

ARCH = "yi-9b-smoke"
PROMPT_LEN = 8
PAGE = 4
BUCKET = PROMPT_LEN


# ---------------------------------------------------------------------------
# Tree units (no model, bare pool)
# ---------------------------------------------------------------------------
def _tree(pages=16, max_nodes=64):
    pool = BlockPool(pages, PAGE)
    return pool, PrefixCache(pool, PAGE, max_nodes=max_nodes)


def _toks(*pages):
    """Page-key shorthand: _toks(1, 2) -> [1]*PAGE + [2]*PAGE."""
    out = []
    for p in pages:
        out.extend([p] * PAGE)
    return out


def test_match_walks_longest_prefix():
    pool, tree = _tree()
    ids = pool.alloc(3)
    tree.insert(BUCKET, _toks(1, 2, 3), ids, next_token=77)
    m = tree.match(BUCKET, _toks(1, 2, 3))
    assert m.pages == ids and m.tokens == 3 * PAGE and m.next_token == 77
    # internal next_token hints come from the following page's first token
    m2 = tree.match(BUCKET, _toks(1, 2))
    assert m2.pages == ids[:2] and m2.next_token == 3
    # divergence stops the walk; nothing matched is still a valid result
    m3 = tree.match(BUCKET, _toks(1, 9))
    assert m3.pages == ids[:1] and m3.next_token is None
    assert tree.match(BUCKET, _toks(8)).pages == []
    assert tree.match(BUCKET + PAGE, _toks(1)).pages == []   # per-bucket
    tree.check_invariants()


def test_non_page_aligned_tokens_rejected():
    _, tree = _tree()
    with pytest.raises(ValueError):
        tree.match(BUCKET, [1, 2, 3])


def test_insert_pins_pages_and_dedups():
    pool, tree = _tree()
    a = pool.alloc(2)
    assert tree.insert(BUCKET, _toks(1, 2), a) == 2
    assert pool.refcount(a[0]) == 2             # caller's ref + tree's ref
    # same token content under different physical pages: existing node
    # wins, the duplicate copy is NOT pinned by the tree
    b = pool.alloc(2)
    assert tree.insert(BUCKET, _toks(1, 2), b) == 0
    assert pool.refcount(b[0]) == 1
    assert tree.match(BUCKET, _toks(1, 2)).pages == a
    # a retiring owner frees its refs; the tree's copy survives
    assert pool.free(a) == []
    assert pool.refcount(a[0]) == 1
    tree.check_invariants()


def test_evict_lru_respects_refcounts_and_cascades():
    pool, tree = _tree()
    cold = pool.alloc(2)
    tree.insert(BUCKET, _toks(1, 2), cold)
    pool.free(cold)                             # tree-only: evictable
    hot = pool.alloc(1)
    tree.insert(BUCKET, _toks(5), hot)          # lane still holds its ref
    tree.match(BUCKET, _toks(5))                # and it is the most recent
    # reclaim: the cold chain cascades leaf -> parent; the lane-held page
    # is never freed out from under its owner
    assert tree.evict_pages(3) == 2
    assert tree.match(BUCKET, _toks(1)).pages == []
    assert pool.refcount(hot[0]) == 2
    assert tree.nodes == 1
    assert tree.reclaimable_pages() == 0        # hot page is lane-shared
    tree.check_invariants()


def test_match_len_probe_does_not_bump_recency():
    pool, tree = _tree()
    a = pool.alloc(1)
    tree.insert(BUCKET, _toks(1), a)
    b = pool.alloc(1)
    tree.insert(BUCKET, _toks(2), b)            # b is now more recent
    pool.free(a)
    pool.free(b)
    assert tree.match_len(BUCKET, _toks(1)) == PAGE      # router probe
    assert tree.match_len(BUCKET, _toks(1, 2)) == PAGE   # unaligned tail ok
    tree.evict_pages(1)
    # the probe did not refresh a: LRU still evicts it first
    assert tree.match(BUCKET, _toks(1)).pages == []
    assert tree.match(BUCKET, _toks(2)).pages == b


def test_max_nodes_overflow_evicts():
    pool, tree = _tree(pages=16, max_nodes=2)
    for i in range(4):
        ids = pool.alloc(1)
        tree.insert(BUCKET, _toks(10 + i), ids)
        pool.free(ids)
    assert tree.nodes <= 2
    assert tree.stats()["evicted_nodes"] >= 2
    tree.check_invariants()


def test_remap_follows_pool_compaction():
    pool, tree = _tree()
    a = pool.alloc(4)
    tree.insert(BUCKET, _toks(1, 2), [a[1], a[3]])
    pool.free([a[0], a[2]])                     # owners of a1/a3 retire too
    pool.free([a[1], a[3]])
    mapping = pool.compact()
    tree.remap(mapping)
    m = tree.match(BUCKET, _toks(1, 2))
    assert m.pages == [mapping.get(a[1], a[1]), mapping.get(a[3], a[3])]
    tree.check_invariants()
    pool.check_invariants()


# ---------------------------------------------------------------------------
# Engine integration: bit-exactness, COW, eviction pressure, gauges
# ---------------------------------------------------------------------------
def _factory(slots=2, max_new=6, pool_pages=None, **kw):
    def make():
        reg = MetricsRegistry()
        mon = Monitor("pfx-test", SliceAllocator("n0", 1), telemetry=reg)
        eng = ContinuousBatchingEngine(
            ARCH, FunkyCL(mon), slots=slots, prompt_len=PROMPT_LEN,
            max_new_tokens=max_new, registry=reg, page_size=PAGE,
            pool_pages=pool_pages, prefix_cache=True, **kw)
        eng.setup()
        return mon, eng
    return make


def _prompts(n_distinct, seed=3):
    rng = np.random.Generator(np.random.Philox(seed))
    return [rng.integers(0, 100, PROMPT_LEN) for _ in range(n_distinct)]


def _requests(prompts, tokens):
    def make():
        return [ServeRequest(rid=f"r{i}", prompt=p, max_new_tokens=n)
                for i, (p, n) in enumerate(zip(prompts, tokens))]
    return make


@pytest.fixture(scope="module")
def warm_run():
    """Reference run: two repeats of one prompt plus a distinct one, on a
    prefix-cache engine with ample pages."""
    p = _prompts(2)
    prompts, tokens = [p[0], p[0], p[1]], [4, 6, 4]
    transcript, eng = run_transcript(_factory(), _requests(prompts, tokens))
    return transcript, eng, prompts, tokens


def test_prefix_hit_bit_exact_vs_cold(warm_run):
    """The tentpole gate: a full prefix hit streams exactly the tokens a
    cold admission of the same prompt produces."""
    transcript, eng, prompts, _ = warm_run
    assert eng.prefix_stats()["hits"] >= 1
    # r1 was a full hit on r0's pages; same prompt -> same greedy stream
    assert transcript["r1"][:4] == transcript["r0"]
    # and vs a genuinely cold engine (no tree at all yet), r1 alone:
    cold, _ = run_transcript(_factory(),
                             _requests([prompts[0]], [6]))
    assert_transcripts_equal({"r0": cold["r0"]}, {"r0": transcript["r1"]},
                             context="prefix hit vs cold")


def test_prefix_hit_bit_exact_across_evict_resume(warm_run):
    """Monitor-level evict/resume mid-run (dirty-page checkpoint of the
    shared pool included) must not perturb hit-path tokens."""
    transcript, _, prompts, tokens = warm_run
    perturbed, eng = run_transcript(_factory(),
                                    _requests(prompts, tokens),
                                    step_hook=evict_resume_every(2))
    assert_transcripts_equal(perturbed, transcript,
                             context="prefix + evict/resume")
    assert eng.prefix_stats()["hits"] >= 1


def test_prefix_hit_bit_exact_under_oom_preemption(warm_run):
    """A pool sized to force OOM preemption mid-shared-prefix: preempted
    lanes drop their shared refs, recompute re-admits via the tree, and
    every stream stays bit-exact."""
    transcript, _, prompts, tokens = warm_run
    # 2 distinct prompts (4 pages, shared) + 3 concurrent lanes' private
    # generation pages overflow a 6-page pool at the first appends
    squeezed, eng = run_transcript(
        _factory(slots=3, pool_pages=6), _requests(prompts, tokens))
    assert eng.preemptions > 0, "pool was not tight enough to preempt"
    assert_transcripts_equal(squeezed, transcript,
                             context="prefix + OOM preemption")
    eng.pool.check_invariants()
    eng.prefix.check_invariants()


def test_cow_on_write_to_shared_page():
    """A divergent write into a page another owner still references must
    copy first: the writer gets a private page, the shared copy and the
    other owner's view survive untouched, and tokens never change."""
    p = _prompts(1)
    ref, _ = run_transcript(_factory(), _requests(p, [6]))

    make = _factory()
    mon, eng = make()
    try:
        eng.submit(ServeRequest(rid="r0", prompt=p[0], max_new_tokens=6))
        eng.step()                      # admit: writes prompt + 1st token
        eng.step()                      # first append: tail page exists
        st = next(iter(eng._active.values()))
        tail = st.blocks[-1]
        eng.pool.share([tail])          # simulate another owner pinning it
        while not eng.idle:
            eng.step()
        assert eng.cow_copies >= 1
        assert tail not in st.blocks    # writer moved to a private copy
        assert eng.pool.refcount(tail) == 1     # our pin still holds
        eng.pool.free([tail])
        assert_transcripts_equal(
            {rid: list(r.tokens) for rid, r in eng.completed.items()},
            ref, context="COW")
        eng.pool.check_invariants()
    finally:
        mon.vfpga_exit()


def test_tree_evicted_under_admission_pressure():
    """Cold tree pages are reclaimed (LRU) before admission fails: many
    distinct prompts through a small pool all complete, and the tree
    reports evictions."""
    prompts = _prompts(6, seed=9)
    transcript, eng = run_transcript(
        _factory(slots=2, pool_pages=10), _requests(prompts, [3] * 6))
    assert len(transcript) == 6
    assert eng.prefix_stats()["evicted_pages"] > 0
    eng.pool.check_invariants()
    eng.prefix.check_invariants()


def test_hit_rate_gauge_published(warm_run):
    _, eng, _, _ = warm_run
    stats = eng.prefix_stats()
    assert stats["hit_rate"] > 0
    val = eng.registry.gauge(M_PREFIX_HIT_RATE, service="svc",
                             engine=eng.engine_id).value
    assert val == pytest.approx(stats["cached_tokens"]
                                / stats["prompt_tokens"])


def test_retire_donates_generated_pages(warm_run):
    """Retirement feeds committed pages (prompt + generation) back into
    the tree, so the cache warms from served traffic, not just prompts."""
    _, eng, _, _ = warm_run
    # r0: 8 prompt + 4 generated tokens = 3 complete pages in the tree
    assert eng.prefix.nodes >= 3


def test_prefix_cache_requires_paged_aligned_buckets():
    reg = MetricsRegistry()
    mon = Monitor("pfx-bad", SliceAllocator("n0", 1), telemetry=reg)
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(ARCH, FunkyCL(mon), slots=2,
                                 prompt_len=PROMPT_LEN, max_new_tokens=4,
                                 registry=reg, paged=False,
                                 prefix_cache=True)
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(ARCH, FunkyCL(mon), slots=2,
                                 prompt_len=6, max_new_tokens=4,
                                 registry=reg, page_size=PAGE,
                                 prefix_cache=True)
    mon.vfpga_exit()


def test_spec_decode_composes_with_prefix_cache():
    """Speculative decode on a prefix-cache engine: hits still happen and
    the stream matches the plain prefix-cache engine bit-exactly."""
    from repro.serve.engine import SpecConfig

    p = _prompts(2, seed=13)
    prompts, tokens = [p[0], p[0], p[1]], [4, 4, 4]
    plain, _ = run_transcript(_factory(), _requests(prompts, tokens))
    spec, eng = run_transcript(_factory(spec=SpecConfig(k=2)),
                               _requests(prompts, tokens))
    assert_transcripts_equal(spec, plain, context="spec + prefix")
    assert eng.prefix_stats()["hits"] >= 1


def test_cow_on_shared_page_inside_fused_span():
    """Fused multi-step decode writes a k-token window per EXECUTE; a
    shared page anywhere in that window must be copied before the fused
    program launches, and the stream must match the single-step run."""
    p = _prompts(1)
    ref, _ = run_transcript(_factory(), _requests(p, [6]))

    # k=3: after admit + one fused span pos sits mid-page, so the next
    # span's write window starts inside the already-mapped tail page
    make = _factory(fuse_steps=3)
    mon, eng = make()
    try:
        eng.submit(ServeRequest(rid="r0", prompt=p[0], max_new_tokens=6))
        eng.step()                      # admit + first fused span commits
        st = next(iter(eng._active.values()))
        tail = st.blocks[-1]
        eng.pool.share([tail])          # simulate another owner pinning it
        while not eng.idle:
            eng.step()
        assert eng.cow_copies >= 1
        assert tail not in st.blocks    # writer moved to a private copy
        assert eng.pool.refcount(tail) == 1     # our pin still holds
        eng.pool.free([tail])
        assert_transcripts_equal(
            {rid: list(r.tokens) for rid, r in eng.completed.items()},
            ref, context="COW in fused span")
        eng.pool.check_invariants()
    finally:
        mon.vfpga_exit()


def test_prefix_hits_bit_exact_with_fused_pipeline():
    """Prefix-cache hits compose with fused + pipelined decode: same
    tokens as the single-step prefix engine, hits still counted."""
    p = _prompts(2, seed=13)
    prompts, tokens = [p[0], p[0], p[1]], [6, 6, 4]
    plain, _ = run_transcript(_factory(), _requests(prompts, tokens))
    fused, eng = run_transcript(_factory(fuse_steps=4, async_depth=1),
                                _requests(prompts, tokens))
    assert_transcripts_equal(fused, plain, context="prefix + fused")
    assert eng.prefix_stats()["hits"] >= 1
    assert eng.bt_delta_execs > 0
