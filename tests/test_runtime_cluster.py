"""Integration: full orchestration stack on a live in-process cluster."""

import time

import pytest

from repro.core import (Policy, TaskImage, TaskStatus, make_cluster)

IMAGES = {
    "train-small": TaskImage(name="train-small", kind="train",
                             arch="yi-9b-smoke", seq_len=16, global_batch=4,
                             total_steps=15, chunks=2),
    "serve-small": TaskImage(name="serve-small", kind="serve",
                             arch="yi-9b-smoke", prompt_len=8, global_batch=2,
                             total_steps=10, tokens_per_step=2),
}


@pytest.fixture(scope="module")
def cluster():
    cl = make_cluster(num_nodes=2, slices_per_node=1, images=IMAGES,
                      policy=Policy.PRE_MG)
    yield cl
    cl.stop()


def test_orchestrated_deploy_to_done(cluster):
    orch = cluster.orchestrator
    orch.start(tick_interval=0.01)
    orch.submit("train-small", priority=0)
    orch.submit("serve-small", priority=1)
    assert orch.wait_all(timeout=600)
    for cid, d in orch.deployments.items():
        assert d.status == "done", (cid, d.status)


def test_evict_migrate_checkpoint_restore(cluster):
    rt0 = cluster.nodes["node0"].runtime
    rt1 = cluster.nodes["node1"].runtime
    img = IMAGES["train-small"]

    rt0.create("m1", img)
    rt0.start("m1")
    stats = rt0.evict("m1")
    assert stats["n_dirty"] >= 1
    assert rt0.status("m1") == TaskStatus.EVICTED
    # migrate to node1 and finish there
    rt1.resume("m1", source=rt0)
    assert rt1.wait("m1", timeout=600) == TaskStatus.DONE
    assert rt1.tasks["m1"].guest_state.step == img.total_steps

    # checkpoint -> kill -> restore elsewhere
    rt0.create("c1", img)
    rt0.start("c1")
    path = rt0.checkpoint("c1")
    rt0.kill("c1")
    rt1.restore("c2", path)
    assert rt1.wait("c2", timeout=600) == TaskStatus.DONE


def test_replicate_horizontal_scaling(cluster):
    rt0 = cluster.nodes["node0"].runtime
    rt1 = cluster.nodes["node1"].runtime
    img = IMAGES["serve-small"]
    rt0.create("s1", img)
    rt0.start("s1")
    new_cid = rt0.replicate("s1", rt1, new_cid="s1-rep")
    assert rt1.wait(new_cid, timeout=600) == TaskStatus.DONE
    assert rt0.wait("s1", timeout=600) == TaskStatus.DONE


def test_vertical_scaling_update(cluster):
    rt0 = cluster.nodes["node0"].runtime
    img = IMAGES["serve-small"]
    rt0.create("v1", img)
    rt0.start("v1")
    rt0.update("v1", vfpga_num=2)
    assert rt0.tasks["v1"].vfpga_num == 2
    assert rt0.wait("v1", timeout=600) == TaskStatus.DONE


def test_node_failure_recovery():
    cl = make_cluster(num_nodes=2, slices_per_node=1, images=IMAGES,
                      policy=Policy.PRE_MG)
    orch = cl.orchestrator
    orch.start(tick_interval=0.01)
    cid = orch.submit("train-small")
    # wait until it runs on some node, checkpoint it, then kill the node
    deadline = time.time() + 300
    node = None
    while time.time() < deadline:
        st = orch._sched_tasks[cid]
        if st.node_id is not None and \
                orch.deployments[cid].status == "running":
            node = st.node_id
            break
        time.sleep(0.02)
    assert node is not None
    try:
        orch.checkpoint(cid)
    except Exception:
        pass  # task may have finished already; failure path still exercised
    orch.handle_node_failure(node)
    assert orch.wait_all(timeout=600)
    assert orch.deployments[cid].status == "done"
    cl.stop()


def test_preemption_priority_end_to_end():
    """High-priority task evicts a low-priority one on a 1-slot cluster."""
    images = {
        "long": TaskImage(name="long", kind="train", arch="yi-9b-smoke",
                          seq_len=16, global_batch=4, total_steps=30,
                          chunks=1),
        "short": TaskImage(name="short", kind="train", arch="yi-9b-smoke",
                           seq_len=16, global_batch=4, total_steps=2,
                           chunks=1),
    }
    cl = make_cluster(num_nodes=1, slices_per_node=1, images=images,
                      policy=Policy.PRE_EV)
    orch = cl.orchestrator
    orch.start(tick_interval=0.01)
    low = orch.submit("long", priority=0)
    time.sleep(1.5)                      # let it occupy the slot
    high = orch.submit("short", priority=5)
    assert orch.wait_all(timeout=900)
    events = [e for _, e, kw in orch.events]
    assert "evict" in events, events     # the low task was preempted
    assert orch.deployments[low].status == "done"
    assert orch.deployments[high].status == "done"
    cl.stop()
