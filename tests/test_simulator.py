"""Trace-driven simulator (paper §5.6): trends must reproduce Figs 11-13."""

import pytest

from repro.core.scheduler import Policy
from repro.core.simulator import SimParams, Simulator
from repro.core.traces import generate_trace


def test_trace_generation_deterministic():
    a = generate_trace(n_jobs=50, seed=4)
    b = generate_trace(n_jobs=50, seed=4)
    c = generate_trace(n_jobs=50, seed=5)
    assert [j.duration for j in a] == [j.duration for j in b]
    assert [j.duration for j in a] != [j.duration for j in c]
    assert all(30.0 <= j.duration <= 3 * 3600 for j in a)
    assert all(j.memory_bytes <= 8 << 30 for j in a)


def test_fig11_throughput_scales_with_slices_and_acceleration():
    jobs = generate_trace(n_jobs=200, horizon_s=2 * 3600, seed=1)
    thr = {}
    for n in (2, 8, 32):
        r = Simulator(jobs, num_nodes=n, policy=Policy.NO_PRE,
                      params=SimParams(acceleration_rate=1.0)).run()
        assert r["completed"] == 200
        thr[n] = r["throughput_per_min"]
    assert thr[8] > thr[2]
    lat = {}
    for rate in (0.0, 1.0):
        r = Simulator(jobs, num_nodes=8, policy=Policy.NO_PRE,
                      params=SimParams(acceleration_rate=rate)).run()
        lat[rate] = r["mean_latency_s"]
    assert lat[1.0] < lat[0.0]          # acceleration helps (paper: 1.6x)


def test_fig13_preemption_helps_high_priority():
    jobs = generate_trace(n_jobs=150, horizon_s=3600, seed=2)
    res = {}
    for pol in (Policy.NO_PRE, Policy.PRE_EV, Policy.PRE_MG):
        r = Simulator(jobs, num_nodes=6, policy=pol).run()
        assert r["completed"] == 150
        res[pol] = r
    hi = max(res[Policy.NO_PRE]["latency_by_priority"])
    assert res[Policy.PRE_EV]["latency_by_priority"][hi] <= \
        res[Policy.NO_PRE]["latency_by_priority"][hi] * 1.02
    assert res[Policy.PRE_EV]["evictions"] > 0
    assert res[Policy.PRE_MG]["migrations"] > 0


def test_fig12_checkpointing_recovers_failures():
    jobs = generate_trace(n_jobs=120, horizon_s=2 * 3600, seed=3,
                          with_failures=True)
    execs = {}
    for ck in (None, 60.0):
        r = Simulator(jobs, num_nodes=16, policy=Policy.NO_PRE,
                      params=SimParams(checkpoint_interval_s=ck)).run()
        assert r["completed"] == 120
        execs[ck] = r["mean_exec_s"]
    assert execs[60.0] < execs[None]    # snapshots recover lost work


def test_fig12_checkpoint_overhead_without_failures():
    jobs = generate_trace(n_jobs=80, horizon_s=3600, seed=6,
                          with_failures=False)
    base = Simulator(jobs, num_nodes=16, policy=Policy.NO_PRE,
                     params=SimParams()).run()
    freq = Simulator(jobs, num_nodes=16, policy=Policy.NO_PRE,
                     params=SimParams(checkpoint_interval_s=15.0)).run()
    assert freq["mean_exec_s"] >= base["mean_exec_s"]   # pure overhead


def test_simulation_conserves_jobs():
    jobs = generate_trace(n_jobs=77, horizon_s=1800, seed=9,
                          with_failures=True)
    r = Simulator(jobs, num_nodes=4, policy=Policy.PRE_MG,
                  params=SimParams(checkpoint_interval_s=120.0)).run()
    assert r["completed"] == 77
