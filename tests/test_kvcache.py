"""Paged KV subsystem: BlockPool allocator invariants (hypothesis-backed),
pool pytree construction, and the traced gather/scatter/scrub helpers the
engine's kernels are built from."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.state import BufferState, BufferTable, tree_bytes
from repro.models.attention import _INVALID_POS
from repro.serve.kvcache import (BlockPool, BlockPoolError, cache_bytes,
                                 gather_lane_cache, pool_specs_from_lane_cache,
                                 scatter_pages, scatter_prefill, scrub_pages,
                                 token_axes_from_lengths)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, invariant,
                                     precondition, rule)
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# BlockPool
# ---------------------------------------------------------------------------
def test_alloc_is_deterministic_lowest_first():
    pool = BlockPool(8, 4)
    assert pool.alloc(3) == [0, 1, 2]
    pool.free([1])
    assert pool.alloc(2) == [1, 3]      # freed low id reused first


def test_watermark_blocks_normal_but_not_urgent_alloc():
    pool = BlockPool(4, 4, reserve_pages=2)
    assert pool.can_admit(2) and not pool.can_admit(3)
    assert pool.alloc(3) is None        # would breach the reserve
    assert pool.alloc(2) == [0, 1]
    assert pool.alloc(1) is None        # reserve protects the last 2
    assert pool.alloc(1, urgent=True) == [2]   # append path may dip in
    assert pool.alloc(2, urgent=True) is None  # but never over-allocates


def test_double_free_raises():
    pool = BlockPool(4, 4)
    ids = pool.alloc(2)
    pool.free(ids)
    with pytest.raises(BlockPoolError):
        pool.free([ids[0]])


def test_compact_packs_used_pages_low():
    pool = BlockPool(8, 4)
    a = pool.alloc(6)
    pool.free([a[0], a[2], a[4]])       # used = {1, 3, 5}
    mapping = pool.compact()
    assert set(mapping) == {3, 5} and set(mapping.values()) == {0, 2}
    assert pool.used_span() == 3        # {0, 1, 2}
    pool.check_invariants()
    # every page still allocatable exactly once
    assert sorted(pool.alloc(5)) == [3, 4, 5, 6, 7]
    assert pool.alloc(1) is None


def test_pages_for_tokens_and_occupancy():
    pool = BlockPool(10, 4)
    assert pool.pages_for_tokens(1) == 1
    assert pool.pages_for_tokens(4) == 1
    assert pool.pages_for_tokens(5) == 2
    pool.alloc(5)
    assert pool.occupancy() == 0.5 and pool.free_count() == 5


def test_share_refcounts_and_symmetric_free():
    """Prefix-cache sharing: ``share`` adds references, ``free`` removes
    one, and a page only returns to the free heap at refcount zero."""
    pool = BlockPool(8, 4)
    a = pool.alloc(2)
    pool.share([a[0]])
    assert pool.refcount(a[0]) == 2 and pool.refcount(a[1]) == 1
    assert pool.shared_count() == 1
    assert pool.free(a) == [a[1]]       # shared page survives its owner
    assert pool.refcount(a[0]) == 1
    pool.check_invariants()
    assert pool.free([a[0]]) == [a[0]]  # last reference actually frees
    with pytest.raises(BlockPoolError):
        pool.share([a[0]])              # cannot share a free page
    pool.check_invariants()


def test_free_tail_unshares_shared_tail():
    """Speculative rollback over a shared tail page must not free it out
    from under the other owner — the reference drops, the page stays."""
    pool = BlockPool(8, 4)
    blocks = pool.alloc(4)
    pool.share([blocks[3]])
    freed = pool.free_tail(blocks, 2)
    assert freed == [blocks[2]]         # shared page survives the rollback
    assert pool.refcount(blocks[3]) == 1
    pool.check_invariants()
    assert pool.free([blocks[3]]) == [blocks[3]]


def test_compact_moves_refcounts_with_pages():
    pool = BlockPool(8, 4)
    a = pool.alloc(4)
    pool.share([a[3]])
    pool.free([a[0], a[1]])
    mapping = pool.compact()
    assert pool.refcount(mapping.get(a[3], a[3])) == 2
    assert pool.shared_count() == 1
    pool.check_invariants()


def test_free_tail_releases_only_the_orphaned_suffix():
    """The speculative-rollback primitive: only the pages past ``keep`` go
    back to the pool, and they are returned for event accounting."""
    pool = BlockPool(10, 4)
    blocks = pool.alloc(5)
    freed = pool.free_tail(blocks, 2)
    assert freed == blocks[2:]
    assert pool._used == set(blocks[:2])
    pool.check_invariants()
    assert pool.free_tail(blocks[:2], 2) == []      # nothing past keep
    with pytest.raises(ValueError):
        pool.free_tail(blocks[:2], -1)
    with pytest.raises(BlockPoolError):             # already freed
        pool.free_tail(blocks, 2)


if HAS_HYPOTHESIS:
    class PoolMachine(RuleBasedStateMachine):
        """Random alloc/share/free/free_tail/compact sequences preserve the
        partition invariant (free ∪ used = all pages, disjoint), ownership
        (a live page is never re-allocated), and refcount semantics: a
        page with references outstanding is never freed (so it can never
        be scrubbed or handed to another owner), and compaction moves
        reference counts with their pages."""

        def __init__(self):
            super().__init__()
            self.pool = BlockPool(16, 4, reserve_pages=2)
            self.owned = {}             # owner -> ordered page list
            self.rc = {}                # page -> model refcount
            self.next_owner = 0

        def _drop_ref(self, p):
            self.rc[p] -= 1
            if self.rc[p] == 0:
                del self.rc[p]
                return True
            return False

        @rule(n=st.integers(1, 5), urgent=st.booleans())
        def alloc(self, n, urgent):
            got = self.pool.alloc(n, urgent=urgent)
            if got is not None:
                assert not (set(got) & set(self.rc)), \
                    "live page re-allocated"
                self.owned[self.next_owner] = list(got)
                for p in got:
                    self.rc[p] = 1
                self.next_owner += 1

        @precondition(lambda self: self.rc)
        @rule(data=st.data())
        def share_one(self, data):
            """A prefix-tree node (or second lane) pins a live page."""
            p = data.draw(st.sampled_from(sorted(self.rc)))
            self.pool.share([p])
            self.rc[p] += 1

        @precondition(lambda self: any(c > 1 for c in self.rc.values()))
        @rule(data=st.data())
        def unshare_one(self, data):
            """Dropping one of several references never frees the page."""
            p = data.draw(st.sampled_from(
                sorted(q for q, c in self.rc.items() if c > 1)))
            assert self.pool.free([p]) == []
            self._drop_ref(p)

        @precondition(lambda self: self.owned)
        @rule(data=st.data())
        def free_owner(self, data):
            """A retiring owner frees exactly its unshared pages."""
            owner = data.draw(st.sampled_from(sorted(self.owned)))
            pages = sorted(self.owned.pop(owner))
            freed = self.pool.free(pages)
            assert freed == [p for p in pages if self._drop_ref(p)]

        @precondition(lambda self: self.owned)
        @rule(data=st.data())
        def rollback_tail(self, data):
            """Speculative rollback: ``free_tail`` on a shared tail page
            unshares it — the surviving owner keeps its copy."""
            owner = data.draw(st.sampled_from(sorted(self.owned)))
            blocks = self.owned[owner]
            keep = data.draw(st.integers(0, len(blocks)))
            freed = self.pool.free_tail(blocks, keep)
            assert freed == [p for p in blocks[keep:]
                             if self._drop_ref(p)]
            self.owned[owner] = blocks[:keep]
            if not self.owned[owner]:
                del self.owned[owner]

        @rule()
        def compact(self):
            mapping = self.pool.compact()
            for owner, pages in self.owned.items():
                self.owned[owner] = [mapping.get(p, p) for p in pages]
            self.rc = {mapping.get(p, p): c for p, c in self.rc.items()}

        @invariant()
        def partition_holds(self):
            self.pool.check_invariants()
            assert set(self.rc) == self.pool._used
            for p, c in self.rc.items():
                assert self.pool.refcount(p) == c
            assert self.pool.free_count() == 16 - len(self.rc)

    TestPoolMachine = PoolMachine.TestCase
    TestPoolMachine.settings = settings(max_examples=30,
                                        deadline=None)

    @settings(max_examples=60, deadline=None)
    @given(data=st.data())
    def test_free_tail_property(data):
        """Rollback frees exactly the orphaned tail: after interleaved
        allocations, ``free_tail(blocks, keep)`` leaves precisely the kept
        prefixes owned and the pool partition invariant intact."""
        pool = BlockPool(16, 4, reserve_pages=2)
        owners = []
        for _ in range(data.draw(st.integers(1, 4))):
            n = data.draw(st.integers(1, 4))
            got = pool.alloc(n, urgent=True)
            if got is not None:
                owners.append(got)
        kept = []
        for blocks in owners:
            keep = data.draw(st.integers(0, len(blocks)))
            freed = pool.free_tail(blocks, keep)
            assert freed == blocks[keep:]
            kept.extend(blocks[:keep])
        pool.check_invariants()
        assert pool._used == set(kept)
        assert pool.free_count() == 16 - len(kept)


# ---------------------------------------------------------------------------
# Pool pytree construction + traced helpers (no model needed)
# ---------------------------------------------------------------------------
PS = 4          # page size
NP_ = 6         # pool pages
MB = 3          # max blocks per lane


def _lane_cache(cap, layers=2, heads=2, hd=3):
    """Stacked-scan-style lane cache like the transformer backbone's."""
    return {
        "k": jnp.arange(layers * cap * heads * hd, dtype=jnp.float32
                        ).reshape(layers, 1, cap, heads, hd),
        "v": jnp.ones((layers, 1, cap, heads, hd), jnp.float32),
        "kv_pos": jnp.tile(jnp.arange(cap, dtype=jnp.int32), (layers, 1)),
    }


def _abs(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


@pytest.fixture(scope="module")
def axes():
    return token_axes_from_lengths(_abs(_lane_cache(5)),
                                   _abs(_lane_cache(8)), 5, 8)


def test_token_axes_discovery(axes):
    assert axes["k"] == 2 and axes["v"] == 2 and axes["kv_pos"] == 1


def test_token_axes_rejects_ring_caches():
    # a window-bounded ring cache keeps its shape across prompt lengths
    ring = {"k": jax.ShapeDtypeStruct((1, 4, 2, 3), jnp.float32)}
    with pytest.raises(ValueError):
        token_axes_from_lengths(ring, ring, 5, 8)


def test_token_axes_delta_mode_for_margined_caches():
    """exact=False matches on axis-size *delta* — the speculative-decode
    draft lane, whose capacity is prompt_len + a constant margin."""
    margin = 6
    a, b = _abs(_lane_cache(5 + margin)), _abs(_lane_cache(8 + margin))
    with pytest.raises(ValueError):
        token_axes_from_lengths(a, b, 5, 8)          # sizes are P + margin
    axes = token_axes_from_lengths(a, b, 5, 8, exact=False)
    assert axes["k"] == 2 and axes["kv_pos"] == 1
    with pytest.raises(ValueError):                  # delta must still match
        token_axes_from_lengths(a, b, 5, 9, exact=False)


def test_pool_specs_shapes(axes):
    pool = pool_specs_from_lane_cache(_abs(_lane_cache(8)), axes, NP_, PS)
    assert pool["k"].shape == (NP_, PS, 2, 1, 2, 3)
    assert pool["kv_pos"].shape == (NP_, PS, 2)
    # byte accounting goes through the one shared helper
    assert cache_bytes(pool) == tree_bytes(pool)


def test_prefill_scatter_gather_roundtrip(axes):
    """scatter_prefill + gather through the block table reassembles the
    lane cache exactly, INVALID-pads the tail, and masks unmapped pages."""
    cap = 5                              # ragged: 2 pages, 3 slots padding
    lane = _lane_cache(cap)
    pool_abs = pool_specs_from_lane_cache(_abs(_lane_cache(MB * PS)), axes,
                                          NP_, PS)
    pool = jax.tree_util.tree_map_with_path(
        lambda p, l: (jnp.full(l.shape, _INVALID_POS, jnp.int32)
                      if p[-1].key == "kv_pos"
                      else jnp.full(l.shape, 99.0, l.dtype)), pool_abs)
    page_ids = jnp.asarray([4, 1], jnp.int32)   # non-contiguous on purpose
    pool = scatter_prefill(pool, page_ids, lane, axes, page_size=PS,
                           prompt_len=cap)
    block_row = jnp.asarray([4, 1, -1], jnp.int32)
    got = gather_lane_cache(pool, block_row, axes, page_size=PS)
    L = MB * PS
    assert got["k"].shape == (2, 1, L, 2, 3)
    np.testing.assert_array_equal(np.asarray(got["k"][:, :, :cap]),
                                  np.asarray(lane["k"]))
    np.testing.assert_array_equal(np.asarray(got["kv_pos"][:, :cap]),
                                  np.asarray(lane["kv_pos"]))
    # tail of the last mapped page and the whole unmapped page: INVALID
    assert (np.asarray(got["kv_pos"][:, cap:]) == _INVALID_POS).all()


def test_scrub_invalidates_only_positions(axes):
    pool_abs = pool_specs_from_lane_cache(_abs(_lane_cache(MB * PS)), axes,
                                          NP_, PS)
    pool = jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), pool_abs)
    ids = jnp.asarray([2, NP_], jnp.int32)       # NP_ = padding, dropped
    out = scrub_pages(pool, ids)
    assert (np.asarray(out["kv_pos"][2]) == _INVALID_POS).all()
    assert (np.asarray(out["kv_pos"][3]) == 0).all()
    assert (np.asarray(out["k"]) == 0).all()     # k/v untouched


def test_scatter_pages_drops_inactive_lanes(axes):
    pool_abs = pool_specs_from_lane_cache(_abs(_lane_cache(MB * PS)), axes,
                                          NP_, PS)
    pool = jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), pool_abs)
    pages = jax.tree.map(
        lambda l: jnp.ones((2,) + l.shape[1:], l.dtype), pool_abs)
    phys = jnp.asarray([3, NP_], jnp.int32)      # lane 1 inactive -> drop
    out = scatter_pages(pool, phys, pages)
    assert (np.asarray(out["k"][3]) == 1).all()
    assert (np.asarray(out["k"][:3]) == 0).all()
    assert (np.asarray(out["k"][4:]) == 0).all()


# ---------------------------------------------------------------------------
# Page-granular dirtiness in the buffer state machine
# ---------------------------------------------------------------------------
def _pool_value(n_pages=4, ps=2):
    return {"k": jnp.arange(n_pages * ps * 3, dtype=jnp.float32
                            ).reshape(n_pages, ps, 3),
            "kv_pos": jnp.zeros((n_pages, ps), jnp.int32)}


def test_paged_buffer_evicts_only_dirty_pages():
    table = BufferTable()
    val = _pool_value()
    table.register("pool", jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), val), paged=True)
    table.on_execute_write("pool", val)          # no dirty_pages: all dirty
    s1 = table.evict_device_state()
    assert s1["paged_saved_pages"] == 4 and s1["paged_total_pages"] == 4
    table.restore_device_state()

    new = jax.tree.map(lambda x: x + (x + 1) * 0, val)   # same values
    new["k"] = new["k"].at[2].set(-1.0)
    table.on_execute_write("pool", new, stable=True, dirty_pages=[2])
    s2 = table.evict_device_state()
    assert s2["paged_saved_pages"] == 1
    assert s2["saved_bytes"] == tree_bytes(val) // 4
    # the merged host copy is bit-exact: clean pages from the old copy,
    # dirty page from the device
    b = table.get("pool")
    np.testing.assert_array_equal(b.host_value["k"][2], np.full((2, 3), -1.))
    np.testing.assert_array_equal(b.host_value["k"][0],
                                  np.asarray(val["k"][0]))
    assert b.state is BufferState.SYNC


def test_paged_buffer_degrades_without_page_info():
    table = BufferTable()
    val = _pool_value()
    table.register("pool", jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), val), paged=True)
    table.on_execute_write("pool", val, dirty_pages=[0])
    table.evict_device_state()
    table.restore_device_state()
    table.on_execute_write("pool", val, stable=True)     # unknown pages
    s = table.evict_device_state()
    assert s["paged_saved_pages"] == 4                   # conservative


def test_snapshot_not_corrupted_by_later_dirty_merge():
    """host_snapshot aliases the live host copies; a later dirty-page
    merge must copy-on-write instead of patching the snapshot's arrays."""
    table = BufferTable()
    val = _pool_value()
    table.register("pool", jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), val), paged=True)
    table.on_execute_write("pool", val)
    table.on_d2h("pool")                         # host copy current
    snap = table.host_snapshot()                 # checkpoint view (aliased)
    before = np.asarray(snap["pool"]["k"][1]).copy()

    new = jax.tree.map(lambda x: x, val)
    new["k"] = new["k"].at[1].set(-7.0)
    table.on_execute_write("pool", new, stable=True, dirty_pages=[1])
    table.on_d2h("pool")                         # merge: must not hit snap
    np.testing.assert_array_equal(np.asarray(snap["pool"]["k"][1]), before)
    np.testing.assert_array_equal(
        table.get("pool").host_value["k"][1], np.full((2, 3), -7.0))
